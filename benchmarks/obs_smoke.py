"""Observability smoke: a traced SSB join/agg must produce a valid trace.

Runs two SSB representative queries (a join/aggregation and a single-dim
filter flight) with ``obs.tracing`` on, then asserts the whole obs surface
end to end:

  * the trace has a span for every pipeline stage and a vertex record
    (with compute / exchange-wait / spill-I/O split) for every DAG vertex;
  * ``Connection.export_trace`` writes Chrome trace-event JSON that the
    ``repro.analysis.trace_check`` validator accepts (ph/ts/pid/tid,
    balanced B/E pairs, per-tid monotone timestamps);
  * ``Connection.metrics()`` returns a non-empty registry snapshot and
    ``Connection.query_log()`` recorded the runs.

Any failure blocks the merge.  Run:
``PYTHONPATH=src python -m benchmarks.obs_smoke``
"""
import json
import os
import sys
import tempfile

from benchmarks.ssb import SSB_QUERIES, load_ssb

SMOKE_QUERIES = ("q1.1", "q3.1")  # filter flight + 3-table join/agg


def main() -> int:
    import repro.api as db
    from repro.analysis.trace_check import validate_chrome_trace
    from repro.core.session import Warehouse

    failures = []
    wh = Warehouse(tempfile.mkdtemp(prefix="obs_smoke_"))
    load_ssb(wh, scale_rows=4000)
    conn = db.connect(warehouse=wh, result_cache=False,
                      **{"obs.tracing": True})
    outdir = tempfile.mkdtemp(prefix="obs_smoke_traces_")
    for qid in SMOKE_QUERIES:
        h = conn.execute_async(SSB_QUERIES[qid])
        h.result(120)
        summ = h._task.trace.summary()
        for stage in ("parse", "bind", "optimize", "compile", "execute"):
            if stage not in summ["stages_ms"]:
                failures.append(f"{qid}: no span for stage {stage!r}")
        n_vertices = h.poll()["vertices_total"]
        if len(summ["vertices"]) != n_vertices:
            failures.append(
                f"{qid}: {len(summ['vertices'])} vertex records for "
                f"{n_vertices} DAG vertices")
        for vid, v in summ["vertices"].items():
            split = (v["compute_ms"] + v["exchange_wait_ms"]
                     + v["spill_io_ms"])
            if split > v["total_ms"] + 0.01:
                failures.append(
                    f"{qid}/{vid}: sub-phases {split}ms exceed total "
                    f"{v['total_ms']}ms")
        path = os.path.join(outdir, f"{qid.replace('.', '_')}.json")
        conn.export_trace(h.query_id, path)
        with open(path) as f:
            problems = validate_chrome_trace(json.load(f))
        failures.extend(f"{qid}: {p}" for p in problems)
        print(f"obs_smoke: {qid} traced — {len(summ['vertices'])} "
              f"vertices, {len(summ['events'])} events, export at {path}")

    metrics = conn.metrics()
    if not metrics["counters"]:
        failures.append("metrics snapshot has no counters")
    if metrics["counters"].get("query.succeeded", 0) < len(SMOKE_QUERIES):
        failures.append("query.succeeded counter did not advance")
    if len(conn.query_log()) < len(SMOKE_QUERIES):
        failures.append("query log missing entries")
    conn.close()

    if failures:
        print(f"obs_smoke: {len(failures)} failure(s)")
        for f in failures:
            print(" ", f)
        return 1
    print(f"obs_smoke: OK — {len(SMOKE_QUERIES)} traced queries validated, "
          f"{len(metrics['counters'])} counters live")
    return 0


if __name__ == "__main__":
    sys.exit(main())
