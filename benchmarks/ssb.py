"""Star-Schema-Benchmark-style synthetic data + query set (paper §7.3).

One fact table (lineorder) + 4 dimensions (date, customer, supplier, part)
and 13 queries across 4 flights that join, aggregate and place tight
dimensional filters — the workload shape of both the paper's Fig. 7 (TPC-DS)
and Fig. 8 (SSB) experiments.
"""
from __future__ import annotations

import numpy as np

from repro.core.acid import AcidTable
from repro.core.runtime.vector import VectorBatch


def load_ssb(wh, scale_rows: int = 60_000, seed: int = 42):
    s = wh.session()
    hms = wh.hms
    s.execute("""CREATE TABLE date_dim (d_datekey INT, d_year INT, d_month INT,
        d_weeknum INT, d_yearmonthnum INT)""")
    s.execute("""CREATE TABLE customer (c_custkey INT, c_region STRING,
        c_nation STRING, c_city STRING)""")
    s.execute("""CREATE TABLE supplier (s_suppkey INT, s_region STRING,
        s_nation STRING, s_city STRING)""")
    s.execute("""CREATE TABLE part (p_partkey INT, p_mfgr STRING,
        p_category STRING, p_brand STRING)""")
    s.execute("""CREATE TABLE lineorder (lo_orderkey INT, lo_custkey INT,
        lo_partkey INT, lo_suppkey INT, lo_orderdate INT, lo_quantity INT,
        lo_extendedprice DOUBLE, lo_discount DOUBLE, lo_revenue DOUBLE,
        lo_supplycost DOUBLE)""")

    rng = np.random.default_rng(seed)
    n_dates, n_cust, n_supp, n_part = 2556, 1000, 200, 400
    regions = np.array(["AMERICA", "ASIA", "EUROPE", "AFRICA", "MIDDLE EAST"])
    nations = np.array([f"NATION_{i}" for i in range(25)])
    cities = np.array([f"CITY_{i}" for i in range(50)])

    tx = hms.open_txn()
    AcidTable(hms.get_table("date_dim"), hms).insert(tx, VectorBatch({
        "d_datekey": np.arange(n_dates),
        "d_year": 1992 + np.arange(n_dates) // 365,
        "d_month": (np.arange(n_dates) // 30) % 12 + 1,
        "d_weeknum": (np.arange(n_dates) // 7) % 52 + 1,
        "d_yearmonthnum": (1992 + np.arange(n_dates) // 365) * 100
        + ((np.arange(n_dates) // 30) % 12 + 1),
    }))
    AcidTable(hms.get_table("customer"), hms).insert(tx, VectorBatch({
        "c_custkey": np.arange(n_cust),
        "c_region": regions[rng.integers(0, 5, n_cust)],
        "c_nation": nations[rng.integers(0, 25, n_cust)],
        "c_city": cities[rng.integers(0, 50, n_cust)],
    }))
    AcidTable(hms.get_table("supplier"), hms).insert(tx, VectorBatch({
        "s_suppkey": np.arange(n_supp),
        "s_region": regions[rng.integers(0, 5, n_supp)],
        "s_nation": nations[rng.integers(0, 25, n_supp)],
        "s_city": cities[rng.integers(0, 50, n_supp)],
    }))
    AcidTable(hms.get_table("part"), hms).insert(tx, VectorBatch({
        "p_partkey": np.arange(n_part),
        "p_mfgr": np.array([f"MFGR_{i % 5}" for i in range(n_part)]),
        "p_category": np.array([f"CAT_{i % 25}" for i in range(n_part)]),
        "p_brand": np.array([f"BRAND_{i % 40}" for i in range(n_part)]),
    }))
    n = scale_rows
    price = rng.uniform(100, 10_000, n).round(2)
    disc = rng.uniform(0, 0.1, n).round(3)
    AcidTable(hms.get_table("lineorder"), hms).insert(tx, VectorBatch({
        "lo_orderkey": np.arange(n),
        "lo_custkey": rng.integers(0, n_cust, n),
        "lo_partkey": rng.integers(0, n_part, n),
        "lo_suppkey": rng.integers(0, n_supp, n),
        "lo_orderdate": rng.integers(0, n_dates, n),
        "lo_quantity": rng.integers(1, 50, n),
        "lo_extendedprice": price,
        "lo_discount": disc,
        "lo_revenue": (price * (1 - disc)).round(2),
        "lo_supplycost": rng.uniform(50, 5000, n).round(2),
    }))
    hms.commit_txn(tx)


def zipf_keys(rng: np.random.Generator, n: int, n_keys: int,
              alpha: float = 1.3) -> np.ndarray:
    """``n`` keys over ``[0, n_keys)`` with a Zipf(alpha) frequency profile.

    Rank-1 truncated zipf (not ``rng.zipf``, whose support is unbounded):
    key ``k`` is drawn with probability proportional to ``(k+1)**-alpha``,
    so the hottest key owns a constant fraction of the rows regardless of
    ``n`` — the skew shape that makes one shuffle lane a straggler."""
    ranks = np.arange(1, n_keys + 1, dtype=np.float64)
    probs = ranks ** -alpha
    probs /= probs.sum()
    return rng.choice(n_keys, size=n, p=probs).astype(np.int64)


def load_skewed(wh, scale_rows: int = 400_000, n_keys: int = 64,
                alpha: float = 1.6, seed: int = 43):
    """A fact/dim pair whose join and group keys are zipf-skewed — the
    adaptive-execution benchmark workload (hot shuffle lane + straggler)."""
    s = wh.session()
    hms = wh.hms
    s.execute("CREATE TABLE zfact (zf_key INT, zf_val DOUBLE, zf_qty INT)")
    s.execute("CREATE TABLE zdim (zd_key INT, zd_group INT)")
    rng = np.random.default_rng(seed)
    keys = zipf_keys(rng, scale_rows, n_keys, alpha)
    tx = hms.open_txn()
    AcidTable(hms.get_table("zfact"), hms).insert(tx, VectorBatch({
        "zf_key": keys,
        "zf_val": rng.uniform(1, 100, scale_rows).round(2),
        "zf_qty": rng.integers(1, 50, scale_rows),
    }))
    AcidTable(hms.get_table("zdim"), hms).insert(tx, VectorBatch({
        "zd_key": np.arange(n_keys),
        "zd_group": np.arange(n_keys) % 8,
    }))
    hms.commit_txn(tx)


# skewed join/agg queries for the adaptive-execution benchmark, shaped like
# a per-key dashboard drill-down: zq2/zq4/zq5/zq6 group on the join key, so
# the co-partition shuffle elision applies; zq3 groups on a non-join column
# (its aggregate keeps its own shuffle hop — a negative control); zq1 is a
# plain scan-fed aggregate (skew telemetry, no join)
SKEWED_QUERIES = {
    "zq1": """SELECT zf_key, SUM(zf_val) AS total, COUNT(*) AS n
        FROM zfact GROUP BY zf_key""",
    "zq2": """SELECT f.zf_key, SUM(f.zf_val) AS total
        FROM zfact f JOIN zdim d ON f.zf_key = d.zd_key
        GROUP BY f.zf_key""",
    "zq3": """SELECT f.zf_qty, SUM(f.zf_val) AS total
        FROM zfact f JOIN zdim d ON f.zf_key = d.zd_key
        GROUP BY f.zf_qty""",
    "zq4": """SELECT f.zf_key, SUM(f.zf_val) AS t, COUNT(*) AS n,
        MIN(f.zf_val) AS lo, MAX(f.zf_val) AS hi
        FROM zfact f JOIN zdim d ON f.zf_key = d.zd_key
        GROUP BY f.zf_key""",
    "zq5": """SELECT f.zf_key, SUM(f.zf_val) AS total, SUM(f.zf_qty) AS q
        FROM zfact f JOIN zdim d ON f.zf_key = d.zd_key
        WHERE d.zd_group < 4 GROUP BY f.zf_key""",
    "zq6": """SELECT f.zf_key, SUM(f.zf_val) AS a, SUM(f.zf_qty) AS b,
        AVG(f.zf_val) AS c, COUNT(*) AS n
        FROM zfact f JOIN zdim d ON f.zf_key = d.zd_key
        GROUP BY f.zf_key""",
}


SSB_QUERIES = {
    # flight 1: single-dim filters
    "q1.1": """SELECT SUM(lo_extendedprice * lo_discount) AS revenue
        FROM lineorder, date_dim WHERE lo_orderdate = d_datekey
        AND d_year = 1993 AND lo_discount BETWEEN 0.01 AND 0.03
        AND lo_quantity < 25""",
    "q1.2": """SELECT SUM(lo_extendedprice * lo_discount) AS revenue
        FROM lineorder, date_dim WHERE lo_orderdate = d_datekey
        AND d_yearmonthnum = 199401 AND lo_discount BETWEEN 0.04 AND 0.06
        AND lo_quantity BETWEEN 26 AND 35""",
    "q1.3": """SELECT SUM(lo_extendedprice * lo_discount) AS revenue
        FROM lineorder, date_dim WHERE lo_orderdate = d_datekey
        AND d_weeknum = 6 AND d_year = 1994
        AND lo_discount BETWEEN 0.05 AND 0.07 AND lo_quantity BETWEEN 26 AND 35""",
    # flight 2: part x supplier
    "q2.1": """SELECT d_year, p_brand, SUM(lo_revenue) AS revenue
        FROM lineorder, date_dim, part, supplier
        WHERE lo_orderdate = d_datekey AND lo_partkey = p_partkey
        AND lo_suppkey = s_suppkey AND p_category = 'CAT_12'
        AND s_region = 'AMERICA' GROUP BY d_year, p_brand
        ORDER BY d_year, p_brand""",
    "q2.2": """SELECT d_year, p_brand, SUM(lo_revenue) AS revenue
        FROM lineorder, date_dim, part, supplier
        WHERE lo_orderdate = d_datekey AND lo_partkey = p_partkey
        AND lo_suppkey = s_suppkey AND p_brand = 'BRAND_22'
        AND s_region = 'ASIA' GROUP BY d_year, p_brand ORDER BY d_year""",
    "q2.3": """SELECT d_year, p_brand, SUM(lo_revenue) AS revenue
        FROM lineorder, date_dim, part, supplier
        WHERE lo_orderdate = d_datekey AND lo_partkey = p_partkey
        AND lo_suppkey = s_suppkey AND p_brand = 'BRAND_3'
        AND s_region = 'EUROPE' GROUP BY d_year, p_brand ORDER BY d_year""",
    # flight 3: customer x supplier geography
    "q3.1": """SELECT c_nation, s_nation, d_year, SUM(lo_revenue) AS revenue
        FROM lineorder, customer, supplier, date_dim
        WHERE lo_custkey = c_custkey AND lo_suppkey = s_suppkey
        AND lo_orderdate = d_datekey AND c_region = 'ASIA'
        AND s_region = 'ASIA' AND d_year >= 1992 AND d_year <= 1997
        GROUP BY c_nation, s_nation, d_year ORDER BY d_year, revenue DESC""",
    "q3.2": """SELECT c_city, s_city, d_year, SUM(lo_revenue) AS revenue
        FROM lineorder, customer, supplier, date_dim
        WHERE lo_custkey = c_custkey AND lo_suppkey = s_suppkey
        AND lo_orderdate = d_datekey AND c_nation = 'NATION_3'
        AND s_nation = 'NATION_3' AND d_year >= 1992 AND d_year <= 1997
        GROUP BY c_city, s_city, d_year ORDER BY d_year, revenue DESC""",
    "q3.3": """SELECT c_city, s_city, d_year, SUM(lo_revenue) AS revenue
        FROM lineorder, customer, supplier, date_dim
        WHERE lo_custkey = c_custkey AND lo_suppkey = s_suppkey
        AND lo_orderdate = d_datekey AND c_city = 'CITY_10'
        AND s_city = 'CITY_10' AND d_year >= 1992 AND d_year <= 1997
        GROUP BY c_city, s_city, d_year ORDER BY d_year, revenue DESC""",
    "q3.4": """SELECT c_city, s_city, d_year, SUM(lo_revenue) AS revenue
        FROM lineorder, customer, supplier, date_dim
        WHERE lo_custkey = c_custkey AND lo_suppkey = s_suppkey
        AND lo_orderdate = d_datekey AND c_city = 'CITY_10'
        AND s_city = 'CITY_11' AND d_yearmonthnum = 199712
        GROUP BY c_city, s_city, d_year ORDER BY revenue DESC""",
    # flight 4: profit drill-downs
    "q4.1": """SELECT d_year, c_nation,
        SUM(lo_revenue - lo_supplycost) AS profit
        FROM lineorder, date_dim, customer, supplier, part
        WHERE lo_custkey = c_custkey AND lo_suppkey = s_suppkey
        AND lo_partkey = p_partkey AND lo_orderdate = d_datekey
        AND c_region = 'AMERICA' AND s_region = 'AMERICA'
        GROUP BY d_year, c_nation ORDER BY d_year, c_nation""",
    "q4.2": """SELECT d_year, s_nation, p_category,
        SUM(lo_revenue - lo_supplycost) AS profit
        FROM lineorder, date_dim, customer, supplier, part
        WHERE lo_custkey = c_custkey AND lo_suppkey = s_suppkey
        AND lo_partkey = p_partkey AND lo_orderdate = d_datekey
        AND c_region = 'AMERICA' AND s_region = 'AMERICA'
        AND d_year IN (1997, 1998)
        GROUP BY d_year, s_nation, p_category ORDER BY d_year, s_nation""",
    "q4.3": """SELECT d_year, s_city, p_brand,
        SUM(lo_revenue - lo_supplycost) AS profit
        FROM lineorder, date_dim, supplier, part
        WHERE lo_suppkey = s_suppkey AND lo_partkey = p_partkey
        AND lo_orderdate = d_datekey AND s_nation = 'NATION_24'
        AND d_year IN (1997, 1998)
        GROUP BY d_year, s_city, p_brand ORDER BY d_year, s_city""",
}
