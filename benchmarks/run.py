"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows plus per-section summaries.

  fig7_version_evolution : Hive v1-mode vs v3-mode over 13 SSB queries (§7.1)
  table1_llap            : LLAP cache on/off total response time (§7.2)
  fig8_federation        : MV native vs MV-in-Druid with pushdown (§7.3)
  acid_at_par            : §8 claim — post-compaction ACID reads at par
  q88_shared_work        : §7.1 claim — shared work optimizer speedup
  kernel_micro           : Pallas kernels (interpret mode) vs jnp oracles
  roofline_summary       : aggregates experiments/dryrun artifacts (§Roofline)
  bench_pr3              : pipelined streaming vs materialized baseline
                           (wall, time-to-first-batch, peak buffered rows,
                           spill counts) -> BENCH_PR3.json
  bench_pr4              : federated scans through the capability-negotiated
                           DataSource API (wall + time-to-first-batch,
                           pushdown on/off, split parallelism)
                           -> BENCH_PR4.json
  bench_pr5              : partitioned shuffle service — partitioned vs
                           single-lane join/aggregation/DISTINCT (wall +
                           time-to-first-batch), skewed vs uniform keys
                           with per-lane rows/spill counts
                           -> BENCH_PR5.json
  bench_pr6              : serving tier — closed-loop concurrent clients
                           (N in 1/8/32/128) over mixed repeated/unique SSB
                           queries, serving on vs off (p50/p99 latency,
                           throughput, result-cache + shared-scan hit
                           rates) -> BENCH_PR6.json
  bench_pr8              : adaptive execution — live-telemetry replanning
                           (hot-lane split, co-partition shuffle elision,
                           payoff-gated fan-out) adaptive on vs off over a
                           zipf-skewed join/agg workload, plus a uniform
                           SSB Q1-Q4 no-regression check
                           -> BENCH_PR8.json
  bench_pr10             : observability — tracing on vs off wall delta
                           over SSB representatives (min-of-5; the span/
                           event overhead a traced query pays), with each
                           traced query's ``trace_summary`` (per-vertex
                           compute/exchange-wait/spill-I/O) embedded
                           -> BENCH_PR10.json

``python -m benchmarks.run pr3|pr4|pr5|pr6|pr8|pr10 [--scale N] [--out PATH]``
runs only that PR's benchmark (the CI smoke invocations).  All wall-clock
claims use min-of-5 timing (the ``timing`` field in each BENCH_PRn.json).
New BENCH reports embed a ``trace_summary`` where a traced run is part of
the measurement (PR 10).
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

ROWS = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def _rounded(rows):
    """Row-set comparison tolerant of float accumulation order."""
    return sorted(
        tuple(round(x, 4) if isinstance(x, float) else x for x in r)
        for r in rows
    )


def _fresh_ssb(scale=60_000, **session_cfg):
    from benchmarks.ssb import load_ssb
    from repro.core.session import Warehouse

    wh = Warehouse(tempfile.mkdtemp(prefix="bench_wh_"))
    load_ssb(wh, scale_rows=scale)
    return wh


V1_MODE = dict(  # Hive v1.2-ish: rule-based physical tweaks only
    cbo=False, join_reorder=False, transitive_inference=False,
    mv_rewriting=False, semijoin_reduction=False, shared_work=False,
    result_cache=False, llap=False, reopt_mode="off",
    broadcast_threshold_rows=0.0,
)
V3_MODE = dict(result_cache=False)  # everything else on (cache timed separately)


def fig7_version_evolution():
    from benchmarks.ssb import SSB_QUERIES

    wh = _fresh_ssb()
    t_v1, t_v3 = {}, {}
    s1 = wh.session(**V1_MODE)
    s3 = wh.session(**V3_MODE)
    for name, sql in SSB_QUERIES.items():
        r3 = s3.execute(sql)  # warm LLAP first (paper reports warm cache)
        t0 = time.perf_counter()
        r3 = s3.execute(sql)
        t_v3[name] = time.perf_counter() - t0
        t0 = time.perf_counter()
        r1 = s1.execute(sql)
        t_v1[name] = time.perf_counter() - t0
        assert _rounded(r1.rows) == _rounded(r3.rows), name
        emit(f"fig7.{name}.v1", t_v1[name] * 1e6)
        emit(f"fig7.{name}.v3", t_v3[name] * 1e6,
             f"speedup={t_v1[name] / t_v3[name]:.2f}x")
    total1, total3 = sum(t_v1.values()), sum(t_v3.values())
    emit("fig7.total.v1", total1 * 1e6)
    emit("fig7.total.v3", total3 * 1e6, f"speedup={total1 / total3:.2f}x")
    return total1 / total3


def table1_llap():
    from benchmarks.ssb import SSB_QUERIES

    wh = _fresh_ssb()
    s_cont = wh.session(llap=False, result_cache=False)
    s_llap = wh.session(llap=True, result_cache=False)
    # containers: every query pays cold I/O; LLAP: warm decoded-chunk cache
    t_c = 0.0
    for sql in SSB_QUERIES.values():
        t0 = time.perf_counter()
        s_cont.execute(sql)
        t_c += time.perf_counter() - t0
    for sql in SSB_QUERIES.values():
        s_llap.execute(sql)  # populate cache
    t_l = 0.0
    for sql in SSB_QUERIES.values():
        t0 = time.perf_counter()
        s_llap.execute(sql)
        t_l += time.perf_counter() - t0
    emit("table1.container_total", t_c * 1e6)
    emit("table1.llap_total", t_l * 1e6, f"speedup={t_c / t_l:.2f}x")
    c = wh.llap.counters
    emit("table1.llap_cache_hits", c["cache_hits"],
         f"misses={c['cache_misses']},stripes_skipped={c['stripes_skipped']}")
    return t_c / t_l


def fig8_federation():
    from repro.core.acid import AcidTable

    wh = _fresh_ssb(scale=60_000)
    s = wh.session(result_cache=False)
    # denormalized MV (the hortonworks SSB/Druid setup)
    s.execute("""CREATE MATERIALIZED VIEW ssb_flat AS
        SELECT d_year, c_region, s_region, p_category,
               SUM(lo_revenue) AS sum_rev, SUM(lo_quantity) AS sum_qty
        FROM lineorder, date_dim, customer, supplier, part
        WHERE lo_orderdate = d_datekey AND lo_custkey = c_custkey
          AND lo_suppkey = s_suppkey AND lo_partkey = p_partkey
        GROUP BY d_year, c_region, s_region, p_category""")
    queries = [
        ("f8.q1", "SELECT d_year, SUM(sum_rev) r FROM ssb_flat"
                  " WHERE c_region = 'ASIA' GROUP BY d_year ORDER BY d_year"),
        ("f8.q2", "SELECT c_region, SUM(sum_rev) r FROM ssb_flat"
                  " GROUP BY c_region ORDER BY r DESC LIMIT 3"),
        ("f8.q3", "SELECT p_category, SUM(sum_qty) q FROM ssb_flat"
                  " WHERE d_year >= 1995 GROUP BY p_category"
                  " ORDER BY q DESC LIMIT 5"),
    ]
    native = {}
    for name, sql in queries:
        s.execute(sql)
        t0 = time.perf_counter()
        r = s.execute(sql)
        native[name] = (time.perf_counter() - t0, _rounded(r.rows))
        emit(f"{name}.native_mv", native[name][0] * 1e6)

    # same MV contents stored in Druid; queries push down via Calcite (§6.2)
    mv_desc = wh.hms.get_table("ssb_flat")
    batch = AcidTable(mv_desc, wh.hms).read_all(
        wh.hms.writeid_list("ssb_flat", wh.hms.get_snapshot()))
    dr = wh.handlers.get("druid")
    dr.store.create_datasource("ssb_flat_druid", batch)
    s.execute("CREATE EXTERNAL TABLE ssb_flat_d STORED BY 'druid'"
              " TBLPROPERTIES ('druid.datasource' = 'ssb_flat_druid')")
    speedups = []
    for name, sql in queries:
        dsql = sql.replace("ssb_flat", "ssb_flat_d")
        s.execute(dsql)
        t0 = time.perf_counter()
        r = s.execute(dsql)
        dt = time.perf_counter() - t0
        assert _rounded(r.rows) == native[name][1], name
        speedups.append(native[name][0] / dt)
        emit(f"{name}.druid_pushdown", dt * 1e6,
             f"speedup={native[name][0] / dt:.2f}x,"
             f"pushed={r.info.get('federated_pushdown')}")
    return float(np.mean(speedups))


def acid_at_par():
    from repro.core.acid import AcidTable
    from repro.core.compaction import compact_partition
    from repro.core.session import Warehouse

    wh = Warehouse(tempfile.mkdtemp(prefix="bench_acid_"))
    s = wh.session(compaction_enabled=False, result_cache=False)
    s.execute("CREATE TABLE t (k INT, v DOUBLE)")
    rng = np.random.default_rng(0)
    for i in range(30):  # many small transactions -> many delta dirs
        vals = ", ".join(
            f"({int(k)}, {float(v):.3f})"
            for k, v in zip(rng.integers(0, 10_000, 2000),
                            rng.uniform(0, 1, 2000)))
        s.execute(f"INSERT INTO t VALUES {vals}")
    s.execute("DELETE FROM t WHERE k < 500")
    sql = "SELECT COUNT(*), SUM(v) FROM t WHERE k > 2000"

    t0 = time.perf_counter()
    for _ in range(3):
        s.execute(sql)
    pre = (time.perf_counter() - t0) / 3
    tbl = AcidTable(wh.hms.get_table("t"), wh.hms)
    compact_partition(tbl, tbl.desc.location, "major", wh.hms)
    t0 = time.perf_counter()
    for _ in range(3):
        s.execute(sql)
    post = (time.perf_counter() - t0) / 3
    emit("acid.read_pre_compaction", pre * 1e6)
    emit("acid.read_post_compaction", post * 1e6,
         f"merge_on_read_overhead={pre / post:.2f}x")
    return pre / post


def q88_shared_work():
    wh = _fresh_ssb()
    # one query computing the same fact-dim subexpression several times (q88 style)
    sql = """SELECT a.r1, b.r2, c.r3 FROM
      (SELECT SUM(lo_revenue) r1 FROM lineorder, date_dim
       WHERE lo_orderdate = d_datekey AND d_year = 1993) a,
      (SELECT SUM(lo_revenue) r2 FROM lineorder, date_dim
       WHERE lo_orderdate = d_datekey AND d_year = 1993) b,
      (SELECT SUM(lo_revenue) r3 FROM lineorder, date_dim
       WHERE lo_orderdate = d_datekey AND d_year = 1993) c"""
    s_off = wh.session(shared_work=False, result_cache=False)
    s_on = wh.session(shared_work=True, result_cache=False)
    s_off.execute(sql)
    s_on.execute(sql)
    t0 = time.perf_counter()
    r_off = s_off.execute(sql)
    t_off = time.perf_counter() - t0
    t0 = time.perf_counter()
    r_on = s_on.execute(sql)
    t_on = time.perf_counter() - t0
    assert r_off.rows == r_on.rows
    emit("q88.shared_work_off", t_off * 1e6)
    emit("q88.shared_work_on", t_on * 1e6, f"speedup={t_off / t_on:.2f}x")
    return t_off / t_on


def kernel_micro():
    import jax.numpy as jnp

    from repro.kernels.filter_eval.ops import filter_eval
    from repro.kernels.hash_group.ops import hash_group
    from repro.kernels.ssd_scan.ops import ssd_scan

    rng = np.random.default_rng(0)
    cols = [jnp.asarray(rng.uniform(0, 100, 16_384).astype(np.float32))
            for _ in range(2)]
    filter_eval(cols, (2, 1), (30.0, 70.0)).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        filter_eval(cols, (2, 1), (30.0, 70.0)).block_until_ready()
    emit("kernel.filter_eval", (time.perf_counter() - t0) / 5 * 1e6,
         "interpret-mode (TPU target)")

    codes = jnp.asarray(rng.integers(0, 128, 16_384).astype(np.int32))
    vals = jnp.asarray(rng.uniform(0, 1, 16_384).astype(np.float32))
    hash_group(codes, vals, 128)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        hash_group(codes, vals, 128)[0].block_until_ready()
    emit("kernel.hash_group", (time.perf_counter() - t0) / 5 * 1e6,
         "one-hot MXU group-by")

    x = jnp.asarray(rng.normal(size=(1, 512, 2, 16)).astype(np.float32)) * 0.1
    dA = -jnp.abs(jnp.asarray(rng.normal(size=(1, 512, 2)).astype(np.float32))) * 0.1
    Bm = jnp.asarray(rng.normal(size=(1, 512, 8)).astype(np.float32))
    Cm = jnp.asarray(rng.normal(size=(1, 512, 8)).astype(np.float32))
    ssd_scan(x, dA, Bm, Cm, chunk=64)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(3):
        ssd_scan(x, dA, Bm, Cm, chunk=64)[0].block_until_ready()
    emit("kernel.ssd_scan", (time.perf_counter() - t0) / 3 * 1e6,
         "chunked SSD (interpret)")


PR3_QUERIES = {
    # scan-filter-project: first rows stream out while the scan runs
    "scan_stream": "SELECT lo_orderdate, lo_revenue FROM lineorder"
                   " WHERE lo_quantity < 48",
    # one representative per SSB flight (Q1-Q4)
    "q1.1": None, "q2.1": None, "q3.1": None, "q4.1": None,
}


def _pr3_measure(conn, sql, page_rows=1024):
    """One streamed execution: wall, time-to-first-batch, poll metrics."""
    t0 = time.perf_counter()
    h = conn.execute_async(sql)
    ttfb = None
    rows = 0
    for batch in h.fetch_stream(batch_rows=page_rows):
        if ttfb is None:
            ttfb = time.perf_counter() - t0
        rows += len(batch)
    h.result(600)
    wall = time.perf_counter() - t0
    p = h.poll()
    return {
        "wall_ms": round(wall * 1e3, 3),
        "time_to_first_batch_ms": round((ttfb if ttfb is not None else wall)
                                        * 1e3, 3),
        "rows": rows,
        "peak_buffered_rows": int(p.get("peak_buffered_rows", 0)),
        "rows_spilled": int(p.get("rows_spilled", 0)),
        "bytes_spilled": int(p.get("bytes_spilled", 0)),
        "spill_chunks_by_vertex": {k: v for k, v in p.get("spill", {}).items()
                                   if v.get("rows")},
    }


def bench_pr3(scale=60_000, out_path=None):
    """Streaming-execution trajectory: pipelined exchanges vs the
    materialize-every-vertex baseline, plus a constrained-budget spill run.

    Writes BENCH_PR3.json so later PRs can track wall time,
    time-to-first-batch, and peak buffered rows per SSB query.
    """
    import repro.api as db
    from benchmarks.ssb import SSB_QUERIES

    wh = _fresh_ssb(scale=scale)
    queries = {name: (sql or SSB_QUERIES[name])
               for name, sql in PR3_QUERIES.items()}
    modes = {
        "baseline": {"exchange.pipeline": False},
        "pipelined": {},
        "pipelined_tight": {"exchange.buffer_rows": 2048,
                            "exchange.buffer_bytes": 1 << 20},
    }
    report = {
        "scale_rows": scale,
        "config": {"exchange.batch_rows": 1024,
                   "tight_buffer_rows": 2048},
        "timing": {"runs_per_cell": 5, "reduction": "min", "warmup_runs": 1},
        "queries": {},
    }
    for name, sql in queries.items():
        per_query = {}
        for mode, overrides in modes.items():
            conn = db.connect(warehouse=wh, result_cache=False, **overrides)
            _pr3_measure(conn, sql)  # warm LLAP (paper reports warm cache)
            runs = [_pr3_measure(conn, sql) for _ in range(5)]
            per_query[mode] = min(runs, key=lambda r: r["wall_ms"])
            conn.close()
            emit(f"pr3.{name}.{mode}", per_query[mode]["wall_ms"] * 1e3,
                 f"ttfb_ms={per_query[mode]['time_to_first_batch_ms']},"
                 f"peak_rows={per_query[mode]['peak_buffered_rows']},"
                 f"spilled={per_query[mode]['rows_spilled']}")
        assert per_query["baseline"]["rows"] == per_query["pipelined"]["rows"]
        assert per_query["pipelined"]["rows"] == \
            per_query["pipelined_tight"]["rows"]
        per_query["ttfb_speedup_vs_baseline"] = round(
            per_query["baseline"]["time_to_first_batch_ms"]
            / max(per_query["pipelined"]["time_to_first_batch_ms"], 1e-3), 3)
        report["queries"][name] = per_query
    streamed = report["queries"]["scan_stream"]
    report["summary"] = {
        "scan_ttfb_speedup": streamed["ttfb_speedup_vs_baseline"],
        "scan_peak_rows_baseline": streamed["baseline"]["peak_buffered_rows"],
        "scan_peak_rows_pipelined":
            streamed["pipelined"]["peak_buffered_rows"],
        # under a constrained budget the in-memory peak stays bounded by
        # exchange.buffer_rows while results stay identical (spill/replay)
        "scan_peak_rows_tight":
            streamed["pipelined_tight"]["peak_buffered_rows"],
        "tight_budget_total_rows_spilled": sum(
            q["pipelined_tight"]["rows_spilled"]
            for q in report["queries"].values()),
    }
    out_path = out_path or os.path.join(os.path.dirname(__file__),
                                        "BENCH_PR3.json")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("pr3.scan_ttfb_speedup", report["summary"]["scan_ttfb_speedup"])
    wh.close()
    return report


def bench_pr4(scale=60_000, out_path=None):
    """Federated-scan trajectory (PR 4): wall time and time-to-first-batch
    for split-parallel streaming scans over a memtable catalog and an
    aggregate query over the jdbc connector, with capability-negotiated
    pushdown on vs off.  Writes BENCH_PR4.json.
    """
    import repro.api as db
    from repro.core.runtime.vector import VectorBatch
    from repro.core.session import Warehouse

    rng = np.random.default_rng(0)
    wh = Warehouse(tempfile.mkdtemp(prefix="bench_pr4_"))
    boot = wh.session()
    boot.execute("CREATE CATALOG mem USING memtable"
                 " WITH (latency_s = '0.0005', batch_rows = '1024')")
    mem = wh.catalogs.get("mem").handler
    mem.load("events", VectorBatch({
        "uid": rng.integers(0, 5000, scale),
        "amount": rng.uniform(0, 100, scale).round(4),
        "region": np.array(["emea", "apac", "amer", "anz"])[
            rng.integers(0, 4, scale)],
    }))
    jd = wh.handlers.get("jdbc")
    jd.load_table("orders", VectorBatch({
        "uid": rng.integers(0, 5000, scale),
        "price": rng.uniform(0, 50, scale).round(4),
    }))
    boot.execute("CREATE EXTERNAL TABLE orders (uid INT, price DOUBLE)"
                 " STORED BY 'jdbc' TBLPROPERTIES ('jdbc.table'='orders')")

    queries = {
        "mem_scan_filter": "SELECT uid, amount FROM mem.default.events"
                           " WHERE amount < 75",
        "mem_topn": "SELECT uid, amount FROM mem.default.events LIMIT 2048",
        "jdbc_agg": "SELECT uid, SUM(price) sp FROM orders"
                    " WHERE uid < 2500 GROUP BY uid",
    }
    pushdown_off = {
        "federation.push_filters": False,
        "federation.push_projection": False,
        "federation.push_aggregate": False,
        "federation.push_limit": False,
    }
    modes = {"pushdown_on": {}, "pushdown_off": pushdown_off}
    report = {"scale_rows": scale,
              "config": {"federation.splits": 4,
                         "memtable_latency_s": 0.0005},
              "timing": {"runs_per_cell": 5, "reduction": "min",
                         "warmup_runs": 1},
              "queries": {}}
    for name, sql in queries.items():
        per_query = {}
        for mode, overrides in modes.items():
            conn = db.connect(warehouse=wh, result_cache=False, **overrides)
            _pr3_measure(conn, sql)  # warm-up
            runs = [_pr3_measure(conn, sql) for _ in range(5)]
            best = min(runs, key=lambda r: r["wall_ms"])
            h = conn.execute_async(sql)
            h.result(600)
            best["pushed"] = h.info.get("federated_pushdown")
            per_query[mode] = best
            conn.close()
            emit(f"pr4.{name}.{mode}", best["wall_ms"] * 1e3,
                 f"ttfb_ms={best['time_to_first_batch_ms']},"
                 f"rows={best['rows']}")
        assert per_query["pushdown_on"]["rows"] == \
            per_query["pushdown_off"]["rows"], name
        per_query["wall_speedup_pushdown"] = round(
            per_query["pushdown_off"]["wall_ms"]
            / max(per_query["pushdown_on"]["wall_ms"], 1e-3), 3)
        per_query["ttfb_speedup_pushdown"] = round(
            per_query["pushdown_off"]["time_to_first_batch_ms"]
            / max(per_query["pushdown_on"]["time_to_first_batch_ms"],
                  1e-3), 3)
        report["queries"][name] = per_query
    report["summary"] = {
        "scan_ttfb_ms_pushdown_on": report["queries"]["mem_scan_filter"][
            "pushdown_on"]["time_to_first_batch_ms"],
        "scan_wall_speedup_pushdown": report["queries"]["mem_scan_filter"][
            "wall_speedup_pushdown"],
        "jdbc_agg_wall_speedup_pushdown": report["queries"]["jdbc_agg"][
            "wall_speedup_pushdown"],
        "peak_parallel_split_readers": mem.peak_active_readers,
    }
    out_path = out_path or os.path.join(os.path.dirname(__file__),
                                        "BENCH_PR4.json")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("pr4.peak_parallel_split_readers", mem.peak_active_readers)
    wh.close()
    return report


def bench_pr5(scale=240_000, out_path=None):
    """Partitioned shuffle service (PR 5): hash-partitioned exchange lanes
    vs the single-lane baseline on grouped-aggregation, shuffle-join, and
    DISTINCT workloads, plus a skewed-vs-uniform key study with per-lane
    rows/spill counts.  Writes BENCH_PR5.json.
    """
    import repro.api as db
    from benchmarks.ssb import SSB_QUERIES, load_ssb
    from repro.core.runtime.shuffle import auto_partition_cap
    from repro.core.session import Warehouse

    parts = auto_partition_cap()
    # enough executors that producer, clones, and merge vertices never
    # queue behind one another (the point of partition parallelism)
    wh = Warehouse(tempfile.mkdtemp(prefix="bench_pr5_"),
                   llap_executors=max(8, 4 * parts))
    load_ssb(wh, scale_rows=scale)

    queries = {
        # grouped aggregation over the 4-dim star join (SSB q4.1): the
        # aggregation input crosses a shuffle edge in both modes, so the
        # partitioned lanes measure fan-out, not the loss of scan fusion
        "group_agg": SSB_QUERIES["q4.1"],
        # shuffle join + grouped aggregation (SSB flight 3)
        "join_agg": SSB_QUERIES["q3.1"],
        # aggregation fed straight by a native scan: single-lane fuses the
        # scan into the aggregate vertex (no exchange at all), so this one
        # records what the extra hop costs when there is nothing to fan out
        "scan_agg": "SELECT lo_custkey, SUM(lo_revenue) AS a,"
                    " MIN(lo_revenue) AS b, MAX(lo_revenue) AS c,"
                    " SUM(lo_quantity) AS d, COUNT(*) AS e,"
                    " AVG(lo_extendedprice) AS f"
                    " FROM lineorder GROUP BY lo_custkey",
        # streaming per-partition distinct hash-set state
        "distinct_agg": "SELECT lo_suppkey, COUNT(DISTINCT lo_custkey) AS d"
                        " FROM lineorder GROUP BY lo_suppkey",
    }
    common = {"result_cache": False, "broadcast_threshold_rows": 0.0,
              "exchange.buffer_rows": 1 << 20}
    modes = {
        "single_lane": {"shuffle.partitions": 1},
        "partitioned": {"shuffle.partitions": parts},
    }
    report = {
        "scale_rows": scale,
        "config": {"partitions": parts, "lane_batch_rows": 8192,
                   "exchange.batch_rows": 1024},
        "timing": {"runs_per_cell": 5, "reduction": "min",
                   "warmup_runs": 1},
        "queries": {},
    }
    for name, sql in queries.items():
        per_query = {}
        for mode, overrides in modes.items():
            conn = db.connect(warehouse=wh, **common, **overrides)
            _pr3_measure(conn, sql)  # warm LLAP (paper reports warm cache)
            runs = []
            for _ in range(5):
                h = conn.execute_async(sql)
                t0 = time.perf_counter()
                ttfb = None
                rows = 0
                for batch in h.fetch_stream(batch_rows=1024):
                    if ttfb is None:
                        ttfb = time.perf_counter() - t0
                    rows += len(batch)
                h.result(600)
                wall = time.perf_counter() - t0
                p = h.poll()
                runs.append({
                    "wall_ms": round(wall * 1e3, 3),
                    "time_to_first_batch_ms": round(
                        (ttfb if ttfb is not None else wall) * 1e3, 3),
                    "rows": rows,
                    "rows_spilled": int(p.get("rows_spilled", 0)),
                    "lanes": p.get("lanes", {}),
                })
            best = min(runs, key=lambda r: r["wall_ms"])
            best["lane_rows"] = {
                vid: [lane["rows"] for lane in lanes]
                for vid, lanes in best.pop("lanes", {}).items()
            }
            per_query[mode] = best
            conn.close()
            emit(f"pr5.{name}.{mode}", best["wall_ms"] * 1e3,
                 f"ttfb_ms={best['time_to_first_batch_ms']},"
                 f"rows={best['rows']},lanes={len(best['lane_rows'])}")
        assert per_query["single_lane"]["rows"] == \
            per_query["partitioned"]["rows"], name
        per_query["wall_speedup_partitioned"] = round(
            per_query["single_lane"]["wall_ms"]
            / max(per_query["partitioned"]["wall_ms"], 1e-3), 3)
        per_query["ttfb_speedup_partitioned"] = round(
            per_query["single_lane"]["time_to_first_batch_ms"]
            / max(per_query["partitioned"]["time_to_first_batch_ms"],
                  1e-3), 3)
        report["queries"][name] = per_query

    # ---- skewed vs uniform keys: per-lane telemetry under a lane budget --
    # a dedicated single-executor warehouse makes the spill contrast
    # deterministic: with one worker the producer fills every lane before a
    # clone drains (put never blocks), so buffered rows per lane equal that
    # lane's share of the table — the hot lane overflows its budget, the
    # uniform lanes never do
    skew_wh = Warehouse(tempfile.mkdtemp(prefix="bench_pr5_skew_"),
                        llap_executors=1)
    s = skew_wh.session()
    s.execute("CREATE TABLE skewed (k INT, v DOUBLE)")
    s.execute("CREATE TABLE uniform (k INT, v DOUBLE)")
    rng = np.random.default_rng(0)
    n = max(scale // 2, 2000)
    hot = rng.uniform(size=n) < 0.85
    ks = np.where(hot, 7, rng.integers(0, 1024, n))
    ku = rng.integers(0, 1024, n)
    from repro.core.acid import AcidTable
    from repro.core.runtime.vector import VectorBatch

    for tname, karr in (("skewed", ks), ("uniform", ku)):
        tx = skew_wh.hms.open_txn()
        AcidTable(skew_wh.hms.get_table(tname), skew_wh.hms).insert(
            tx, VectorBatch({"k": karr.astype(np.int64),
                             "v": rng.uniform(0, 1, n).round(5)}))
        skew_wh.hms.commit_txn(tx)
    report["skew"] = {}
    # lane budget sized between the uniform per-lane share (n / parts) and
    # the skewed hot lane (~0.85 n): uniform lanes stay in memory, the hot
    # lane overflows — the per-lane spill counters point straight at it
    lane_budget = int(n * 0.7)
    for tname in ("skewed", "uniform"):
        conn = db.connect(warehouse=skew_wh, result_cache=False,
                          **{"shuffle.partitions": parts,
                             "exchange.buffer_rows": lane_budget})
        sql = (f"SELECT k, COUNT(*) AS c, SUM(v) AS sv FROM {tname}"
               " GROUP BY k")
        conn.execute(sql)
        h = conn.execute_async(sql)
        h.result(600)
        p = h.poll()
        lanes = [lane for ls in p.get("lanes", {}).values() for lane in ls]
        lane_rows = [lane["rows"] for lane in lanes] or [0]
        report["skew"][tname] = {
            "per_lane_rows": lane_rows,
            "hot_lane_rows": max(lane_rows),
            "per_lane_spilled_rows": [lane["spilled_rows"]
                                      for lane in lanes],
            "rows_spilled": int(p.get("rows_spilled", 0)),
        }
        conn.close()
        emit(f"pr5.skew.{tname}", max(lane_rows),
             f"spilled={report['skew'][tname]['rows_spilled']}")

    report["summary"] = {
        "partitions": parts,
        "group_agg_wall_speedup": report["queries"]["group_agg"][
            "wall_speedup_partitioned"],
        "join_agg_wall_speedup": report["queries"]["join_agg"][
            "wall_speedup_partitioned"],
        "distinct_agg_wall_speedup": report["queries"]["distinct_agg"][
            "wall_speedup_partitioned"],
        "skewed_hot_lane_rows": report["skew"]["skewed"]["hot_lane_rows"],
        "uniform_hot_lane_rows": report["skew"]["uniform"]["hot_lane_rows"],
        "skewed_rows_spilled": report["skew"]["skewed"]["rows_spilled"],
    }
    out_path = out_path or os.path.join(os.path.dirname(__file__),
                                        "BENCH_PR5.json")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("pr5.group_agg_wall_speedup",
         report["summary"]["group_agg_wall_speedup"])
    emit("pr5.join_agg_wall_speedup",
         report["summary"]["join_agg_wall_speedup"])
    skew_wh.close()
    wh.close()
    return report


def bench_pr6(scale=120_000, out_path=None, clients=(1, 8, 32, 128)):
    """Serving tier (PR 6): closed-loop concurrent clients over a mixed
    repeated/unique SSB workload, serving tier on vs off.

    Each cell runs N client threads in a closed loop (submit, wait, submit)
    against one shared warehouse; >=50% of statements are repeated dashboard
    queries (result-cache candidates), the rest are unique dimension-filter
    variants whose fact-scan vertex is identical across queries (shared-scan
    candidates).  Records p50/p99 latency, throughput, and the serving
    tier's hit-rate counters per cell.  Writes BENCH_PR6.json.
    """
    import threading

    import repro.api as db
    from benchmarks.ssb import SSB_QUERIES, load_ssb
    from repro.core.session import Warehouse

    wh = Warehouse(tempfile.mkdtemp(prefix="bench_pr6_"),
                   query_workers=32, llap_executors=8)
    load_ssb(wh, scale_rows=scale)

    # dashboard queries: repeated verbatim, so the serving result cache can
    # answer them without admission or execution
    repeated_pool = [SSB_QUERIES["q1.1"], SSB_QUERIES["q2.2"],
                     SSB_QUERIES["q3.1"]]

    def unique_sql(run_idx, cid, op):
        # filters live on non-join-key date_dim columns: every statement is
        # distinct (no result-cache absorption) but the lineorder scan
        # vertex key is identical, so overlapping executions attach to one
        # another's in-flight scans instead of re-reading the fact table
        ym = 199201 + ((cid * 5 + op) * 7) % 80
        wk = 10 + (run_idx * 9 + cid) % 43
        return (f"SELECT d_year, SUM(lo_revenue) AS rev"
                f" FROM lineorder, date_dim"
                f" WHERE lo_orderdate = d_datekey"
                f" AND d_yearmonthnum >= {ym} AND d_weeknum <= {wk}"
                f" GROUP BY d_year")

    # semijoin reduction injects fact-side runtime filters, which makes scan
    # vertices unshareable; disable it in BOTH modes so the comparison
    # isolates the serving tier
    common = {"semijoin_reduction": False}
    modes = {
        "serving_off": {**common, "serving.shared_scans": False,
                        "serving.result_cache": False},
        "serving_on": dict(common),
    }
    ops_per_client = 4
    repeated_fraction = 0.6
    runs_per_cell = 5

    def run_cell(n_clients, cfg, run_idx):
        barrier = threading.Barrier(n_clients + 1)
        lock = threading.Lock()
        latencies, errors = [], []

        def client(cid):
            try:
                c = db.connect(warehouse=wh, **cfg)
                r = np.random.default_rng(1000 * run_idx + cid)
                times = []
                barrier.wait()
                for op in range(ops_per_client):
                    if r.uniform() < repeated_fraction:
                        sql = repeated_pool[int(r.integers(
                            len(repeated_pool)))]
                    else:
                        sql = unique_sql(run_idx, cid, op)
                    t0 = time.perf_counter()
                    h = c.execute_async(sql)
                    h.result(600)
                    times.append(time.perf_counter() - t0)
                with lock:
                    latencies.extend(times)
                c.close()
            except Exception as exc:  # noqa: BLE001 - surfaced below
                errors.append(exc)

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(n_clients)]
        for t in threads:
            t.start()
        barrier.wait()  # clients connected and seeded; start the clock
        t0 = time.perf_counter()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        if errors:
            raise errors[0]
        return {"wall_s": wall, "latencies": latencies}

    # global warm-up: LLAP cache + plan cache (both modes measure warm I/O)
    warm = db.connect(warehouse=wh, **modes["serving_off"])
    for sql in repeated_pool + [unique_sql(0, 0, 0)]:
        warm.execute(sql)
    warm.close()

    report = {
        "scale_rows": scale,
        "workload": {"clients": list(clients),
                     "ops_per_client": ops_per_client,
                     "repeated_fraction": repeated_fraction,
                     "repeated_queries": ["q1.1", "q2.2", "q3.1"]},
        "timing": {"runs_per_cell": runs_per_cell,
                   "reduction": "min-wall (throughput from best run;"
                                " latencies pooled across runs)"},
        "cells": {},
    }
    for n in clients:
        for mode, cfg in modes.items():
            # each cell starts with a cold serving tier; steady-state runs
            # (what min-wall picks) then serve repeats from the cache
            wh.result_cache.invalidate_all()
            wh.shared_scans.invalidate_all()
            before = wh.serving_stats()
            runs = [run_cell(n, cfg, i) for i in range(runs_per_cell)]
            after = wh.serving_stats()
            best = min(runs, key=lambda r: r["wall_s"])
            pooled = np.array(sorted(x for r in runs
                                     for x in r["latencies"]))
            ops = n * ops_per_client
            rc = {k: after["result_cache"][k] - before["result_cache"][k]
                  for k in ("hits", "misses", "pending_waits")}
            ss = {k: after["shared_scans"][k] - before["shared_scans"][k]
                  for k in ("published", "attached", "attach_misses",
                            "fallbacks")}
            cell = {
                "throughput_qps": round(ops / best["wall_s"], 3),
                "wall_s": round(best["wall_s"], 4),
                "p50_ms": round(float(np.percentile(pooled, 50)) * 1e3, 3),
                "p99_ms": round(float(np.percentile(pooled, 99)) * 1e3, 3),
                "ops_per_run": ops,
                "result_cache": rc,
                "result_cache_hit_rate": round(
                    rc["hits"] / max(rc["hits"] + rc["misses"], 1), 4),
                "shared_scans": ss,
                "shared_scan_attach_rate": round(
                    ss["attached"] / max(ss["attached"]
                                         + ss["attach_misses"], 1), 4),
            }
            report["cells"][f"{mode}.n{n}"] = cell
            emit(f"pr6.{mode}.n{n}", cell["p50_ms"] * 1e3,
                 f"qps={cell['throughput_qps']},p99_ms={cell['p99_ms']},"
                 f"rc_hit={cell['result_cache_hit_rate']},"
                 f"scan_attach={cell['shared_scan_attach_rate']}")

    headline_n = 32 if 32 in clients else max(clients)
    on = report["cells"][f"serving_on.n{headline_n}"]
    off = report["cells"][f"serving_off.n{headline_n}"]
    report["summary"] = {
        "headline_clients": headline_n,
        "throughput_speedup_serving": round(
            on["throughput_qps"] / max(off["throughput_qps"], 1e-9), 3),
        "p99_speedup_serving": round(
            off["p99_ms"] / max(on["p99_ms"], 1e-3), 3),
        "result_cache_hit_rate": on["result_cache_hit_rate"],
        "shared_scan_attach_rate": on["shared_scan_attach_rate"],
        "acceptance_threshold_throughput_speedup": 1.5,
    }
    out_path = out_path or os.path.join(os.path.dirname(__file__),
                                        "BENCH_PR6.json")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("pr6.throughput_speedup_serving",
         report["summary"]["throughput_speedup_serving"])
    wh.close()
    return report


def bench_pr8(scale=400_000, out_path=None):
    """Adaptive execution (PR 8): live-telemetry replanning — adaptive on
    vs off over a zipf-skewed join/aggregation workload (hot-lane split,
    co-partition shuffle elision, payoff-gated fan-out), plus a
    no-regression check on the uniform SSB flight representatives Q1-Q4.
    Writes BENCH_PR8.json.
    """
    import repro.api as db
    from benchmarks.ssb import (SKEWED_QUERIES, SSB_QUERIES, load_skewed,
                                load_ssb)
    from repro.core.runtime.shuffle import auto_partition_cap
    from repro.core.session import Warehouse

    parts = auto_partition_cap()
    # a bounded per-edge buffer (default-sized memory budget / 4) makes the
    # exchange hop a real cost: the off-mode's extra aggregate shuffle
    # spills what the elided plan never materializes
    common = {"shuffle.partitions": "auto", "result_cache": False,
              "broadcast_threshold_rows": 0.0,
              "exchange.buffer_rows": 16384}
    modes = {
        "adaptive_on": {},
        "adaptive_off": {"adaptive.enabled": False,
                         "adaptive.elide_copartition": False},
    }

    def measure(conn, sql, reps=5):
        """min-of-``reps`` wall (after one warmup run) + the best run's
        adaptive event kinds and the (sorted) rowset for parity checks."""
        _pr3_measure(conn, sql)  # warm LLAP (paper reports warm cache)
        best = None
        for _ in range(reps):
            t0 = time.perf_counter()
            h = conn.execute_async(sql)
            rows = []
            for batch in h.fetch_stream(batch_rows=1024):
                rows.extend(batch)
            h.result(600)
            wall = time.perf_counter() - t0
            if best is None or wall < best["wall_s"]:
                events = h.poll().get("adaptive") or []
                best = {"wall_s": wall, "rows": rows,
                        "adaptive_events": [e["kind"] for e in events]}
        return best

    report = {
        "scale_rows": scale,
        "config": {"partitions": parts, "zipf_alpha": 2.5, **common},
        "timing": {"runs_per_cell": 5, "reduction": "min",
                   "warmup_runs": 1},
        "skewed": {},
        "uniform_ssb": {},
    }

    # ---- zipf-skewed join/agg workload: adaptive on vs off ---------------
    wh = Warehouse(tempfile.mkdtemp(prefix="bench_pr8_"),
                   llap_executors=max(8, 4 * parts))
    load_skewed(wh, scale_rows=scale, alpha=2.5)
    totals = {m: 0.0 for m in modes}
    for name, sql in SKEWED_QUERIES.items():
        cell = {}
        for mode, overrides in modes.items():
            conn = db.connect(warehouse=wh, **common, **overrides)
            best = measure(conn, sql)
            conn.close()
            totals[mode] += best["wall_s"]
            cell[mode] = {"wall_ms": round(best["wall_s"] * 1e3, 3),
                          "rows": len(best["rows"]),
                          "adaptive_events": best["adaptive_events"]}
            emit(f"pr8.{name}.{mode}", best["wall_s"] * 1e6,
                 f"rows={len(best['rows'])},"
                 f"events={'+'.join(best['adaptive_events']) or 'none'}")
            cell[mode]["_rowset"] = best["rows"]
        assert _rounded(cell["adaptive_on"].pop("_rowset")) == \
            _rounded(cell["adaptive_off"].pop("_rowset")), \
            f"adaptive parity broken on {name}"
        cell["wall_speedup_adaptive"] = round(
            cell["adaptive_off"]["wall_ms"]
            / max(cell["adaptive_on"]["wall_ms"], 1e-3), 3)
        report["skewed"][name] = cell
    wh.close()

    # ---- uniform SSB Q1-Q4: adaptive must not regress --------------------
    # half the skewed scale, and more reps per cell: these queries are an
    # order of magnitude shorter, so the min needs more samples to converge
    uni_scale = max(scale // 2, 4_000)
    wh = Warehouse(tempfile.mkdtemp(prefix="bench_pr8_ssb_"),
                   llap_executors=max(8, 4 * parts))
    load_ssb(wh, scale_rows=uni_scale)
    report["uniform_ssb"]["scale_rows"] = uni_scale
    uni_speedups = []
    for name in ("q1.1", "q2.1", "q3.1", "q4.1"):
        cell = {}
        for mode, overrides in modes.items():
            conn = db.connect(warehouse=wh, **common, **overrides)
            best = measure(conn, SSB_QUERIES[name], reps=9)
            conn.close()
            cell[mode] = {"wall_ms": round(best["wall_s"] * 1e3, 3),
                          "rows": len(best["rows"]),
                          "adaptive_events": best["adaptive_events"]}
            cell[mode]["_rowset"] = best["rows"]
        assert _rounded(cell["adaptive_on"].pop("_rowset")) == \
            _rounded(cell["adaptive_off"].pop("_rowset")), \
            f"adaptive parity broken on uniform {name}"
        cell["wall_speedup_adaptive"] = round(
            cell["adaptive_off"]["wall_ms"]
            / max(cell["adaptive_on"]["wall_ms"], 1e-3), 3)
        uni_speedups.append(cell["wall_speedup_adaptive"])
        emit(f"pr8.ssb_{name}.speedup", cell["wall_speedup_adaptive"] * 1e3)
        report["uniform_ssb"][name] = cell
    wh.close()

    report["summary"] = {
        "partitions": parts,
        "skewed_total_wall_ms_adaptive_on": round(
            totals["adaptive_on"] * 1e3, 3),
        "skewed_total_wall_ms_adaptive_off": round(
            totals["adaptive_off"] * 1e3, 3),
        "skewed_total_speedup_adaptive": round(
            totals["adaptive_off"] / max(totals["adaptive_on"], 1e-6), 3),
        "per_query_speedup": {
            n: c["wall_speedup_adaptive"]
            for n, c in report["skewed"].items()},
        "uniform_ssb_min_speedup": min(uni_speedups),
        "adaptive_events_observed": sorted({
            k for c in report["skewed"].values()
            for k in c["adaptive_on"]["adaptive_events"]}),
    }
    out_path = out_path or os.path.join(os.path.dirname(__file__),
                                        "BENCH_PR8.json")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("pr8.skewed_total_speedup_adaptive",
         report["summary"]["skewed_total_speedup_adaptive"] * 1e3)
    emit("pr8.uniform_ssb_min_speedup",
         report["summary"]["uniform_ssb_min_speedup"] * 1e3)
    return report


def bench_pr10(scale=120_000, out_path=None):
    """Observability (PR 10): what does tracing cost?

    Runs SSB representatives Q1-Q4 with ``obs.tracing`` off and on
    (min-of-5 wall after one warmup each), reports the per-query and
    total deltas, and embeds each traced query's ``trace_summary``
    (stage spans + per-vertex compute / exchange-wait / spill-I/O
    breakdown) as the proof the trace actually covered the execution.
    Writes BENCH_PR10.json.
    """
    import repro.api as db
    from benchmarks.ssb import SSB_QUERIES, load_ssb
    from repro.core.session import Warehouse

    wh = Warehouse(tempfile.mkdtemp(prefix="bench_pr10_"))
    load_ssb(wh, scale_rows=scale)
    queries = ("q1.1", "q2.1", "q3.1", "q4.1")
    modes = {"tracing_off": {}, "tracing_on": {"obs.tracing": True}}
    common = {"result_cache": False}

    def measure(conn, sql, reps=5):
        """min-of-``reps`` wall after one warmup; keeps the best run's
        handle so the traced mode can attach its trace summary."""
        _pr3_measure(conn, sql)  # warm LLAP (paper reports warm cache)
        best = None
        for _ in range(reps):
            t0 = time.perf_counter()
            h = conn.execute_async(sql)
            n = sum(len(b) for b in h.fetch_stream(batch_rows=1024))
            h.result(600)
            wall = time.perf_counter() - t0
            if best is None or wall < best["wall_s"]:
                best = {"wall_s": wall, "rows": n, "handle": h}
        return best

    report = {
        "scale_rows": scale,
        "config": dict(common),
        "timing": {"runs_per_cell": 5, "reduction": "min",
                   "warmup_runs": 1},
        "queries": {},
    }
    totals = {m: 0.0 for m in modes}
    for name in queries:
        cell = {}
        for mode, overrides in modes.items():
            conn = db.connect(warehouse=wh, **common, **overrides)
            best = measure(conn, SSB_QUERIES[name])
            conn.close()
            totals[mode] += best["wall_s"]
            cell[mode] = {"wall_ms": round(best["wall_s"] * 1e3, 3),
                          "rows": best["rows"]}
            if mode == "tracing_on":
                # the trace is the evidence: stage spans + vertex split
                summ = best["handle"]._task.trace.summary()
                cell[mode]["trace_summary"] = {
                    "stages_ms": summ["stages_ms"],
                    "vertices": summ["vertices"],
                    "n_events": len(summ["events"]),
                }
        cell["tracing_overhead_pct"] = round(
            100.0 * (cell["tracing_on"]["wall_ms"]
                     - cell["tracing_off"]["wall_ms"])
            / max(cell["tracing_off"]["wall_ms"], 1e-3), 2)
        emit(f"pr10.{name}.tracing_off",
             cell["tracing_off"]["wall_ms"] * 1e3)
        emit(f"pr10.{name}.tracing_on",
             cell["tracing_on"]["wall_ms"] * 1e3,
             f"overhead={cell['tracing_overhead_pct']}%")
        report["queries"][name] = cell
    wh.close()

    report["summary"] = {
        "total_wall_ms_tracing_off": round(totals["tracing_off"] * 1e3, 3),
        "total_wall_ms_tracing_on": round(totals["tracing_on"] * 1e3, 3),
        "total_tracing_overhead_pct": round(
            100.0 * (totals["tracing_on"] - totals["tracing_off"])
            / max(totals["tracing_off"], 1e-6), 2),
        "per_query_overhead_pct": {
            n: c["tracing_overhead_pct"]
            for n, c in report["queries"].items()},
    }
    out_path = out_path or os.path.join(os.path.dirname(__file__),
                                        "BENCH_PR10.json")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("pr10.total_tracing_overhead_pct",
         report["summary"]["total_tracing_overhead_pct"] * 1e3)
    return report


def roofline_summary():
    d = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
    if not os.path.isdir(d):
        print("roofline_summary: run `python -m repro.launch.dryrun --all"
              " --both-meshes` first")
        return
    for name in sorted(os.listdir(d)):
        if not name.endswith(".json") or "debug" in name:
            continue
        with open(os.path.join(d, name)) as f:
            c = json.load(f)
        rf = c["roofline"]
        dom = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        frac = rf["compute_s"] / dom if dom else 0.0
        emit(
            f"roofline.{c['arch']}.{c['shape']}.{c['mesh']}",
            dom * 1e6,
            f"bound={rf['bottleneck']},compute_s={rf['compute_s']:.4f},"
            f"memory_s={rf['memory_s']:.4f},collective_s={rf['collective_s']:.4f},"
            f"roofline_frac={frac:.3f},MF/HF={rf['flops_ratio']:.3f}",
        )


def main() -> None:
    print("name,us_per_call,derived")
    v1v3 = fig7_version_evolution()
    llap = table1_llap()
    fed = fig8_federation()
    acid = acid_at_par()
    sw = q88_shared_work()
    kernel_micro()
    bench_pr3()
    bench_pr4()
    bench_pr5()
    bench_pr6()
    bench_pr8()
    bench_pr10()
    roofline_summary()
    print()
    print(f"# paper-claims summary: v3-vs-v1 speedup {v1v3:.2f}x (paper: 4.6x avg),"
          f" LLAP {llap:.2f}x (paper: 2.7x), federation {fed:.2f}x (paper: 1.6x),"
          f" ACID merge-on-read overhead {acid:.2f}x (paper: ~at par post-compaction),"
          f" shared-work {sw:.2f}x (paper q88: 2.7x)")


if __name__ == "__main__":
    import argparse

    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("section", nargs="?", default="all",
                        choices=["all", "pr3", "pr4", "pr5", "pr6", "pr8",
                                 "pr10"])
    parser.add_argument("--scale", type=int, default=None,
                        help="row scale (pr3/pr5: SSB lineorder,"
                             " pr4: external); per-section default if unset")
    parser.add_argument("--out", default=None,
                        help="BENCH_PRn.json output path (pr3-pr5 sections)")
    args = parser.parse_args()
    if args.section == "pr3":
        print("name,us_per_call,derived")
        bench_pr3(scale=args.scale or 60_000, out_path=args.out)
    elif args.section == "pr4":
        print("name,us_per_call,derived")
        bench_pr4(scale=args.scale or 60_000, out_path=args.out)
    elif args.section == "pr5":
        print("name,us_per_call,derived")
        bench_pr5(scale=args.scale or 240_000, out_path=args.out)
    elif args.section == "pr6":
        print("name,us_per_call,derived")
        bench_pr6(scale=args.scale or 120_000, out_path=args.out)
    elif args.section == "pr8":
        print("name,us_per_call,derived")
        bench_pr8(scale=args.scale or 400_000, out_path=args.out)
    elif args.section == "pr10":
        print("name,us_per_call,derived")
        bench_pr10(scale=args.scale or 120_000, out_path=args.out)
    else:
        main()
