"""Schema-contract smoke: the static checker must be clean on real plans.

Compiles every SSB query and every skewed adaptive-benchmark query at small
scale and asserts the schema-flow checker (SCH001..SCH006) reports zero
findings on both the optimized plan and the compiled task DAG.  Any finding
here is either genuine dtype drift in the engine or a checker false
positive — both block the merge.

Run: ``PYTHONPATH=src python -m benchmarks.schema_smoke``
"""
import sys
import tempfile

from benchmarks.ssb import SKEWED_QUERIES, SSB_QUERIES, load_skewed, load_ssb


def main() -> int:
    from repro.analysis.schema_check import (validate_dag_schemas,
                                             validate_plan_schema)
    from repro.core.runtime.dag import compile_dag
    from repro.core.session import Warehouse

    failures = []
    suites = [
        ("ssb", load_ssb, SSB_QUERIES, dict(scale_rows=2000)),
        ("skewed", load_skewed, SKEWED_QUERIES,
         dict(scale_rows=4000, n_keys=16)),
    ]
    for name, loader, queries, kwargs in suites:
        wh = Warehouse(tempfile.mkdtemp(prefix=f"schema_smoke_{name}_"))
        loader(wh, **kwargs)
        s = wh.session()
        for qid, sql in queries.items():
            from repro.core.sql.parser import parse

            plan, _info = s._plan_query(parse(sql))
            for finding in validate_plan_schema(plan):
                failures.append(f"{name}/{qid} (plan): {finding}")
            expanded = s._expand_for_compile(plan)
            for finding in validate_dag_schemas(compile_dag(expanded)):
                failures.append(f"{name}/{qid} (dag): {finding}")
            print(f"ok {name}/{qid}")
    for f in failures:
        print(f"FINDING {f}", file=sys.stderr)
    n = sum(len(q) for _, _, q, _ in suites)
    print(f"schema_smoke: {n} queries, {len(failures)} finding(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
