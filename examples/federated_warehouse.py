"""Federation: one SQL layer over Druid-style OLAP + a JDBC RDBMS (paper §6).

Registers external tables backed by both engines, shows Calcite-style
computation pushdown (Druid JSON / generated SQL), and a cross-engine join.

Run:  PYTHONPATH=src python examples/federated_warehouse.py
"""
import tempfile

import numpy as np

from repro.core.runtime.vector import VectorBatch
from repro.core.session import Warehouse


def main():
    wh = Warehouse(tempfile.mkdtemp(prefix="tahoe_fed_"))
    s = wh.session()
    rng = np.random.default_rng(1)

    # -- a Druid datasource with event data (paper Figure 6)
    druid = wh.handlers.get("druid")
    druid.store.create_datasource("events", VectorBatch({
        "__time": np.array([f"2017-{1 + i % 12:02d}-01" for i in range(5000)]),
        "d1": np.array([f"user_{i % 9}" for i in range(5000)]),
        "m1": rng.uniform(0, 10, 5000),
    }))
    s.execute("""CREATE EXTERNAL TABLE druid_table_1
        STORED BY 'org.apache.hadoop.hive.druid.DruidStorageHandler'
        TBLPROPERTIES ('druid.datasource' = 'events')""")
    print("schema inferred from Druid:",
          wh.hms.get_table("druid_table_1").schema)

    r = s.execute("""SELECT d1, SUM(m1) AS st FROM druid_table_1
                     GROUP BY d1 ORDER BY st DESC LIMIT 5""")
    print("\npushed:", r.info.get("federated_pushdown"))
    print("druid JSON:", druid.store.queries_served[-1])
    for row in r.rows:
        print("  ", row)

    # -- a JDBC engine (embedded sqlite) with reference data
    jdbc = wh.handlers.get("jdbc")
    jdbc.load_table("users", VectorBatch({
        "uid": np.array([f"user_{i}" for i in range(9)]),
        "segment": np.array(["free", "pro", "enterprise"])[np.arange(9) % 3],
    }))
    s.execute("""CREATE EXTERNAL TABLE users STORED BY 'jdbc'
        TBLPROPERTIES ('jdbc.table'='users')""")
    r = s.execute("SELECT segment, COUNT(*) c FROM users GROUP BY segment")
    print("\nJDBC pushdown SQL:", jdbc.queries_served[-1])

    # -- cross-engine join, mediated by the warehouse (paper §6 'mediator')
    r = s.execute("""SELECT segment, SUM(m1) AS usage_sum
                     FROM druid_table_1, users
                     WHERE d1 = uid GROUP BY segment ORDER BY usage_sum DESC""")
    print("\ncross-engine join (Druid x sqlite):")
    for row in r.rows:
        print("  ", row)

    # -- write back to Druid (output format, §6.1)
    s.execute("CREATE EXTERNAL TABLE rollup_out (seg STRING, total DOUBLE)"
              " STORED BY 'druid'")
    s.execute("INSERT INTO rollup_out SELECT segment, SUM(m1) FROM"
              " druid_table_1, users WHERE d1 = uid GROUP BY segment")
    print("\nwrote rollup into Druid:",
          s.execute("SELECT COUNT(*) FROM rollup_out").rows)


if __name__ == "__main__":
    main()
