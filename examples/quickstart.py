"""Quickstart: the warehouse in 60 seconds, through the client API.

Connects via the DB-API-style front-end (``repro.api``), creates a
partitioned ACID table, runs optimized analytic queries with ``?``
parameters, pages results with a cursor, reuses a prepared statement's
cached plan, shows the results cache, a materialized-view rewrite, DML with
snapshot isolation, and EXPLAIN ANALYZE with per-stage pipeline timings.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import numpy as np

import repro.api as db


def main():
    conn = db.connect(tempfile.mkdtemp(prefix="tahoe_quickstart_"))
    cur = conn.cursor()

    print("== DDL: partitioned fact table + dimension (paper §3.1) ==")
    cur.execute("""CREATE TABLE store_sales (
        ss_item_sk INT, ss_qty INT, ss_price DECIMAL(7,2), ss_sold_date_sk INT
    ) PARTITIONED BY (ss_sold_date_sk INT)""")
    cur.execute("CREATE TABLE item (i_item_sk INT, i_category STRING)")

    rng = np.random.default_rng(0)
    rows = ", ".join(
        f"({rng.integers(0, 30)}, {rng.integers(1, 9)},"
        f" {rng.uniform(1, 50):.2f}, {d})"
        for d in range(8) for _ in range(500))
    cur.execute(f"INSERT INTO store_sales VALUES {rows}")
    cur.executemany("INSERT INTO item VALUES (?, ?)",
                    [(i, ["Sports", "Books", "Home"][i % 3])
                     for i in range(30)])
    hms = conn.warehouse.hms
    print(f"partitions on disk: {len(hms.list_partitions('store_sales'))}")

    q = """SELECT i_category, SUM(ss_price * ss_qty) AS rev
           FROM store_sales, item
           WHERE ss_item_sk = i_item_sk AND ss_sold_date_sk BETWEEN ? AND ?
           GROUP BY i_category ORDER BY rev DESC"""
    print("\n== parameterized query (CBO + semijoin reduction + LLAP) ==")
    cur.execute(q, (2, 5))
    print("description:", [d[:2] for d in cur.description])
    for row in cur:
        print("  ", row)
    print("info:", {k: cur.info[k] for k in
                    ("semijoin_reducers", "dag_edges", "cache_hit")})

    cur.execute(q, (2, 5))
    print(f"second run: cache_hit={cur.info['cache_hit']} "
          f"plan_cache_hit={cur.info.get('plan_cache_hit')}")

    print("\n== prepared statement: plan bound+optimized once ==")
    ps = conn.prepare("""SELECT ss_sold_date_sk, COUNT(*) AS n
                         FROM store_sales WHERE ss_qty >= ?
                         GROUP BY ss_sold_date_sk ORDER BY ss_sold_date_sk""")
    for qty in (7, 8):
        c = ps.execute((qty,))
        page = c.fetchmany(3)  # cursor pages through the result
        print(f"  qty>={qty}: first page {page} "
              f"(plan_cache_hit={c.info.get('plan_cache_hit')})")

    print("\n== materialized view rewrite (paper §4.4) ==")
    cur.execute("""CREATE MATERIALIZED VIEW daily_rev AS
        SELECT ss_sold_date_sk, i_category, SUM(ss_price) AS s
        FROM store_sales, item WHERE ss_item_sk = i_item_sk
        GROUP BY ss_sold_date_sk, i_category""")
    cur.execute("""SELECT i_category, SUM(ss_price) FROM store_sales, item
                   WHERE ss_item_sk = i_item_sk GROUP BY i_category""")
    print(f"rewritten against MV: {cur.info.get('mv_used')}"
          f" (mode={cur.info.get('mv_mode')})")

    print("\n== ACID DML with snapshot isolation (paper §3.2) ==")
    cur.execute("UPDATE item SET i_category = 'Clearance' WHERE i_item_sk < ?",
                (3,))
    print("updated rows:", cur.rowcount)
    cur.execute("DELETE FROM store_sales WHERE ss_qty = ?", (1,))
    print("deleted rows:", cur.rowcount)
    cur.execute("ALTER MATERIALIZED VIEW daily_rev REBUILD")
    print("MV rebuild after delete:", cur.info)
    cur.execute("SELECT COUNT(*) FROM store_sales")
    print("row count:", cur.fetchone()[0])

    print("\n== EXPLAIN ANALYZE: per-stage pipeline timings ==")
    cur.execute("EXPLAIN ANALYZE " + q.replace("?", "3", 1).replace("?", "6"))
    for (line,) in cur.fetchall():
        print(line)

    conn.close()


if __name__ == "__main__":
    main()
