"""Quickstart: the warehouse in 60 seconds.

Creates a partitioned ACID table, runs optimized analytic queries, shows the
results cache, a materialized-view rewrite, and DML with snapshot isolation.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import numpy as np

from repro.core.session import Warehouse


def main():
    wh = Warehouse(tempfile.mkdtemp(prefix="tahoe_quickstart_"))
    s = wh.session()

    print("== DDL: partitioned fact table + dimension (paper §3.1) ==")
    s.execute("""CREATE TABLE store_sales (
        ss_item_sk INT, ss_qty INT, ss_price DECIMAL(7,2), ss_sold_date_sk INT
    ) PARTITIONED BY (ss_sold_date_sk INT)""")
    s.execute("CREATE TABLE item (i_item_sk INT, i_category STRING)")

    rng = np.random.default_rng(0)
    rows = ", ".join(
        f"({rng.integers(0, 30)}, {rng.integers(1, 9)},"
        f" {rng.uniform(1, 50):.2f}, {d})"
        for d in range(8) for _ in range(500))
    s.execute(f"INSERT INTO store_sales VALUES {rows}")
    s.execute("INSERT INTO item VALUES " + ", ".join(
        f"({i}, '{['Sports', 'Books', 'Home'][i % 3]}')" for i in range(30)))
    print(f"partitions on disk: {len(wh.hms.list_partitions('store_sales'))}")

    q = """SELECT i_category, SUM(ss_price * ss_qty) AS rev
           FROM store_sales, item
           WHERE ss_item_sk = i_item_sk AND ss_sold_date_sk BETWEEN 2 AND 5
           GROUP BY i_category ORDER BY rev DESC"""
    print("\n== optimized query (CBO + semijoin reduction + LLAP) ==")
    r = s.execute(q)
    for row in r.rows:
        print("  ", row)
    print("info:", {k: r.info[k] for k in
                    ("semijoin_reducers", "dag_edges", "cache_hit")})

    r2 = s.execute(q)
    print(f"second run: cache_hit={r2.info['cache_hit']} "
          f"({r2.info['seconds'] * 1e3:.1f} ms)")

    print("\n== materialized view rewrite (paper §4.4) ==")
    s.execute("""CREATE MATERIALIZED VIEW daily_rev AS
        SELECT ss_sold_date_sk, i_category, SUM(ss_price) AS s
        FROM store_sales, item WHERE ss_item_sk = i_item_sk
        GROUP BY ss_sold_date_sk, i_category""")
    r3 = s.execute("""SELECT i_category, SUM(ss_price) FROM store_sales, item
                      WHERE ss_item_sk = i_item_sk GROUP BY i_category""")
    print(f"rewritten against MV: {r3.info.get('mv_used')}"
          f" (mode={r3.info.get('mv_mode')})")

    print("\n== ACID DML with snapshot isolation (paper §3.2) ==")
    s.execute("UPDATE item SET i_category = 'Clearance' WHERE i_item_sk < 3")
    s.execute("DELETE FROM store_sales WHERE ss_qty = 1")
    r4 = s.execute("ALTER MATERIALIZED VIEW daily_rev REBUILD")
    print("MV rebuild after delete:", r4.info)
    print("row count:",
          s.execute("SELECT COUNT(*) FROM store_sales").rows[0][0])

    print("\n== EXPLAIN ==")
    print(s.explain(q))


if __name__ == "__main__":
    main()
