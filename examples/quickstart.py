"""Quickstart: the warehouse in 60 seconds, through the client API.

Connects via the DB-API-style front-end (``repro.api``), creates a
partitioned ACID table, runs optimized analytic queries with ``?``
parameters, pages results with a cursor, reuses a prepared statement's
cached plan, shows the results cache, a materialized-view rewrite, DML with
snapshot isolation, asynchronous query handles (``execute_async`` +
``fetch_stream`` behind workload-manager pools, paper §5.2), streaming
execution over spill-aware exchanges (``exchange.*`` session config),
federated catalogs (``CREATE CATALOG`` + three-part names with
capability-negotiated pushdown, paper §6), EXPLAIN ANALYZE with per-stage
pipeline timings, adaptive execution (live-telemetry replanning: hot-
lane splits, co-partition shuffle elision, payoff-gated fan-out), and the
observability layer (per-query tracing with Perfetto-renderable export,
the warehouse metrics registry, and the always-on query log).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import numpy as np

import repro.api as db


def main():
    conn = db.connect(tempfile.mkdtemp(prefix="tahoe_quickstart_"))
    cur = conn.cursor()

    print("== DDL: partitioned fact table + dimension (paper §3.1) ==")
    cur.execute("""CREATE TABLE store_sales (
        ss_item_sk INT, ss_qty INT, ss_price DECIMAL(7,2), ss_sold_date_sk INT
    ) PARTITIONED BY (ss_sold_date_sk INT)""")
    cur.execute("CREATE TABLE item (i_item_sk INT, i_category STRING)")

    rng = np.random.default_rng(0)
    rows = ", ".join(
        f"({rng.integers(0, 30)}, {rng.integers(1, 9)},"
        f" {rng.uniform(1, 50):.2f}, {d})"
        for d in range(8) for _ in range(500))
    cur.execute(f"INSERT INTO store_sales VALUES {rows}")
    cur.executemany("INSERT INTO item VALUES (?, ?)",
                    [(i, ["Sports", "Books", "Home"][i % 3])
                     for i in range(30)])
    hms = conn.warehouse.hms
    print(f"partitions on disk: {len(hms.list_partitions('store_sales'))}")

    q = """SELECT i_category, SUM(ss_price * ss_qty) AS rev
           FROM store_sales, item
           WHERE ss_item_sk = i_item_sk AND ss_sold_date_sk BETWEEN ? AND ?
           GROUP BY i_category ORDER BY rev DESC"""
    print("\n== parameterized query (CBO + semijoin reduction + LLAP) ==")
    cur.execute(q, (2, 5))
    print("description:", [d[:2] for d in cur.description])
    for row in cur:
        print("  ", row)
    print("info:", {k: cur.info[k] for k in
                    ("semijoin_reducers", "dag_edges", "cache_hit")})

    cur.execute(q, (2, 5))
    print(f"second run: cache_hit={cur.info['cache_hit']} "
          f"plan_cache_hit={cur.info.get('plan_cache_hit')}")

    print("\n== prepared statement: plan bound+optimized once ==")
    ps = conn.prepare("""SELECT ss_sold_date_sk, COUNT(*) AS n
                         FROM store_sales WHERE ss_qty >= ?
                         GROUP BY ss_sold_date_sk ORDER BY ss_sold_date_sk""")
    for qty in (7, 8):
        c = ps.execute((qty,))
        page = c.fetchmany(3)  # cursor pages through the result
        print(f"  qty>={qty}: first page {page} "
              f"(plan_cache_hit={c.info.get('plan_cache_hit')})")

    print("\n== materialized view rewrite (paper §4.4) ==")
    cur.execute("""CREATE MATERIALIZED VIEW daily_rev AS
        SELECT ss_sold_date_sk, i_category, SUM(ss_price) AS s
        FROM store_sales, item WHERE ss_item_sk = i_item_sk
        GROUP BY ss_sold_date_sk, i_category""")
    cur.execute("""SELECT i_category, SUM(ss_price) FROM store_sales, item
                   WHERE ss_item_sk = i_item_sk GROUP BY i_category""")
    print(f"rewritten against MV: {cur.info.get('mv_used')}"
          f" (mode={cur.info.get('mv_mode')})")

    print("\n== ACID DML with snapshot isolation (paper §3.2) ==")
    cur.execute("UPDATE item SET i_category = 'Clearance' WHERE i_item_sk < ?",
                (3,))
    print("updated rows:", cur.rowcount)
    cur.execute("DELETE FROM store_sales WHERE ss_qty = ?", (1,))
    print("deleted rows:", cur.rowcount)
    cur.execute("ALTER MATERIALIZED VIEW daily_rev REBUILD")
    print("MV rebuild after delete:", cur.info)
    cur.execute("SELECT COUNT(*) FROM store_sales")
    print("row count:", cur.fetchone()[0])

    print("\n== async handles: concurrent queries behind WLM pools (§5.2) ==")
    # a resource plan with two pools: interactive clients are admitted into
    # `bi` (one query at a time), everything else lands in `etl`
    for ddl in [
        "CREATE RESOURCE PLAN daytime",
        "CREATE POOL daytime.bi WITH alloc_fraction=0.7, query_parallelism=1",
        "CREATE POOL daytime.etl WITH alloc_fraction=0.3, query_parallelism=2",
        "CREATE APPLICATION MAPPING dashboard IN daytime TO bi",
        "ALTER PLAN daytime SET DEFAULT POOL = etl",
        "ALTER RESOURCE PLAN daytime ENABLE ACTIVATE",
    ]:
        cur.execute(ddl)
    dash = db.connect(warehouse=conn.warehouse, application="dashboard",
                      result_cache=False)
    # submit without blocking; both handles run on the warehouse scheduler
    h1 = dash.execute_async(
        "SELECT i_category, SUM(ss_price * ss_qty) AS rev "
        "FROM store_sales, item WHERE ss_item_sk = i_item_sk "
        "GROUP BY i_category ORDER BY rev DESC")
    h2 = dash.execute_async("SELECT COUNT(*) FROM store_sales")
    print(f"submitted {h1.query_id} and {h2.query_id} without blocking "
          f"(states: h1={h1.state}, h2={h2.state}; pool bi admits one "
          f"query at a time — with bi full, h2 borrows idle etl capacity; "
          f"once every pool is busy, further handles queue as QUEUED)")
    # stream row batches as the engine produces them; on slow queries the
    # consumer sees batches while the handle is still RUNNING
    for batch in h1.fetch_stream(batch_rows=2):
        print(f"  streamed {len(batch)} row(s) (h1: {h1.state}): {batch}")
    p = h1.poll()
    print(f"h1 finished: pool={p['pool']} vertices="
          f"{p['vertices_done']}/{p['vertices_total']} "
          f"queue_wait_ms={p['queue_wait_ms']}")
    print("h2 result:", h2.result(timeout=30).fetchone()[0],
          f"(state={h2.state})")
    # handles are cancellable while queued or running (cooperative,
    # observed at DAG vertex boundaries); killed/cancelled queries raise
    # QueryKilledError / QueryCancelledError from result().  The demo slows
    # each vertex so the cancel lands before the last cancellation point.
    slow = db.connect(warehouse=conn.warehouse, application="dashboard",
                      debug_vertex_delay_s=0.3, result_cache=False)
    h3 = slow.execute_async("SELECT ss_customer_sk, SUM(ss_price) "
                            "FROM store_sales GROUP BY ss_customer_sk")
    h3.cancel()
    try:
        h3.result(timeout=30)
        print(f"h3 outran the cancel request (state={h3.state})")
    except db.QueryCancelledError:
        print(f"h3 cancelled cleanly (state={h3.state})")
    slow.close()
    dash.close()

    print("\n== streaming execution + spill-aware exchanges (§5) ==")
    # Operators stream `exchange.batch_rows`-row morsels end-to-end: scans,
    # filters and projects pipeline chunk-by-chunk, pipeline breakers (join
    # builds, grouped aggregation, sort) keep incremental-merge state, and
    # each DAG edge buffers at most `exchange.buffer_rows` rows /
    # `exchange.buffer_bytes` bytes in memory — overflow morsels spill to a
    # per-query scratch directory and replay downstream, so a constrained
    # budget changes peak memory, never results.  fetch_stream() therefore
    # yields first rows while upstream vertices are still running.
    tight = db.connect(warehouse=conn.warehouse, result_cache=False,
                       **{"exchange.batch_rows": 256,
                          "exchange.buffer_rows": 512,
                          "exchange.spill": True})
    ht = tight.execute_async(
        "SELECT ss_item_sk, ss_price FROM store_sales WHERE ss_qty >= 2")
    first = next(iter(ht.fetch_stream(batch_rows=256)))
    print(f"first {len(first)} rows arrived while state={ht.state}")
    ht.result(30)
    pt = ht.poll()
    print(f"spilled under the tight budget: rows={pt['rows_spilled']} "
          f"bytes={pt['bytes_spilled']} per-vertex={pt['spill']} "
          f"(peak in-memory rows bounded at {pt['peak_buffered_rows']})")
    # with `exchange.spill: False` the same overflow raises
    # MemoryPressureError and feeds the §4.2 re-optimization path instead
    tight.close()

    print("\n== partitioned shuffle service (§4/§5 MPP parallelism) ==")
    # SHUFFLE edges hash-partition the producer stream into per-consumer
    # lanes: pipeline-breaker consumers (shuffle joins, grouped aggregation,
    # DISTINCT) clone once per partition, each clone owns its lane's
    # build/probe/aggregation state, and the clones merge back through a
    # UNION (or a merging fold for global DISTINCT partials).  The default
    # `shuffle.partitions: auto` derives the lane count from CBO row
    # estimates (small inputs stay single-lane); an int forces it.
    part = db.connect(warehouse=conn.warehouse, result_cache=False,
                      **{"shuffle.partitions": 2})
    hp = part.execute_async(
        "SELECT i_category, COUNT(DISTINCT ss_item_sk) AS items, "
        "SUM(ss_price) AS rev FROM store_sales, item "
        "WHERE ss_item_sk = i_item_sk GROUP BY i_category")
    print("partitioned result:", hp.result(30).fetchall())
    # per-lane rows/bytes/spill are visible while (and after) running, so
    # key skew shows up as one hot lane instead of a mystery slowdown
    lanes = hp.poll()["lanes"]
    for vid, per_lane in lanes.items():
        rows = [l["rows"] for l in per_lane]
        spill = sum(l["spilled_rows"] for l in per_lane)
        print(f"  edge {vid}: lane rows={rows} spilled={spill}"
          f" (skew = max/min imbalance)")
    # EXPLAIN annotates every exchange boundary with its movement kind and
    # lane count (pushed-vs-residual style)
    s_part = conn.warehouse.session(result_cache=False,
                                    **{"shuffle.partitions": 2})
    for line in s_part.explain(
            "SELECT i_category, SUM(ss_price) FROM store_sales, item"
            " WHERE ss_item_sk = i_item_sk GROUP BY i_category").split("\n"):
        if "partitions=" in line or line.startswith("exchanges"):
            print(" ", line.strip())
    part.close()

    print("\n== federated catalogs (paper §6) ==")
    # CREATE CATALOG mounts a whole external system at once: tables are
    # addressed with three-part names (catalog.schema.table) and their
    # remote schemas are discovered lazily — no per-table STORED BY DDL
    # (which still works, on the same connector API).
    cur.execute("CREATE CATALOG crm USING jdbc")
    cur.execute("CREATE CATALOG events USING memtable"
                " WITH (latency_s = '0.001', batch_rows = '256')")
    print("mounted catalogs:", conn.catalogs())
    # load data directly into the external engines (out-of-band)
    from repro.core.runtime.vector import VectorBatch

    crm = conn.warehouse.catalogs.get("crm").handler
    crm.load_table("accounts", VectorBatch({
        "item_sk": np.arange(30),
        "owner": np.array([f"acct_{i % 6}" for i in range(30)]),
    }))
    ev = conn.warehouse.catalogs.get("events").handler
    ev.load("clicks", [{"item_sk": int(i % 30), "n": int(1 + i % 4)}
                       for i in range(5000)])
    # pushdown is negotiated capability-by-capability; whatever a connector
    # declines runs locally as residual operators (here the parameterized
    # predicate stays a local residual — plans are parameter-generic, so
    # `?`-bound conjuncts never bake into a connector query), and EXPLAIN
    # shows pushed vs residual on the scan node
    cur.execute("""SELECT owner, SUM(n) AS clicks
                   FROM events.default.clicks c, crm.main.accounts a
                   WHERE c.item_sk = a.item_sk AND c.item_sk < ?
                   GROUP BY owner ORDER BY clicks DESC""", (20,))
    for row in cur.fetchall():
        print("  ", row)
    print("pushed vs residual:", cur.info.get("federated_pushdown"))
    cur.execute("SELECT item_sk, n FROM events.default.clicks"
                " WHERE item_sk < 10 AND n > 1")
    print("literal filters push down:",
          cur.info["federated_pushdown"]["events.default.clicks"])
    # split-parallel streaming: the memtable connector produces morsels
    # with latency, yet first rows arrive before it finishes producing
    hs = conn.execute_async("SELECT item_sk, n FROM events.default.clicks")
    first = next(iter(hs.fetch_stream(batch_rows=256)))
    print(f"first {len(first)} federated rows streamed while "
          f"state={hs.state} (parallel split readers: "
          f"{ev.peak_active_readers})")
    hs.result(30)

    print("\n== serving tier: shared scans + result-cache serving (PR 6) ==")
    # high-concurrency serving: repeated dashboard queries are answered
    # straight from the warehouse-wide result cache — a hit skips WLM
    # admission and execution entirely (`admission_skipped` below) — while
    # distinct-but-overlapping queries attach to an in-flight scan's
    # exchange instead of re-reading the table through LLAP
    dash = """SELECT i_category, SUM(ss_price) AS rev FROM store_sales, item
              WHERE ss_item_sk = i_item_sk GROUP BY i_category"""
    conn.execute(dash)  # first execution fills the cache
    hd = conn.execute_async(dash)  # repeat: served without a WLM slot
    hd.result(30)
    print("repeat served without admission:",
          hd.info.get("admission_skipped"),
          f"(cache_hit={hd.info.get('cache_hit')})")
    # concurrent unique variants (dim-side filters only) share one fact
    # scan: the second query's scan vertex attaches to the first's exchange
    share = db.connect(warehouse=conn.warehouse, semijoin_reduction=False,
                       result_cache=False,
                       **{"debug_vertex_delay_s": 0.05})
    hs1 = share.execute_async(dash + " ORDER BY rev DESC")
    hs2 = share.execute_async(dash + " ORDER BY rev")
    hs1.result(30), hs2.result(30)
    stats = conn.server_stats()  # warehouse-wide serving counters
    print("result cache:", {k: stats["result_cache"][k]
                            for k in ("hits", "misses", "bytes_used")})
    print("shared scans:", {k: stats["shared_scans"][k]
                            for k in ("published", "attached", "fallbacks")})
    print("admission queues:", stats["admission_queues"])
    share.close()

    print("\n== EXPLAIN ANALYZE: per-stage pipeline timings ==")
    cur.execute("EXPLAIN ANALYZE " + q.replace("?", "3", 1).replace("?", "6"))
    for (line,) in cur.fetchall():
        print(line)

    print("\n== correctness toolkit (PR 7) ==")
    # three analysis gates ship with the warehouse (`repro.analysis`):
    #   * `python -m repro.analysis` — AST invariant lint (REP001..REP004:
    #     declared config keys, cancellable reader loops, no new full-
    #     materialization sites, lock hygiene); CI fails on any finding;
    #   * REPRO_LOCKDEP=1 — every runtime lock becomes order-tracked and
    #     the first AB/BA inversion raises LockOrderError deterministically;
    #   * debug.validate_plans / REPRO_VALIDATE_PLANS — every compiled DAG
    #     is structurally validated (edges, shuffle lanes, plan-cache
    #     aliasing) before execution, as below:
    checked = db.connect(warehouse=conn.warehouse,
                         **{"debug.validate_plans": True})
    rows = checked.execute(
        "SELECT i_category, COUNT(*) AS n FROM store_sales, item"
        " WHERE ss_item_sk = i_item_sk GROUP BY i_category"
    ).fetchall()
    print(f"validated plan executed: {len(rows)} groups "
          f"(every DAG this session compiles is structure-checked)")
    checked.close()

    print("\n-- schema contract --")
    # every bound plan node carries a typed output schema (name -> numpy
    # dtype + nullability, inferred from catalog types through the same
    # promotion rules the executor applies).  The schema-flow checker
    # (`repro.analysis.schema_check`, rules SCH001..SCH006) re-verifies
    # the contract on every compiled and adaptively mutated DAG under
    # `debug.validate_plans`: column refs resolve, UNION/shuffle branches
    # promote, aggregate merge folds preserve partial-state dtypes, join
    # and partition keys hash in the same dtype family, federated
    # residuals only touch surviving columns, and edge placeholders agree
    # with their producers.  `debug.check_batches` (REPRO_CHECK_BATCHES)
    # adds the runtime half: every exchange morsel is asserted against
    # the edge's declared schema — zero overhead when off.  EXPLAIN shows
    # the inferred contract inline:
    schema_checked = db.connect(warehouse=conn.warehouse,
                                **{"debug.validate_plans": True,
                                   "debug.check_batches": True})
    sc_cur = schema_checked.cursor()
    sc_cur.execute(
        "EXPLAIN SELECT i_category, COUNT(*) AS n FROM store_sales, item"
        " WHERE ss_item_sk = i_item_sk GROUP BY i_category")
    for (line,) in sc_cur.fetchall():
        if "schema:" in line or "->" in line:
            print(line)
    schema_checked.close()

    print("\n== adaptive execution: live-telemetry replanning (PR 8) ==")
    # with `adaptive.enabled` (the default) the running DAG is replanned
    # from lane telemetry: a hot shuffle lane splits its remaining rows
    # across fresh sub-lanes (re-merged by a folding aggregate), a grouped
    # aggregate whose keys cover the upstream join's shuffle keys reuses
    # the join's lanes instead of adding its own hop (shuffle elision, at
    # compile time), and a fan-out whose live rows fall far short of the
    # CBO estimate collapses back to a single consumer.  Every mid-query
    # DAG mutation is re-validated by `repro.analysis.check_dag` before
    # the scheduler adopts it; declined adoptions surface as `declined`.
    cur.execute("CREATE TABLE skewed_sales (k INT, v INT)")
    cur.execute("CREATE TABLE sku (sk INT, weight INT)")
    n = 240_000
    k = rng.integers(0, 64, n)
    k[rng.random(n) < 0.85] = 7  # one key owns ~85% of the rows
    from repro.core.acid import AcidTable
    tx = conn.warehouse.hms.open_txn()
    AcidTable(conn.warehouse.hms.get_table("skewed_sales"),
              conn.warehouse.hms).insert(
        tx, VectorBatch({"k": k, "v": np.arange(n) % 100}))
    AcidTable(conn.warehouse.hms.get_table("sku"),
              conn.warehouse.hms).insert(
        tx, VectorBatch({"sk": np.arange(64), "weight": np.arange(64)}))
    conn.warehouse.hms.commit_txn(tx)

    # hot-lane split: the skewed key floods one of the two lanes; its
    # remaining rows are re-spread over fresh sub-lanes mid-stream and the
    # merge becomes a partial-combining fold
    adp2 = db.connect(warehouse=conn.warehouse, result_cache=False,
                      **{"shuffle.partitions": 2})
    ha = adp2.execute_async(
        "SELECT k, SUM(v) AS sv, COUNT(*) AS c FROM skewed_sales"
        " GROUP BY k")
    ha.result(60)
    print("skewed aggregate replanned live:",
          [e["kind"] for e in ha.poll()["adaptive"]])

    auto = db.connect(warehouse=conn.warehouse, result_cache=False,
                      **{"shuffle.partitions": "auto",
                         "broadcast_threshold_rows": 0.0})
    # co-partition elision: GROUP BY s.k covers the join's shuffle keys,
    # so the aggregate runs inside the join's lanes — one hop, not two
    he = auto.execute_async(
        "SELECT s.k, SUM(s.v) AS sv FROM skewed_sales s"
        " JOIN sku d ON s.k = d.sk GROUP BY s.k")
    he.result(60)
    print("covered join/agg elides its shuffle:", he.poll()["adaptive"])
    # payoff gate: the residual predicate is opaque to the CBO, live rows
    # come in far under the estimate, and the fan-out is collapsed back
    # to a single consumer
    hc = auto.execute_async(
        "SELECT s.v, SUM(s.k) AS sk FROM skewed_sales s"
        " JOIN sku d ON s.k = d.sk"
        " WHERE s.k + d.weight >= 100 GROUP BY s.v")
    hc.result(60)
    print("over-estimated fan-out declined:", hc.poll()["adaptive"])
    auto.close()

    # EXPLAIN ANALYZE appends the adaptive decision log to the stage
    # timings, so a replanned query explains itself after the fact
    s_adp = conn.warehouse.session(result_cache=False,
                                   **{"shuffle.partitions": 2})
    ra = s_adp.execute("EXPLAIN ANALYZE SELECT k, SUM(v) AS sv"
                       " FROM skewed_sales GROUP BY k")
    text = [str(line) for line in ra.batch.cols["plan"]]
    start = next((i for i, l in enumerate(text)
                  if l.startswith("adaptive decisions:")), len(text))
    for line in text[start:]:
        print(" ", line)
    adp2.close()

    print("\n== observability: tracing, metrics, query log (PR 10) ==")
    # `obs.tracing` (or REPRO_OBS_TRACING=1) records a structured
    # QueryTrace per query: pipeline-stage spans, the WLM admission wait,
    # every DAG vertex split into compute / exchange-wait / spill-I/O,
    # shuffle lanes, federated split reads, kernel dispatches, and
    # serving/adaptive events — all on one clock.  Tracing off costs one
    # attribute test per site (the span helpers return a shared no-op).
    traced = db.connect(warehouse=conn.warehouse, result_cache=False,
                        **{"obs.tracing": True, "shuffle.partitions": 2})
    ht = traced.execute_async(
        "SELECT k, SUM(v) AS sv FROM skewed_sales GROUP BY k")
    ht.result(60)
    summ = ht._task.trace.summary()
    print("traced stages:", sorted(summ["stages_ms"]))
    for vid, v in summ["vertices"].items():
        print(f"  vertex {vid}: total={v['total_ms']:.1f}ms "
              f"compute={v['compute_ms']:.1f}ms "
              f"exchange_wait={v['exchange_wait_ms']:.1f}ms "
              f"spill_io={v['spill_io_ms']:.1f}ms rows={v['rows']}")
    # export as Chrome trace-event JSON: open in Perfetto or
    # chrome://tracing to see the query as a timeline
    import os
    trace_path = os.path.join(tempfile.gettempdir(), "quickstart_trace.json")
    traced.export_trace(ht.query_id, trace_path)
    print("Perfetto-renderable trace written to", trace_path)
    # every counter/gauge/histogram flows through one MetricsRegistry;
    # server_stats()/poll() keep their shapes but derive from it
    m = conn.metrics()
    print("metrics: query.succeeded =",
          m["counters"].get("query.succeeded"),
          "| result-cache hits =",
          m["counters"].get("serving.result_cache.hits"),
          "| kernel dispatches =",
          {k.split(".", 2)[2]: v for k, v in m["counters"].items()
           if k.startswith("kernels.dispatch.")} or "(engine=auto)")
    print("query.wall_ms histogram:",
          m["histograms"]["query.wall_ms"]["count"], "queries observed")
    # the query log is an always-on bounded ring — no config needed
    for entry in conn.query_log(limit=3):
        print(f"  [{entry['status']}] {entry['qid'] or '-'} "
              f"{entry['wall_ms']:.1f}ms rows={entry['rows']} "
              f"cache_hit={entry['cache_hit']}: {entry['sql'][:48]}...")
    traced.close()

    conn.close()


if __name__ == "__main__":
    main()
