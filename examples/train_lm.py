"""End-to-end training driver: the warehouse feeds an LM train loop.

The two pillars composed: token batches are produced by snapshot-isolated
vectorized SQL scans over an ACID corpus table (the Hive layer is the data
pipeline), and the training stack (scan-over-layers model, AdamW, sharded
checkpoints with save-on-preemption) consumes them.

On CPU we train a reduced mamba2-family model (~1.5M params) for a few
hundred steps and assert the loss drops; the identical driver lowers on the
production mesh via repro.launch.dryrun.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig, get_config, reduced_config
from repro.core.acid import AcidTable
from repro.core.runtime.vector import VectorBatch
from repro.core.session import Warehouse
from repro.distributed.checkpoint import CheckpointManager, install_preemption_handler
from repro.models import model as M
from repro.train.optimizer import adamw_init
from repro.train.steps import make_train_step


def build_corpus(wh: Warehouse, vocab: int, n_docs: int = 400,
                 doc_len: int = 256) -> None:
    """An ACID 'documents' table: id, split, packed token ids."""
    s = wh.session()
    s.execute("CREATE TABLE corpus (doc_id INT, split STRING, tok_off INT)")
    s.execute("CREATE TABLE tokens (doc_id INT, pos INT, tok INT)")
    rng = np.random.default_rng(0)
    hms = wh.hms
    tx = hms.open_txn()
    # skewed unigram distribution so there is something to learn
    probs = 1.0 / np.arange(1, vocab + 1)
    probs /= probs.sum()
    doc_ids = np.repeat(np.arange(n_docs), doc_len)
    AcidTable(hms.get_table("tokens"), hms).insert(tx, VectorBatch({
        "doc_id": doc_ids,
        "pos": np.tile(np.arange(doc_len), n_docs),
        "tok": rng.choice(vocab, size=n_docs * doc_len, p=probs),
    }))
    AcidTable(hms.get_table("corpus"), hms).insert(tx, VectorBatch({
        "doc_id": np.arange(n_docs),
        "split": np.where(np.arange(n_docs) % 10 == 0, "eval", "train"),
        "tok_off": np.arange(n_docs) * doc_len,
    }))
    hms.commit_txn(tx)


def batches_from_warehouse(wh, split: str, batch: int, seq: int, vocab: int):
    """The data pipeline: one vectorized scan per epoch, then shuffle+pack.

    Uses the same snapshot-isolated scan path as every query, so training
    data versions are transactional (GDPR deletes -> next epoch's snapshot).
    """
    s = wh.session(result_cache=False)
    r = s.execute(
        "SELECT t.doc_id, t.pos, t.tok FROM tokens t, corpus c"
        f" WHERE t.doc_id = c.doc_id AND c.split = '{split}'"
        " ORDER BY t.doc_id, t.pos")
    toks = np.array([x[2] for x in r.rows], dtype=np.int32)
    rng = np.random.default_rng(1)
    n = (len(toks) - 1) // seq
    while True:
        order = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            idx = order[i:i + batch]
            x = np.stack([toks[j * seq:(j + 1) * seq] for j in idx])
            y = np.stack([toks[j * seq + 1:(j + 1) * seq + 1] for j in idx])
            yield {"inputs": jnp.asarray(x), "labels": jnp.asarray(y)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = reduced_config(get_config("mamba2-130m"))
    wh = Warehouse(tempfile.mkdtemp(prefix="tahoe_train_"))
    print(f"building ACID corpus (vocab={cfg.vocab_size}) ...")
    build_corpus(wh, cfg.vocab_size)
    data = batches_from_warehouse(wh, "train", args.batch, args.seq,
                                  cfg.vocab_size)

    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    opt = adamw_init(params)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model: {cfg.name} (reduced) — {n_params/1e6:.2f}M params")

    ckpt = CheckpointManager(tempfile.mkdtemp(prefix="tahoe_ckpt_"), keep=2)
    state = {"params": params, "opt": opt}
    install_preemption_handler(lambda: ckpt.save(-1, state))

    step_fn = jax.jit(make_train_step(cfg, lr=3e-3))
    losses = []
    t0 = time.time()
    for step in range(1, args.steps + 1):
        batch = next(data)
        params, opt, metrics = step_fn(params, opt, batch)
        losses.append(float(metrics["loss"]))
        if step % 25 == 0:
            print(f"step {step:4d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.time() - t0) / step * 1e3:.0f} ms/step)")
        if step % 100 == 0:
            ckpt.save(step, {"params": params, "opt": opt}, blocking=False)
    ckpt.wait()

    first, last = np.mean(losses[:20]), np.mean(losses[-20:])
    print(f"\nloss: {first:.4f} -> {last:.4f} "
          f"({'OK, learning' if last < first - 0.2 else 'NOT LEARNING?'})")
    restored, step = ckpt.restore({"params": params, "opt": opt})
    print(f"checkpoint restore OK (step {step})")
    assert last < first - 0.2, "training failed to reduce loss"


if __name__ == "__main__":
    main()
