import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init).  For every cell this driver:

  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. resolves parameter/optimizer/cache shardings via the divisibility-aware
     planner,
  3. ``jax.jit(step).lower(**ShapeDtypeStruct inputs).compile()`` — no
     device allocation anywhere,
  4. records memory_analysis(), cost_analysis(), and the collective-byte
     account parsed from the optimized HLO into
     ``experiments/dryrun/<arch>__<shape>__<mesh>.json``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--debug-mesh]
"""
import argparse
import functools
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import (
    LM_SHAPES,
    ModelConfig,
    ShapeConfig,
    get_config,
    load_all,
    supported_shapes,
)
from ..models import model as M
from ..train.optimizer import adamw_init, adamw_state_axes
from ..train.steps import input_specs, make_prefill_step, make_serve_step, make_train_step
from .hlo_analysis import analyze_hlo, roofline_terms
from .mesh import make_debug_mesh, make_production_mesh, shard_ctx
from .sharding import resolve_pspec, sharded_bytes_per_device, tree_shardings

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# microbatch (gradient accumulation) counts per train cell — keeps the
# per-microbatch logits buffer sharded-small (see DESIGN.md §4)
TRAIN_MICROBATCHES = {"default": 8}


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh, specs):
    """Input shardings: batch dims over (pod,data); cache seq-sharded when
    batch does not divide the DP axes (long_500k, batch=1)."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    B = shape.global_batch

    def spec_for_batch_leaf(leaf):
        pref = (tuple(dp),) + (None,) * (len(leaf.shape) - 1)
        return resolve_pspec(pref, leaf.shape, mesh)

    from jax.sharding import NamedSharding

    if shape.kind in ("train", "prefill"):
        return jax.tree.map(
            lambda l: NamedSharding(mesh, spec_for_batch_leaf(l)), specs
        )
    # decode: tokens/pos + cache
    dp_over_seq = B % dp_size != 0
    cache_ax = M.cache_axes(cfg, B, dp_over_seq)
    out = {}
    out["inputs"] = NamedSharding(mesh, spec_for_batch_leaf(specs["inputs"]))
    out["pos"] = NamedSharding(mesh, resolve_pspec((), (), mesh))
    if dp_over_seq:
        # seq-dim sharding for the KV cache: (periods, B, S, Hkv, hd)
        def cache_spec(ax, leaf):
            # replace the batch 'data' pref with seq 'data'
            pref = list(ax)
            return resolve_pspec(tuple(pref), leaf.shape, mesh, expand_data=True)
        from .sharding import _is_axes_leaf
        # move 'data' from batch dim to seq dim for attention caches
        def retarget(ax):
            ax = list(ax)
            # attention cache leaves: (periods, B, S, H, hd): len 5
            if len(ax) >= 4 and ax[1] == "data":
                ax[1] = None
                ax[2] = "data"
            return tuple(ax)
        cache_ax = jax.tree.map(retarget, cache_ax, is_leaf=_is_axes_leaf)
    out["cache"] = tree_shardings(cache_ax, specs["cache"], mesh,
                                  expand_data=True)
    return out


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); decode: D = new tokens only."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # one token per sequence


def run_cell(arch: str, shape_name: str, multi_pod: bool, debug_mesh: bool,
             out_dir: str = OUT_DIR, mb_override: Optional[int] = None,
             attn_impl: str = "blocked", remat_mode: str = "per_period",
             tag: str = "") -> Dict:
    from ..models.layers import set_attention_impl
    from ..models.model import set_remat_mode

    set_attention_impl(attn_impl)
    set_remat_mode(remat_mode)
    cfg = get_config(arch)
    shape = LM_SHAPES[shape_name]
    mesh = (make_debug_mesh(multi_pod=multi_pod) if debug_mesh
            else make_production_mesh(multi_pod=multi_pod))
    ctx = shard_ctx(mesh)
    chips = int(np.prod(list(mesh.shape.values())))
    mesh_name = ("debug_" if debug_mesh else "") + \
        ("2x16x16" if multi_pod and not debug_mesh else
         "16x16" if not debug_mesh else "x".join(map(str, mesh.shape.values())))

    t0 = time.time()
    params_shapes = jax.eval_shape(
        functools.partial(M.init_params, cfg), jax.random.PRNGKey(0))
    axes = M.param_axes(cfg)
    param_sh = tree_shardings(axes, params_shapes, mesh)
    specs = input_specs(cfg, shape)

    with mesh:
        if shape.kind == "train":
            mb = mb_override or TRAIN_MICROBATCHES.get(
                (arch, shape_name), TRAIN_MICROBATCHES["default"])
            opt_shapes = jax.eval_shape(adamw_init, params_shapes)
            opt_sh = tree_shardings(adamw_state_axes(axes), opt_shapes, mesh)
            step = make_train_step(cfg, ctx, microbatches=mb)
            bsh = batch_shardings(cfg, shape, mesh, specs["batch"])
            jitted = jax.jit(
                step,
                in_shardings=(param_sh, opt_sh, bsh),
                out_shardings=(param_sh, opt_sh, None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_shapes, opt_shapes, specs["batch"])
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, ctx)
            bsh = batch_shardings(cfg, shape, mesh, specs)
            jitted = jax.jit(step, in_shardings=(param_sh, bsh["inputs"]))
            lowered = jitted.lower(params_shapes, specs["inputs"])
        else:  # decode
            step = make_serve_step(cfg, ctx)
            bsh = batch_shardings(cfg, shape, mesh, specs)
            jitted = jax.jit(
                step,
                in_shardings=(param_sh, bsh["cache"], bsh["inputs"], bsh["pos"]),
                out_shardings=(None, bsh["cache"]),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params_shapes, specs["cache"],
                                   specs["inputs"], specs["pos"])

        compiled = lowered.compile()

    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    mem_dict = {}
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                mem_dict[k] = int(v)
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax returns [{...}] per device
        cost = cost[0] if cost else {}

    # trip-count-corrected accounting from the optimized per-device HLO
    hlo_text = compiled.as_text()
    hc = analyze_hlo(hlo_text)

    rf = roofline_terms(
        per_device_flops=hc.flops,
        per_device_bytes=hc.bytes_accessed,
        per_device_collective_bytes=hc.collective_bytes,
        chips=chips,
        model_flops=model_flops(cfg, shape),
    )
    param_bytes_dev = sharded_bytes_per_device(params_shapes, param_sh)

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "chips": chips,
        "kind": shape.kind,
        "compile_seconds": round(compile_s, 1),
        "params_total": int(cfg.param_count()),
        "params_active": int(cfg.active_param_count()),
        "param_bytes_per_device": int(param_bytes_dev),
        "memory_analysis": mem_dict,
        "cost_analysis_raw": {k: float(v) for k, v in cost.items()
                              if isinstance(v, (int, float))},
        "hlo_corrected": {
            "per_device_flops": hc.flops,
            "per_device_bytes": hc.bytes_accessed,
            "loop_trip_counts": hc.trip_counts,
        },
        "collectives": {
            "per_device_bytes_by_type": {k: float(v)
                                         for k, v in hc.collective_by_type.items()},
            "op_count": hc.collective_count,
        },
        "roofline": rf.to_dict(),
    }
    result["attn_impl"] = attn_impl
    result["remat_mode"] = remat_mode
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    fname = f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(result, f, indent=2)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--debug-mesh", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--attn-impl", default="blocked",
                    choices=["blocked", "online"])
    ap.add_argument("--remat-mode", default="per_period",
                    choices=["per_period", "sqrt"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--out-dir", default=OUT_DIR)
    args = ap.parse_args()

    load_all()
    cells = []
    if args.all:
        from ..configs.base import ARCH_IDS
        for arch in ARCH_IDS:
            for sh in supported_shapes(get_config(arch)):
                cells.append((arch, sh))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = 0
    for arch, sh in cells:
        for mp in meshes:
            tag = f"{arch} x {sh} x {'2x16x16' if mp else '16x16'}"
            try:
                r = run_cell(arch, sh, mp, args.debug_mesh, args.out_dir,
                             args.microbatches, attn_impl=args.attn_impl,
                             remat_mode=args.remat_mode, tag=args.tag)
                rf = r["roofline"]
                print(f"OK   {tag}: compile={r['compile_seconds']}s "
                      f"compute={rf['compute_s']:.4f}s memory={rf['memory_s']:.4f}s "
                      f"coll={rf['collective_s']:.4f}s bound={rf['bottleneck']} "
                      f"MF/HF={rf['flops_ratio']:.3f}", flush=True)
            except Exception as e:
                failures += 1
                print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
