"""Divisibility-aware sharding resolver.

Model code declares *preferred* mesh axes per tensor dimension (an "axes
pytree" mirroring the param pytree).  This module resolves preferences to
concrete NamedShardings against an actual mesh, dropping any axis that does
not evenly divide its dimension (e.g. qwen3's 40 heads vs model=16 — the
head sharding is dropped while d_ff=17408 shards cleanly) and never using a
mesh axis twice in one spec.

``expand_data=True`` maps the logical 'data' axis to ('pod','data') — used
for batch/activation/cache trees on the multi-pod mesh, while parameters
keep FSDP confined to one pod (gradients, not weights, cross the DCN).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and len(x) > 0 and all(
        y is None or isinstance(y, str) or (
            isinstance(y, tuple) and all(isinstance(z, str) for z in y)
        )
        for y in x
    )


def resolve_pspec(pref: Tuple, shape: Tuple[int, ...], mesh,
                  expand_data: bool = False) -> P:
    used = set()
    resolved = []
    pref = tuple(pref) + (None,) * (len(shape) - len(pref))
    for dim, ax in zip(shape, pref):
        if ax is None:
            resolved.append(None)
            continue
        names = list(ax) if isinstance(ax, tuple) else [ax]
        if expand_data and "data" in names and "pod" in mesh.shape:
            names = ["pod" if n == "data" else n for n in names] + ["data"]
            # ('pod','data') acts as the combined DP axis
            seen = set()
            names = [n for n in names if not (n in seen or seen.add(n))]
        names = [n for n in names if n in mesh.shape and n not in used]
        total = int(np.prod([mesh.shape[n] for n in names])) if names else 1
        if names and dim % total == 0 and dim > 0:
            resolved.append(tuple(names) if len(names) > 1 else names[0])
            used.update(names)
        else:
            # try each axis individually before giving up
            placed = False
            for n in names:
                if dim % mesh.shape[n] == 0:
                    resolved.append(n)
                    used.add(n)
                    placed = True
                    break
            if not placed:
                resolved.append(None)
    return P(*resolved)


def tree_shardings(axes_tree, shape_tree, mesh, expand_data: bool = False):
    """NamedShardings for a pytree given its axes-preferences pytree."""

    def mk(ax, leaf):
        return NamedSharding(
            mesh, resolve_pspec(ax, leaf.shape, mesh, expand_data=expand_data)
        )

    return jax.tree.map(mk, axes_tree, shape_tree, is_leaf=_is_axes_leaf)


def sharded_bytes_per_device(shape_tree, sharding_tree) -> int:
    """Analytic per-device bytes for a sharded pytree (dry-run reporting)."""
    total = 0
    for leaf, sh in zip(jax.tree.leaves(shape_tree), jax.tree.leaves(
            sharding_tree, is_leaf=lambda x: isinstance(x, NamedSharding))):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        div = 1
        spec = sh.spec
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * len(leaf.shape)):
            if ax is None:
                continue
            names = ax if isinstance(ax, tuple) else (ax,)
            for nm in names:
                div *= sh.mesh.shape[nm]
        total += n * leaf.dtype.itemsize // max(div, 1)
    return total
