"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The single-pod production mesh is 16x16
(256 chips, TPU v5e pod); the multi-pod mesh adds a leading 'pod' axis
(2 pods = 512 chips).  The 'pod' axis is pure data parallelism — only
gradient all-reduce crosses the pod (ICI/DCN) boundary.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False):
    """Small mesh for CI-sized dry-run smoke tests (8 host devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def shard_ctx(mesh):
    from ..models.layers import ShardCtx

    return ShardCtx(dp=dp_axes(mesh), tp="model", axis_sizes=dict(mesh.shape))
