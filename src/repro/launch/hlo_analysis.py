"""Post-compile HLO analysis: trip-count-corrected FLOPs / HBM bytes /
collective-byte accounting + the three roofline terms.

``compiled.cost_analysis()`` counts while-loop bodies **once**, which under-
counts layer-scanned models by ~num_layers.  This module parses the optimized
(SPMD-partitioned, per-device) HLO text instead:

  * builds a symbol table (instruction -> shape) per computation,
  * recovers loop trip counts from ``backend_config={"known_trip_count":...}``
    (fallback: the comparison constant in the loop condition),
  * FLOPs: 2·M·N·K for every ``dot`` (batch dims included), convolution
    FLOPs from kernel/output shapes — multiplied along the call graph;
  * HBM bytes: operand+output bytes of top-level ops per computation
    (fusion internals excluded: the fusion op's operands/results ARE the
    traffic) — multiplied the same way;
  * collective bytes: operand sizes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute, trip-count weighted.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import json
import re

import numpy as np
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

# Buffers at or below this size that are produced AND consumed inside one
# computation are assumed VMEM-resident on TPU (a well-tiled kernel/fusion
# keeps them on chip); larger intermediates and anything crossing a loop /
# computation boundary is charged as HBM traffic.  This is what makes a
# flash-style (tile-sized online-softmax) attention visibly cheaper than a
# naive one in the memory roofline term.
VMEM_TILE_BYTES = 16 << 20

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "u64": 8,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# one array shape: dtype[d0,d1,...]
_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_OPCODE_RE = re.compile(r"^((?:\([^)]*\)|[\w\[\],\{\}\s])*?)\s*([\w\-]+)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\s*{\s*"n"\s*:\s*"?(\d+)"?')
_CONST_RE = re.compile(r"s(?:32|64)\[\]\s+constant\((\d+)\)")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")


def _parse_shapes(sig: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(sig):
        if dtype in _DTYPE_BYTES:
            out.append((dtype, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(sig: str) -> int:
    total = 0
    for dtype, dims in _parse_shapes(sig):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class Instr:
    name: str
    shape_sig: str  # result type signature text
    opcode: str
    line: str
    operands: List[str]


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    table: Dict[str, str] = field(default_factory=dict)  # name -> shape sig


_HEADER_RE = re.compile(
    r"^(ENTRY\s+)?%?([\w\.\-]+)\s*(\(.*\))?\s*->\s*.*{\s*$")
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*([\w\[\],\{\}\s/#]+?)(?:,|\)$|\))")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if cur is None:
            m = _HEADER_RE.match(stripped)
            if m and stripped.endswith("{"):
                cur = Computation(m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
                # parameters declared in the header
                if m.group(3):
                    for pm in _PARAM_RE.finditer(m.group(3)):
                        cur.table[pm.group(1)] = pm.group(2)
            continue
        if stripped == "}":
            cur = None
            continue
        im = _INSTR_RE.match(stripped)
        if not im:
            continue
        name, rhs = im.group(1), im.group(2)
        om = _OPCODE_RE.match(rhs)
        opcode = om.group(2) if om else rhs.split("(")[0].split()[-1]
        result_sig = rhs.split(opcode + "(")[0] if opcode + "(" in rhs else rhs
        paren = rhs.find(opcode + "(")
        args = ""
        if paren >= 0:
            depth = 0
            start = paren + len(opcode) + 1
            for i in range(start, len(rhs)):
                if rhs[i] == "(":
                    depth += 1
                elif rhs[i] == ")":
                    if depth == 0:
                        args = rhs[start:i]
                        break
                    depth -= 1
        operands = _OPERAND_RE.findall(args)
        instr = Instr(name, result_sig, opcode, stripped, operands)
        cur.instrs.append(instr)
        cur.table[name] = result_sig
        # parameters defined as instructions
        if opcode == "parameter":
            cur.table[name] = result_sig
    return comps, entry


def _dot_flops(instr: Instr, comp: Computation) -> float:
    out_shapes = _parse_shapes(instr.shape_sig)
    if not out_shapes:
        return 0.0
    out_elems = 1
    for d in out_shapes[0][1]:
        out_elems *= d
    # contracted size from the lhs operand's contracting dims
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.line)
    if not m or not instr.operands:
        return 2.0 * out_elems  # unknown: elementwise-ish fallback
    lhs_sig = comp.table.get(instr.operands[0], "")
    lhs_shapes = _parse_shapes(lhs_sig)
    if not lhs_shapes:
        return 2.0 * out_elems
    lhs_dims = lhs_shapes[0][1]
    k = 1
    for ci in m.group(1).split(","):
        if ci and int(ci) < len(lhs_dims):
            k *= lhs_dims[int(ci)]
    return 2.0 * out_elems * k


def _conv_flops(instr: Instr, comp: Computation) -> float:
    out_shapes = _parse_shapes(instr.shape_sig)
    if not out_shapes or len(instr.operands) < 2:
        return 0.0
    out_elems = 1
    for d in out_shapes[0][1]:
        out_elems *= d
    kern = _parse_shapes(comp.table.get(instr.operands[1], ""))
    if not kern:
        return 2.0 * out_elems
    kern_elems = 1
    for d in kern[0][1]:
        kern_elems *= d
    # depthwise/grouped handled implicitly: kernel already has I/G channels
    groups = 1
    gm = re.search(r"feature_group_count=(\d+)", instr.line)
    if gm:
        groups = int(gm.group(1))
    out_ch = out_shapes[0][1][1] if len(out_shapes[0][1]) > 1 else 1
    per_out = kern_elems / max(out_ch, 1)
    return 2.0 * out_elems * per_out


_LOCAL_SMALL_CACHE: Dict[int, set] = {}


def _local_small(comp: Computation) -> set:
    """Names of locally-produced buffers <= VMEM_TILE_BYTES with all users in
    this computation — assumed to stay on chip (never charged to HBM)."""
    key = id(comp)
    if key in _LOCAL_SMALL_CACHE:
        return _LOCAL_SMALL_CACHE[key]
    users: Dict[str, int] = {}
    root = comp.instrs[-1].name if comp.instrs else None
    for ins in comp.instrs:
        for op in ins.operands:
            users[op] = users.get(op, 0) + 1
    small = set()
    for ins in comp.instrs:
        if ins.opcode in ("parameter", "get-tuple-element", "constant"):
            continue
        if ins.name == root:
            continue  # crosses the boundary
        if users.get(ins.name, 0) == 0:
            continue
        if _shape_bytes(ins.shape_sig) <= VMEM_TILE_BYTES:
            small.add(ins.name)
    _LOCAL_SMALL_CACHE[key] = small
    return small


def _instr_traffic(ins: Instr, comp: Computation,
                   comps: Dict[str, Computation],
                   local_small: Optional[set] = None) -> float:
    """HBM traffic model for one top-level instruction.

    dynamic-slice reads only the slice; dynamic-update-slice is an in-place
    read-modify-write of the slice (XLA aliases the buffer).  Fusions are
    priced from their body: sliced parameters contribute slice-sized reads,
    whole-array parameters full reads; a dynamic-update-slice root writes
    only the update region.  This mirrors how TPU fusions actually touch HBM
    — without it, scan-over-layers carry buffers (L, B, S, D) would be
    charged L times at full size."""
    if ins.opcode == "dynamic-slice":
        return 2.0 * _shape_bytes(ins.shape_sig)
    if ins.opcode == "dynamic-update-slice":
        upd = comp.table.get(ins.operands[1], "") if len(ins.operands) > 1 else ""
        return 2.0 * _shape_bytes(upd)
    if ins.opcode == "fusion":
        m = re.search(r"calls=%?([\w\.\-]+)", ins.line)
        body = comps.get(m.group(1)) if m else None
        if body is not None:
            traffic = 0.0
            local_small = local_small or set()
            param_names = [i.name for i in body.instrs if i.opcode == "parameter"]
            dus_list = [i for i in body.instrs
                        if i.opcode == "dynamic-update-slice"]
            # buffers updated in place are charged as slice RMW, not full size
            aliased = {i.operands[0] for i in dus_list if i.operands}
            for pi, pn in enumerate(param_names):
                if pn in aliased:
                    continue
                # VMEM-resident caller operand -> free read
                if pi < len(ins.operands) and ins.operands[pi] in local_small:
                    continue
                users = [i for i in body.instrs if pn in i.operands]
                if users and all(u.opcode == "dynamic-slice" for u in users):
                    traffic += sum(_shape_bytes(u.shape_sig) for u in users)
                else:
                    traffic += _shape_bytes(body.table.get(pn, ""))
            for d in dus_list:
                upd = body.table.get(d.operands[1], "") if len(d.operands) > 1 else ""
                traffic += 2.0 * _shape_bytes(upd)
            if not dus_list:
                if ins.name not in local_small:
                    traffic += _shape_bytes(ins.shape_sig)
            else:
                # non-aliased fusion outputs (beyond the in-place buffers)
                dus_sigs = {d.shape_sig for d in dus_list}
                out_sigs = _parse_shapes(ins.shape_sig)
                dus_elems = sum(
                    int(np.prod(dims)) * _DTYPE_BYTES[dt]
                    for sig in dus_sigs for dt, dims in _parse_shapes(sig)
                )
                total_out = _shape_bytes(ins.shape_sig)
                traffic += max(0.0, total_out - dus_elems)
            return traffic
    local_small = local_small or set()
    nbytes = 0.0
    if not (ins.name in local_small):
        nbytes += _shape_bytes(ins.shape_sig)
    for op in ins.operands:
        if op in local_small:
            continue  # VMEM-resident producer-consumer edge
        nbytes += _shape_bytes(comp.table.get(op, ""))
    return nbytes


@dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    collective_by_type: Dict[str, float] = field(default_factory=dict)
    collective_count: int = 0
    trip_counts: Dict[str, float] = field(default_factory=dict)


def analyze_hlo(text: str) -> HloCost:
    comps, entry = parse_hlo(text)

    # call graph with multipliers
    calls: Dict[str, List[Tuple[str, float, bool]]] = {n: [] for n in comps}
    fusion_bodies = set()
    for name, comp in comps.items():
        for ins in comp.instrs:
            if ins.opcode == "while":
                trips = 1.0
                tm = _TRIP_RE.search(ins.line)
                if tm:
                    trips = float(tm.group(1))
                body = cond = None
                bm = re.search(r"body=%?([\w\.\-]+)", ins.line)
                cm = re.search(r"condition=%?([\w\.\-]+)", ins.line)
                if bm:
                    body = bm.group(1)
                if cm:
                    cond = cm.group(1)
                if trips == 1.0 and cond in comps:
                    consts = [int(m.group(1)) for l in comps[cond].instrs
                              for m in _CONST_RE.finditer(l.line)]
                    if consts:
                        trips = float(max(consts))
                if body in comps:
                    calls[name].append((body, trips, False))
                if cond in comps:
                    calls[name].append((cond, trips, False))
            elif ins.opcode == "fusion":
                for m in re.finditer(r"calls=%?([\w\.\-]+)", ins.line):
                    if m.group(1) in comps:
                        fusion_bodies.add(m.group(1))
                        calls[name].append((m.group(1), 1.0, True))
            else:
                for m in _CALLS_RE.finditer(ins.line):
                    if m.group(1) in comps:
                        calls[name].append((m.group(1), 1.0, False))

    mult: Dict[str, float] = {}

    def walk(name: str, factor: float, depth: int = 0):
        if depth > 128:
            return
        if mult.get(name, 0.0) >= factor:
            return
        mult[name] = factor
        for callee, trips, _fused in calls.get(name, []):
            walk(callee, factor * trips, depth + 1)

    if entry:
        walk(entry, 1.0)
    for name in comps:
        mult.setdefault(name, 0.0)  # unreachable -> ignore

    out = HloCost()
    out.trip_counts = {n: m for n, m in mult.items() if m > 1.0}
    for name, comp in comps.items():
        factor = mult.get(name, 0.0)
        if factor <= 0.0:
            continue
        in_fusion = name in fusion_bodies
        for ins in comp.instrs:
            if ins.opcode == "dot":
                out.flops += _dot_flops(ins, comp) * factor
            elif ins.opcode == "convolution":
                out.flops += _conv_flops(ins, comp) * factor
            coll = next((c for c in _COLLECTIVES if ins.opcode == c or
                         ins.opcode.startswith(c)), None)
            if coll is not None:
                nbytes = _shape_bytes(ins.shape_sig)
                if nbytes == 0:
                    nbytes = _shape_bytes(ins.line)
                out.collective_bytes += nbytes * factor
                out.collective_by_type[coll] = \
                    out.collective_by_type.get(coll, 0.0) + nbytes * factor
                out.collective_count += 1
            if not in_fusion and ins.opcode not in (
                    "parameter", "constant", "get-tuple-element", "tuple",
                    "bitcast", "while", "conditional", "call",
                    "optimization-barrier"):
                out.bytes_accessed += _instr_traffic(
                    ins, comp, comps, _local_small(comp)) * factor
    return out


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float  # global (per-device x chips)
    hlo_bytes: float
    collective_bytes: float
    model_flops: float
    flops_ratio: float  # model_flops / hlo_flops
    bottleneck: str
    chips: int

    def to_dict(self) -> dict:
        return self.__dict__.copy()


def roofline_terms(per_device_flops, per_device_bytes,
                   per_device_collective_bytes, chips, model_flops) -> Roofline:
    hlo_flops = per_device_flops * chips
    hlo_bytes = per_device_bytes * chips
    coll_bytes = per_device_collective_bytes * chips
    compute_s = hlo_flops / (chips * PEAK_FLOPS)
    memory_s = hlo_bytes / (chips * HBM_BW)
    collective_s = coll_bytes / (chips * ICI_BW)
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    return Roofline(
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        hlo_flops=hlo_flops, hlo_bytes=hlo_bytes, collective_bytes=coll_bytes,
        model_flops=model_flops,
        flops_ratio=model_flops / hlo_flops if hlo_flops else 0.0,
        bottleneck=max(terms, key=terms.get), chips=chips,
    )
