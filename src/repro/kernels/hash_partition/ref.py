"""Pure-jnp oracle for shuffle bucket assignment.

Bit-for-bit the same hash as the Pallas kernel (and as the numpy host path
in ``repro.core.runtime.shuffle``): float32 bitcast, FNV-style column fold,
Knuth multiplicative finisher, modulo lane count.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .hash_partition import FNV_PRIME, _avalanche


def hash_partition_ref(cols, num_partitions: int):
    cols = tuple(cols)
    n = cols[0].shape[0]
    h = jnp.zeros((n,), jnp.uint32)
    for c in cols:
        v = c.astype(jnp.float32)
        v = jnp.where(v == 0.0, jnp.float32(0.0), v)
        w = jax.lax.bitcast_convert_type(v, jnp.uint32)
        h = h * jnp.uint32(FNV_PRIME) ^ w
    h = _avalanche(h)
    return (h % jnp.uint32(num_partitions)).astype(jnp.int32)
