"""Public wrapper for the shuffle bucket-assignment kernel."""
from __future__ import annotations

import functools

import jax

from ..registry import on_tpu, register, resolve
from .hash_partition import hash_partition_pallas
from .ref import hash_partition_ref


@register("hash_partition", "pallas")
@functools.partial(jax.jit, static_argnames=("num_partitions",))
def _hash_partition_pallas(cols, num_partitions: int):
    return hash_partition_pallas(cols, num_partitions,
                                 interpret=not on_tpu())


@register("hash_partition", "ref")
@functools.partial(jax.jit, static_argnames=("num_partitions",))
def _hash_partition_ref(cols, num_partitions: int):
    return hash_partition_ref(cols, num_partitions)


def hash_partition(cols, num_partitions: int, engine: str = "auto"):
    """Map rows to shuffle lanes: cols is a tuple of (N,) float32 key
    columns; returns (N,) int32 bucket ids in ``[0, num_partitions)``."""
    return resolve("hash_partition", engine)(tuple(cols), num_partitions)
