"""Pallas TPU kernel: hash-partition bucket assignment (shuffle service).

The partitioned shuffle service routes every row of a producer morsel into
one of N consumer lanes by hashing its partition-key columns.  On TPU that
assignment is a pure VPU map: each key column block is bitcast to uint32
lanes, folded FNV-style into a running hash word, finished with a Knuth
multiplicative mix, and reduced modulo the lane count — no gathers, no
scatters, one pass over the rows.

The float32 bit pattern is the canonical numeric representation (the host
side canonicalizes every numeric key column the same way, so a value that
compares equal always lands in the same lane; distinct float64 values that
collapse to one float32 merely share a bucket, which hash partitioning
tolerates by construction).  ``-0.0`` is normalized to ``+0.0`` before the
bitcast so the two equal zeros agree on a lane.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 2048

# FNV-1a style column fold + lowbias32 avalanche finisher (uint32 wrap).
# The avalanche matters: float32 bit patterns of small integers have all-zero
# low mantissa bits, so without it every row of an integer key column would
# agree modulo any power-of-two lane count.
FNV_PRIME = 16777619
MIX1 = 0x7FEB352D
MIX2 = 0x846CA68B


def _avalanche(h):
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(MIX1)
    h = h ^ (h >> jnp.uint32(15))
    h = h * jnp.uint32(MIX2)
    return h ^ (h >> jnp.uint32(16))


def _partition_kernel(*refs, num_partitions):
    col_refs, out_ref = refs[:-1], refs[-1]
    h = jnp.zeros(out_ref.shape, jnp.uint32)
    for ref in col_refs:
        v = ref[...].astype(jnp.float32)
        v = jnp.where(v == 0.0, jnp.float32(0.0), v)  # -0.0 == +0.0
        w = jax.lax.bitcast_convert_type(v, jnp.uint32)
        h = h * jnp.uint32(FNV_PRIME) ^ w
    h = _avalanche(h)
    out_ref[...] = (h % jnp.uint32(num_partitions)).astype(jnp.int32)


def hash_partition_pallas(cols, num_partitions: int, interpret: bool = True):
    """cols: tuple of (N,) float32 key columns; returns (N,) int32 buckets
    in ``[0, num_partitions)``."""
    cols = tuple(cols)
    n = cols[0].shape[0]
    if n == 0:
        return jnp.zeros((0,), dtype=jnp.int32)
    block = min(ROW_BLOCK, max(((n + 7) // 8) * 8, 8))
    pad = (-n) % block
    padded = [jnp.pad(c.astype(jnp.float32), (0, pad)) for c in cols]
    out = pl.pallas_call(
        functools.partial(_partition_kernel, num_partitions=num_partitions),
        grid=((n + pad) // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)) for _ in padded],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n + pad,), jnp.int32),
        interpret=interpret,
    )(*padded)
    return out[:n]
