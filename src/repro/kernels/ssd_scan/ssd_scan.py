"""Pallas TPU kernel: Mamba2 SSD chunked scan.

Grid: (batch*heads, num_chunks) — the chunk axis iterates sequentially on
TPU, so the inter-chunk recurrent state lives in a VMEM scratch buffer that
carries across grid steps.  Per program instance the working set is one
chunk: x (Q, P), B/C (Q, N), dA (Q,) plus the (P, N) state — a few hundred
KB, comfortably VMEM-resident, with the (Q, Q) intra-chunk score matmuls
hitting the MXU.

This is the TPU-native realization of SSD: the quadratic intra-chunk part is
dense matmul work for the systolic array; the linear inter-chunk part is a
carried VMEM state, never touching HBM between chunks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ssd_chunk_kernel(dA_ref, x_ref, b_ref, c_ref, y_ref, state_ref):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    dA = dA_ref[0, :].astype(jnp.float32)  # (Q,)
    x = x_ref[0].astype(jnp.float32)  # (Q, P)
    b = b_ref[0].astype(jnp.float32)  # (Q, N)
    c = c_ref[0].astype(jnp.float32)  # (Q, N)
    q = dA.shape[0]

    cum = jnp.cumsum(dA)  # (Q,)
    # L[i, j] = exp(cum_i - cum_j) for i >= j  (decay from j to i)
    li = cum[:, None] - cum[None, :]
    mask = jnp.tril(jnp.ones((q, q), dtype=bool))
    L = jnp.where(mask, jnp.exp(li), 0.0)

    scores = jnp.dot(c, b.T, preferred_element_type=jnp.float32)  # (Q, Q) MXU
    y_intra = jnp.dot(scores * L, x, preferred_element_type=jnp.float32)

    # contribution of the carried state (decay from chunk start to i)
    state = state_ref[...]
    decay_in = jnp.exp(cum)[:, None]  # (Q, 1)
    y_inter = jnp.dot(c, state.T, preferred_element_type=jnp.float32) * decay_in
    # state.T: (N, P) -> y_inter (Q, P)

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # update carried state: decay to chunk end, add this chunk's outer products
    decay_out = jnp.exp(cum[-1] - cum)[:, None]  # (Q, 1)
    state_ref[...] = jnp.dot((x * decay_out).T, b,  # (P,Q)@(Q,N) -> (P,N)
                             preferred_element_type=jnp.float32) + \
        state * jnp.exp(cum[-1])


def ssd_scan_pallas(x, dA, Bm, Cm, chunk: int, interpret: bool = True):
    """x: (B, S, H, P) dt-scaled; dA: (B, S, H); Bm/Cm: (B, S, N).

    Returns y (B, S, H, P).  State handling matches
    ``repro.models.mamba2.ssd_reference`` with zero initial state.
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    q = min(chunk, s)
    assert s % q == 0
    nc = s // q

    # flatten (b, h) into the leading grid axis; broadcast B/C over heads
    xg = x.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    dAg = dA.transpose(0, 2, 1).reshape(b * h, s)
    Bg = jnp.repeat(Bm[:, None], h, axis=1).reshape(b * h, s, n)
    Cg = jnp.repeat(Cm[:, None], h, axis=1).reshape(b * h, s, n)

    grid = (b * h, nc)
    out = pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q), lambda i, j: (i, j)),          # dA
            pl.BlockSpec((1, q, p), lambda i, j: (i, j, 0)),    # x
            pl.BlockSpec((1, q, n), lambda i, j: (i, j, 0)),    # B
            pl.BlockSpec((1, q, n), lambda i, j: (i, j, 0)),    # C
        ],
        out_specs=pl.BlockSpec((1, q, p), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, p), jnp.float32),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],  # carried state
        interpret=interpret,
    )(dAg, xg, Bg, Cg)
    return out.reshape(b, h, s, p).transpose(0, 2, 1, 3)
