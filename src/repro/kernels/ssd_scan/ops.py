"""jit'd public wrapper for the SSD scan kernel (interpret-mode on CPU)."""
from __future__ import annotations

import functools

import jax

from .ssd_scan import ssd_scan_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dA, Bm, Cm, chunk: int = 256):
    """Mamba2 SSD scan; returns (y, None) mirroring ssd_reference's API."""
    y = ssd_scan_pallas(x, dA, Bm, Cm, chunk, interpret=not _on_tpu())
    return y, None
