"""Public wrapper for the SSD scan kernel (registry-dispatched)."""
from __future__ import annotations

import functools

import jax

from ..registry import on_tpu, register, resolve
from .ssd_scan import ssd_scan_pallas


@register("ssd_scan", "pallas")
@functools.partial(jax.jit, static_argnames=("chunk",))
def _ssd_scan_pallas(x, dA, Bm, Cm, chunk: int = 256):
    y = ssd_scan_pallas(x, dA, Bm, Cm, chunk, interpret=not on_tpu())
    return y, None


@register("ssd_scan", "ref")
def _ssd_scan_ref(x, dA, Bm, Cm, chunk: int = 256):
    from .ref import ssd_scan_ref  # lazy: ref pulls in repro.models.mamba2

    return ssd_scan_ref(x, dA, Bm, Cm, chunk), None


def ssd_scan(x, dA, Bm, Cm, chunk: int = 256, engine: str = "auto"):
    """Mamba2 SSD scan; returns (y, None) mirroring ssd_reference's API."""
    return resolve("ssd_scan", engine)(x, dA, Bm, Cm, chunk)
