"""Pure-jnp oracle for the SSD chunk-scan kernel."""
from __future__ import annotations

from ...models.mamba2 import ssd_reference


def ssd_scan_ref(x, dA, Bm, Cm, chunk: int):
    y, _final = ssd_reference(x, dA, Bm, Cm, chunk)
    return y
