"""Public wrapper for the grouped-aggregation kernel (registry-dispatched)."""
from __future__ import annotations

import functools

import jax

from ..registry import on_tpu, register, resolve
from .hash_group import hash_group_minmax_pallas, hash_group_pallas
from .ref import hash_group_minmax_ref, hash_group_ref


@register("hash_group", "pallas")
@functools.partial(jax.jit, static_argnames=("num_groups",))
def _hash_group_pallas(codes, values, num_groups: int):
    return hash_group_pallas(codes, values, num_groups,
                             interpret=not on_tpu())


register("hash_group", "ref", hash_group_ref)


@register("hash_group_minmax", "pallas")
@functools.partial(jax.jit, static_argnames=("num_groups",))
def _hash_group_minmax_pallas(codes, values, num_groups: int):
    return hash_group_minmax_pallas(codes, values, num_groups,
                                    interpret=not on_tpu())


register("hash_group_minmax", "ref", hash_group_minmax_ref)


def hash_group(codes, values, num_groups: int, engine: str = "auto"):
    return resolve("hash_group", engine)(codes, values, num_groups)


def hash_group_minmax(codes, values, num_groups: int, engine: str = "auto"):
    return resolve("hash_group_minmax", engine)(codes, values, num_groups)
