"""jit'd public wrapper for the grouped-aggregation kernel."""
from __future__ import annotations

import functools

import jax

from .hash_group import hash_group_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("num_groups",))
def hash_group(codes, values, num_groups: int):
    return hash_group_pallas(codes, values, num_groups,
                             interpret=not _on_tpu())
