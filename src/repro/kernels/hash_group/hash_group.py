"""Pallas TPU kernel: grouped aggregation as one-hot x MXU matmul.

Hive's hash aggregation has no efficient TPU analogue (no scatter units);
the TPU-native re-think is: for a bounded group domain G, grouped SUM/COUNT
is a dense matmul ``one_hot(codes)^T @ values`` — which the MXU executes at
full rate.  The grid walks row blocks sequentially; the (G_block,) partial
accumulators live in the output block (revisited per row-block), giving an
HBM-resident accumulator only G floats wide.

Shapes are padded to lane multiples (G to 128, rows to the block size).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 2048


def _group_kernel(codes_ref, vals_ref, sums_ref, counts_ref, *, num_groups):
    ri = pl.program_id(0)

    @pl.when(ri == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    codes = codes_ref[...]  # (R,) int32; -1 = masked/padding
    vals = vals_ref[...].astype(jnp.float32)  # (R,)
    onehot = (codes[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (codes.shape[0], num_groups), 1)
              ).astype(jnp.float32)  # (R, G)
    sums_ref[...] += jnp.dot(vals[None, :], onehot,
                             preferred_element_type=jnp.float32)[0]
    counts_ref[...] += jnp.sum(onehot, axis=0)


def _minmax_kernel(codes_ref, vals_ref, mins_ref, maxs_ref, *, num_groups):
    ri = pl.program_id(0)

    @pl.when(ri == 0)
    def _init():
        mins_ref[...] = jnp.full_like(mins_ref, jnp.inf)
        maxs_ref[...] = jnp.full_like(maxs_ref, -jnp.inf)

    codes = codes_ref[...]  # (R,) int32; -1 = masked/padding
    vals = vals_ref[...].astype(jnp.float32)  # (R,)
    onehot = (codes[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (codes.shape[0], num_groups), 1))
    mins_ref[...] = jnp.minimum(
        mins_ref[...], jnp.min(jnp.where(onehot, vals[:, None], jnp.inf), axis=0))
    maxs_ref[...] = jnp.maximum(
        maxs_ref[...], jnp.max(jnp.where(onehot, vals[:, None], -jnp.inf), axis=0))


def hash_group_minmax_pallas(codes, values, num_groups: int,
                             interpret: bool = True):
    """Grouped MIN/MAX as masked one-hot reductions over row blocks.

    codes: (N,) int32 in [0, num_groups); values: (N,) float.
    Returns (mins (G,), maxs (G,)) float32; empty groups hold +/-inf (the
    caller maps them to NULL via group counts).
    """
    n = codes.shape[0]
    g = ((num_groups + 127) // 128) * 128  # lane-align the group domain
    block = min(ROW_BLOCK, max(((n + 7) // 8) * 8, 8))
    pad = (-n) % block
    codes_p = jnp.pad(codes.astype(jnp.int32), (0, pad), constant_values=-1)
    vals_p = jnp.pad(values.astype(jnp.float32), (0, pad))
    grid = ((n + pad) // block,)
    mins, maxs = pl.pallas_call(
        functools.partial(_minmax_kernel, num_groups=g),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((g,), lambda i: (0,)),
            pl.BlockSpec((g,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g,), jnp.float32),
            jax.ShapeDtypeStruct((g,), jnp.float32),
        ],
        interpret=interpret,
    )(codes_p, vals_p)
    return mins[:num_groups], maxs[:num_groups]


def hash_group_pallas(codes, values, num_groups: int, interpret: bool = True):
    """codes: (N,) int32 in [0, num_groups); values: (N,) float.

    Returns (sums (G,), counts (G,)) float32.
    """
    n = codes.shape[0]
    g = ((num_groups + 127) // 128) * 128  # lane-align the group domain
    block = min(ROW_BLOCK, max(((n + 7) // 8) * 8, 8))
    pad = (-n) % block
    codes_p = jnp.pad(codes.astype(jnp.int32), (0, pad), constant_values=-1)
    vals_p = jnp.pad(values.astype(jnp.float32), (0, pad))
    grid = ((n + pad) // block,)
    sums, counts = pl.pallas_call(
        functools.partial(_group_kernel, num_groups=g),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((g,), lambda i: (0,)),
            pl.BlockSpec((g,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g,), jnp.float32),
            jax.ShapeDtypeStruct((g,), jnp.float32),
        ],
        interpret=interpret,
    )(codes_p, vals_p)
    return sums[:num_groups], counts[:num_groups]
