"""Pure-jnp oracle for grouped aggregation."""
from __future__ import annotations

import jax.numpy as jnp


def hash_group_ref(codes, values, num_groups: int):
    valid = codes >= 0
    sums = jnp.zeros(num_groups, jnp.float32).at[
        jnp.where(valid, codes, 0)].add(
        jnp.where(valid, values.astype(jnp.float32), 0.0))
    counts = jnp.zeros(num_groups, jnp.float32).at[
        jnp.where(valid, codes, 0)].add(valid.astype(jnp.float32))
    return sums, counts
