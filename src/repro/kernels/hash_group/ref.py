"""Pure-jnp oracle for grouped aggregation."""
from __future__ import annotations

import jax.numpy as jnp


def hash_group_ref(codes, values, num_groups: int):
    valid = codes >= 0
    sums = jnp.zeros(num_groups, jnp.float32).at[
        jnp.where(valid, codes, 0)].add(
        jnp.where(valid, values.astype(jnp.float32), 0.0))
    counts = jnp.zeros(num_groups, jnp.float32).at[
        jnp.where(valid, codes, 0)].add(valid.astype(jnp.float32))
    return sums, counts


def hash_group_minmax_ref(codes, values, num_groups: int):
    valid = codes >= 0
    safe = jnp.where(valid, codes, 0)
    v = values.astype(jnp.float32)
    mins = jnp.full(num_groups, jnp.inf, jnp.float32).at[safe].min(
        jnp.where(valid, v, jnp.inf))
    maxs = jnp.full(num_groups, -jnp.inf, jnp.float32).at[safe].max(
        jnp.where(valid, v, -jnp.inf))
    return mins, maxs
