"""Pallas TPU kernel: vectorized predicate evaluation (paper §5 / [39]).

Evaluates a conjunction of up to K simple comparisons over K numeric
columns in one fused pass: ``AND_k (col_k OP_k lit_k)``.  This is Hive's
vectorized filter operator mapped onto the TPU VPU: columns stream through
VMEM in (8x128)-aligned blocks and the comparison+AND chain never
materializes intermediate masks in HBM.

Op codes: 0 '<', 1 '<=', 2 '>', 3 '>=', 4 '==', 5 '!='.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024  # rows per program instance (8 sublanes x 128 lanes)


def _cmp(x, op: int, lit: float):
    if op == 0:
        return x < lit
    if op == 1:
        return x <= lit
    if op == 2:
        return x > lit
    if op == 3:
        return x >= lit
    if op == 4:
        return x == lit
    return x != lit


def _filter_kernel(*refs, ops, lits):
    col_refs = refs[:-1]
    out_ref = refs[-1]
    mask = jnp.ones(out_ref.shape, dtype=jnp.bool_)
    for ref, op, lit in zip(col_refs, ops, lits):
        mask &= _cmp(ref[...].astype(jnp.float32), op, lit)
    out_ref[...] = mask


def filter_eval_pallas(columns, ops, lits, interpret: bool = True):
    """columns: list of (N,) float arrays; ops/lits: static tuples.

    Returns (N,) bool mask for the conjunction.
    """
    assert len(columns) == len(ops) == len(lits) and columns
    n = columns[0].shape[0]
    block = min(BLOCK, n)
    pad = (-n) % block
    cols = [jnp.pad(c.astype(jnp.float32), (0, pad),
                    constant_values=jnp.float32(0)) for c in columns]
    grid = ((n + pad) // block,)
    out = pl.pallas_call(
        functools.partial(_filter_kernel, ops=tuple(ops), lits=tuple(lits)),
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)) for _ in cols],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n + pad,), jnp.bool_),
        interpret=interpret,
    )(*cols)
    return out[:n]
