"""Public wrapper for the vectorized filter kernel (registry-dispatched)."""
from __future__ import annotations

import functools

import jax

from ..registry import on_tpu, register, resolve
from .filter_eval import filter_eval_pallas
from .ref import filter_eval_ref


@register("filter_eval", "pallas")
@functools.partial(jax.jit, static_argnames=("ops", "lits"))
def _filter_eval_pallas(columns, ops: tuple, lits: tuple):
    return filter_eval_pallas(list(columns), ops, lits,
                              interpret=not on_tpu())


register("filter_eval", "ref", filter_eval_ref)


def filter_eval(columns, ops: tuple, lits: tuple, engine: str = "auto"):
    return resolve("filter_eval", engine)(columns, ops, lits)
