"""jit'd public wrapper for the vectorized filter kernel."""
from __future__ import annotations

import functools

import jax

from .filter_eval import filter_eval_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("ops", "lits"))
def filter_eval(columns, ops: tuple, lits: tuple):
    return filter_eval_pallas(list(columns), ops, lits,
                              interpret=not _on_tpu())
