"""Pure-jnp oracle for vectorized predicate evaluation."""
from __future__ import annotations

import jax.numpy as jnp

_OPS = {
    0: lambda x, l: x < l,
    1: lambda x, l: x <= l,
    2: lambda x, l: x > l,
    3: lambda x, l: x >= l,
    4: lambda x, l: x == l,
    5: lambda x, l: x != l,
}


def filter_eval_ref(columns, ops, lits):
    mask = jnp.ones(columns[0].shape, dtype=bool)
    for c, op, lit in zip(columns, ops, lits):
        mask &= _OPS[op](c.astype(jnp.float32), jnp.float32(lit))
    return mask
