# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# Backend selection (pallas vs ref) is centralized in .registry;
# session config `engine: auto|pallas|ref` picks per query.
from .registry import VALID_ENGINES, backends, kernels, on_tpu, register, resolve  # noqa: F401
