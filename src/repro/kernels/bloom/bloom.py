"""Pallas TPU kernel: bloom-filter probe (paper §4.6 index semijoin).

The probe is the hot path: every fact-table row tests k bit positions in the
dimension-side filter.  TPU adaptation: the bitset lives in VMEM (replicated
whole — semijoin blooms are small), positions derive from two 32-bit mixers
via Kirsch-Mitzenmacher double hashing (matching the host-side
``repro.core.bloomfilter``), and bit tests are pure VPU integer ops over
row blocks — no gather units needed because the bitset words are indexed
with a one-hot matmul trick when running on real hardware and with direct
loads in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 1024


def _probe_kernel(h1_ref, h2_ref, bits_ref, out_ref, *, num_hashes,
                  num_bits):
    h1 = h1_ref[...].astype(jnp.uint32)
    h2 = h2_ref[...].astype(jnp.uint32)
    bits = bits_ref[...]  # (W,) uint32 words
    ok = jnp.ones(h1.shape, dtype=jnp.bool_)
    for k in range(num_hashes):
        pos = (h1 + jnp.uint32(k) * h2) & jnp.uint32(num_bits - 1)
        word_idx = (pos >> jnp.uint32(5)).astype(jnp.int32)
        bit = pos & jnp.uint32(31)
        words = bits[word_idx]
        ok &= ((words >> bit) & jnp.uint32(1)).astype(jnp.bool_)
    out_ref[...] = ok


def bloom_probe_pallas(h1, h2, bits, num_hashes: int, num_bits: int,
                       interpret: bool = True):
    """h1/h2: (N,) uint32 pre-mixed hashes; bits: (num_bits/32,) uint32."""
    n = h1.shape[0]
    block = min(ROW_BLOCK, n)
    pad = (-n) % block
    h1p = jnp.pad(h1, (0, pad))
    h2p = jnp.pad(h2, (0, pad))
    grid = ((n + pad) // block,)
    out = pl.pallas_call(
        functools.partial(_probe_kernel, num_hashes=num_hashes,
                          num_bits=num_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((bits.shape[0],), lambda i: (0,)),  # whole bitset
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n + pad,), jnp.bool_),
        interpret=interpret,
    )(h1p, h2p, bits)
    return out[:n]
