"""Public wrapper + host-side bridge for the bloom-probe kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ...core.bloomfilter import BloomFilter, hash_values
from ..registry import on_tpu, register, resolve
from .bloom import bloom_probe_pallas
from .ref import bloom_probe_ref


@register("bloom_probe", "pallas")
@functools.partial(jax.jit, static_argnames=("num_hashes", "num_bits"))
def _bloom_probe_pallas(h1, h2, bits, num_hashes: int, num_bits: int):
    return bloom_probe_pallas(h1, h2, bits, num_hashes, num_bits,
                              interpret=not on_tpu())


register("bloom_probe", "ref", bloom_probe_ref)


def bloom_probe(h1, h2, bits, num_hashes: int, num_bits: int,
                engine: str = "auto"):
    return resolve("bloom_probe", engine)(h1, h2, bits, num_hashes, num_bits)


def probe_bloom_filter(bf: BloomFilter, values: np.ndarray,
                       engine: str = "auto") -> np.ndarray:
    """Probe a core.bloomfilter.BloomFilter via the TPU kernel path."""
    h = hash_values(values)
    h1 = jnp.asarray((h & np.uint64(0xFFFFFFFF)).astype(np.uint32))
    h2 = jnp.asarray((h >> np.uint64(32)).astype(np.uint32))
    bits32 = jnp.asarray(bf.bits.view(np.uint32))
    return np.asarray(
        bloom_probe(h1, h2, bits32, bf.num_hashes, bf.num_bits, engine=engine)
    )
