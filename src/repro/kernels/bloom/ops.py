"""jit'd public wrapper + host-side bridge for the bloom-probe kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ...core.bloomfilter import BloomFilter, hash_values
from .bloom import bloom_probe_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("num_hashes", "num_bits"))
def bloom_probe(h1, h2, bits, num_hashes: int, num_bits: int):
    return bloom_probe_pallas(h1, h2, bits, num_hashes, num_bits,
                              interpret=not _on_tpu())


def probe_bloom_filter(bf: BloomFilter, values: np.ndarray) -> np.ndarray:
    """Probe a core.bloomfilter.BloomFilter via the TPU kernel path."""
    h = hash_values(values)
    h1 = jnp.asarray((h & np.uint64(0xFFFFFFFF)).astype(np.uint32))
    h2 = jnp.asarray((h >> np.uint64(32)).astype(np.uint32))
    bits32 = jnp.asarray(bf.bits.view(np.uint32))
    return np.asarray(bloom_probe(h1, h2, bits32, bf.num_hashes, bf.num_bits))
