"""Pure-jnp oracle for the bloom probe (mirrors core.bloomfilter)."""
from __future__ import annotations

import jax.numpy as jnp


def bloom_probe_ref(h1, h2, bits, num_hashes: int, num_bits: int):
    ok = jnp.ones(h1.shape, dtype=bool)
    for k in range(num_hashes):
        pos = (h1.astype(jnp.uint32) + jnp.uint32(k) * h2.astype(jnp.uint32)) \
            & jnp.uint32(num_bits - 1)
        word = bits[(pos >> jnp.uint32(5)).astype(jnp.int32)]
        ok &= ((word >> (pos & jnp.uint32(31))) & jnp.uint32(1)).astype(bool)
    return ok
