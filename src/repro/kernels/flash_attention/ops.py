"""Public wrapper for causal flash attention (registry-dispatched)."""
from __future__ import annotations

import functools

import jax

from ..registry import on_tpu, register, resolve
from .flash_attention import flash_attention_pallas
from .ref import attention_ref


@register("flash_attention", "pallas")
@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def _flash_attention_pallas(q, k, v, causal: bool = True, block_q: int = 128,
                            block_k: int = 128):
    return flash_attention_pallas(q, k, v, causal=causal, block_q=block_q,
                                  block_k=block_k, interpret=not on_tpu())


@register("flash_attention", "ref")
def _flash_attention_ref(q, k, v, causal: bool = True, block_q: int = 128,
                         block_k: int = 128):
    del block_q, block_k  # exact oracle has no tiling
    return attention_ref(q, k, v, causal=causal)


def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, engine: str = "auto"):
    return resolve("flash_attention", engine)(q, k, v, causal=causal,
                                              block_q=block_q, block_k=block_k)
