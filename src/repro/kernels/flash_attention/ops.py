"""jit'd public wrapper for causal flash attention."""
from __future__ import annotations

import functools

import jax

from .flash_attention import flash_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    return flash_attention_pallas(q, k, v, causal=causal, block_q=block_q,
                                  block_k=block_k, interpret=not _on_tpu())
