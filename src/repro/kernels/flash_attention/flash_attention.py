"""Pallas TPU kernel: causal flash attention (online softmax).

Grid: (batch*heads, q_blocks, k_blocks), k innermost/sequential.  Running
max/denominator/accumulator live in VMEM scratch; (Bq, Bk) score tiles never
leave the chip — this is the kernel that removes the attention-score HBM
traffic that dominates the naive baseline's memory roofline term.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, scale: float, block_q: int, block_k: int,
                  seq_len: int, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # (Bq, d)
    k = k_ref[0].astype(jnp.float32)  # (Bk, d)
    v = v_ref[0].astype(jnp.float32)  # (Bk, d)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (Bq, Bk)
    if causal:
        qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

    m_prev = m_scr[...]  # (Bq, 1)
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)  # (Bq, Bk)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _emit():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(
            o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True):
    """q/k/v: (B, H, S, d) -> (B, H, S, d). MHA (same head counts)."""
    B, H, S, d = q.shape
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    scale = 1.0 / np.sqrt(d)
    qg = q.reshape(B * H, S, d)
    kg = k.reshape(B * H, S, d)
    vg = v.reshape(B * H, S, d)
    grid = (B * H, S // block_q, S // block_k)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, block_q=block_q,
                          block_k=block_k, seq_len=S, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),  # running max
            pltpu.VMEM((block_q, 1), jnp.float32),  # running denom
            pltpu.VMEM((block_q, d), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qg, kg, vg)
    return out.reshape(B, H, S, d)
