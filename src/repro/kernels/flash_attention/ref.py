"""Pure-jnp oracle: exact causal softmax attention."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import jax


def attention_ref(q, k, v, causal: bool = True):
    """q/k/v: (B, H, S, d)."""
    d = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(d)
    if causal:
        S = q.shape[2]
        mask = jnp.tril(jnp.ones((S, S), dtype=bool))
        s = jnp.where(mask[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32)).astype(q.dtype)
