"""Public wrapper for the join-key lookup kernel (registry-dispatched)."""
from __future__ import annotations

import jax

from ..registry import on_tpu, register, resolve
from .key_lookup import key_lookup_pallas
from .ref import key_lookup_ref


@register("key_lookup", "pallas")
@jax.jit
def _key_lookup_pallas(sorted_vals, probe):
    return key_lookup_pallas(sorted_vals, probe, interpret=not on_tpu())


register("key_lookup", "ref", key_lookup_ref)


def key_lookup(sorted_vals, probe, engine: str = "auto"):
    """Map probe values to positions in a sorted dictionary (-1 = miss)."""
    return resolve("key_lookup", engine)(sorted_vals, probe)
