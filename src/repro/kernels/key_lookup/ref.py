"""Pure-jnp oracle for the join-key dictionary lookup."""
from __future__ import annotations

import jax.numpy as jnp


def key_lookup_ref(sorted_vals, probe):
    g = sorted_vals.shape[0]
    if g == 0:
        return jnp.full(probe.shape, -1, dtype=jnp.int32)
    idx = jnp.searchsorted(sorted_vals, probe)
    found = (idx < g) & (sorted_vals[jnp.minimum(idx, g - 1)] == probe)
    return jnp.where(found, idx, -1).astype(jnp.int32)
