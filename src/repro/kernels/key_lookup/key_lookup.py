"""Pallas TPU kernel: join-key dictionary lookup (factorized hash-join probe).

The streaming hash join dictionary-encodes the build side's key columns once
(sorted uniques); every probe morsel then maps its key values into build
codes.  The TPU-native re-think of that hash lookup is a *vectorized binary
search*: the sorted dictionary is replicated into VMEM (join-key
dictionaries are small — bounded by the build side's distinct keys) and each
probe block runs ``ceil(log2(G))`` gather/compare steps on the VPU, the same
direct-load idiom the bloom-probe kernel uses for its bitset words.

Returns, per probe value, the dictionary position of an exact match or -1 —
i.e. ``searchsorted`` + equality in one fused kernel.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 1024


def _lookup_kernel(sorted_ref, probe_ref, out_ref, *, n_real, steps):
    svals = sorted_ref[...]  # (G,) float32, padded with +inf
    probe = probe_ref[...]   # (B,) float32
    lo = jnp.zeros(probe.shape, jnp.int32)
    hi = jnp.full(probe.shape, n_real, jnp.int32)
    for _ in range(steps):  # static unrolled binary search
        mid = (lo + hi) // 2
        go_right = svals[mid] < probe
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right, hi, mid)
    # lo == leftmost insertion point; an exact hit sits right there
    probe_at = svals[jnp.minimum(lo, n_real - 1)]
    found = (lo < n_real) & (probe_at == probe)
    out_ref[...] = jnp.where(found, lo, -1)


def key_lookup_pallas(sorted_vals, probe, interpret: bool = True):
    """sorted_vals: (G,) float32 ascending (no NaN); probe: (N,) float32.

    Returns (N,) int32: index of the exact match in ``sorted_vals``, or -1.
    """
    g = sorted_vals.shape[0]
    n = probe.shape[0]
    if g == 0 or n == 0:
        return jnp.full((n,), -1, dtype=jnp.int32)
    gpad = ((g + 127) // 128) * 128  # lane-align the dictionary
    svals_p = jnp.pad(sorted_vals.astype(jnp.float32), (0, gpad - g),
                      constant_values=jnp.inf)
    block = min(ROW_BLOCK, max(((n + 7) // 8) * 8, 8))
    pad = (-n) % block
    probe_p = jnp.pad(probe.astype(jnp.float32), (0, pad))
    steps = max(1, math.ceil(math.log2(g + 1)))
    out = pl.pallas_call(
        functools.partial(_lookup_kernel, n_real=g, steps=steps),
        grid=((n + pad) // block,),
        in_specs=[
            pl.BlockSpec((gpad,), lambda i: (0,)),  # whole dictionary
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n + pad,), jnp.int32),
        interpret=interpret,
    )(svals_p, probe_p)
    return out[:n]
