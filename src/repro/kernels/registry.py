"""Engine registry: resolves ``pallas`` vs ``ref`` kernel backends.

Every kernel package registers its implementations here under a stable
kernel name (``filter_eval``, ``hash_group``, ``hash_group_minmax``,
``bloom_probe``, ``key_lookup``, ``ssd_scan``, ``flash_attention``).
Callers resolve a backend by name + engine selector:

  * ``auto``   — the Pallas implementation (interpret mode off-TPU), i.e. the
                 historical default previously encoded as per-file
                 ``_on_tpu()`` checks;
  * ``pallas`` — force the Pallas kernel;
  * ``ref``    — force the pure-jnp oracle (useful for A/B-ing numerics and
                 for hosts where Pallas lowering is unavailable).

The session config key ``engine`` selects the backend per query and is
threaded through ``ExecContext`` (see ``repro.core.runtime.exec``).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

VALID_ENGINES = ("auto", "pallas", "ref")

_REGISTRY: Dict[str, Dict[str, Callable]] = {}

# process-wide dispatch tally per "kernel[backend]" — plain int bumps under
# the GIL (resolve is not a per-morsel path); surfaced through
# ``Connection.metrics()`` gauges and dispatch_counts()
_DISPATCHES: Dict[str, int] = {}


def dispatch_counts() -> Dict[str, int]:
    """Snapshot of per-(kernel, backend) resolve() counts this process."""
    return dict(_DISPATCHES)


def on_tpu() -> bool:
    """Single authority for the TPU check (was duplicated per ops.py)."""
    import jax  # lazy: lets jax-free paths import VALID_ENGINES cheaply

    return jax.default_backend() == "tpu"


def validate_engine(engine: str) -> str:
    if engine not in VALID_ENGINES:
        raise ValueError(
            f"unknown engine {engine!r}; expected one of {VALID_ENGINES}"
        )
    return engine


def register(kernel: str, backend: str, fn: Optional[Callable] = None):
    """Register an implementation; usable directly or as a decorator."""
    if backend not in ("pallas", "ref"):
        raise ValueError(f"backend must be 'pallas' or 'ref', got {backend!r}")

    def _do(f: Callable) -> Callable:
        _REGISTRY.setdefault(kernel, {})[backend] = f
        return f

    return _do(fn) if fn is not None else _do


def backends(kernel: str):
    if kernel not in _REGISTRY:
        _import_all()
    return tuple(sorted(_REGISTRY.get(kernel, {})))


def kernels():
    return tuple(sorted(_REGISTRY))


def resolve(kernel: str, engine: str = "auto") -> Callable:
    """Return the implementation of ``kernel`` for ``engine``."""
    validate_engine(engine)
    impls = _REGISTRY.get(kernel)
    if impls is None:
        # kernel packages self-register on import; pull them in lazily so
        # `resolve` works without callers importing repro.kernels.* first
        _import_all()
        impls = _REGISTRY.get(kernel)
        if impls is None:
            raise KeyError(f"no kernel registered under {kernel!r}; "
                           f"have {kernels()}")
    backend = "pallas" if engine == "auto" else engine
    if backend not in impls:
        raise KeyError(f"kernel {kernel!r} has no {backend!r} backend; "
                       f"have {backends(kernel)}")
    key = f"{kernel}[{backend}]"
    _DISPATCHES[key] = _DISPATCHES.get(key, 0) + 1
    return impls[backend]


def _import_all() -> None:
    import importlib

    for pkg in ("filter_eval", "hash_group", "bloom", "ssd_scan",
                "flash_attention", "key_lookup", "hash_partition"):
        importlib.import_module(f"repro.kernels.{pkg}.ops")
