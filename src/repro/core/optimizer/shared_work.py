"""Shared work optimization (paper §4.5).

Rather than searching for semantically equivalent subexpressions, Hive's
shared-work optimizer *merges equal parts of the plan* right before
execution: identical scans first, then identical operator prefixes above
them.  We implement the same reuse-based idea structurally: every subtree is
identified by its canonical key; keys that occur more than once are marked as
shared, and the executor computes them once and reuses the result (the
"shared edge" decision is left to the runtime, as the paper leaves it to
Tez).
"""
from __future__ import annotations

from collections import Counter
from typing import Dict, List, Set, Tuple

from . import plan as P


def find_shared_subplans(plan: P.PlanNode, min_occurrences: int = 2) -> Set[str]:
    """Return canonical keys of subtrees that appear multiple times.

    Only maximal shared subtrees are returned: if an entire join appears
    twice, its scans are not separately marked (reusing the larger result
    subsumes the smaller).
    """
    counts: Counter = Counter()
    nodes_by_key: Dict[str, P.PlanNode] = {}

    def visit(node: P.PlanNode):
        key = node.key()
        counts[key] += 1
        nodes_by_key[key] = node
        for c in node.inputs:
            visit(c)
        if isinstance(node, P.Scan):
            for rf in node.runtime_filters:
                visit(rf.producer)

    visit(plan)
    shared = {k for k, c in counts.items() if c >= min_occurrences}

    # keep only maximal shared subtrees
    maximal = set(shared)
    for k in shared:
        node = nodes_by_key[k]
        for child in _descendants(node):
            ck = child.key()
            if ck in maximal and counts[ck] == counts[k]:
                maximal.discard(ck)
    return maximal


def _descendants(node: P.PlanNode):
    for c in node.inputs:
        yield c
        yield from _descendants(c)


def shared_work_summary(plan: P.PlanNode) -> List[Tuple[str, int]]:
    counts: Counter = Counter()

    def visit(node):
        counts[node.describe()] += 1
        for c in node.inputs:
            visit(c)

    visit(plan)
    return [(k, v) for k, v in counts.items() if v > 1]
