"""Cardinality estimation & cost model (paper §4.1 "Statistics").

Estimates flow from HMS statistics: row counts, min/max ranges, and HLL++
NDV sketches.  Runtime-captured actuals (paper §4.2) can be layered on top as
``overrides`` keyed by plan-node digest — that is exactly what the
re-optimization path feeds back after an execution error.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

from ..metastore import Metastore
from ..sql import ast as A
from . import plan as P

DEFAULT_EQ_SELECTIVITY = 0.1
DEFAULT_RANGE_SELECTIVITY = 1.0 / 3.0
DEFAULT_LIKE_SELECTIVITY = 0.25


class ColumnInfo:
    __slots__ = ("ndv", "min", "max", "rows")

    def __init__(self, ndv=None, min=None, max=None, rows=None):
        self.ndv = ndv
        self.min = min
        self.max = max
        self.rows = rows


class Estimate:
    def __init__(self, rows: float, columns: Dict[str, ColumnInfo]):
        self.rows = max(rows, 0.0)
        self.columns = columns

    def col(self, name: str) -> ColumnInfo:
        return self.columns.get(name, ColumnInfo())

    def scaled(self, sel: float) -> "Estimate":
        rows = self.rows * sel
        cols = {
            k: ColumnInfo(
                ndv=min(v.ndv, rows) if v.ndv is not None else None,
                min=v.min, max=v.max, rows=rows,
            )
            for k, v in self.columns.items()
        }
        return Estimate(rows, cols)


class CostModel:
    def __init__(self, hms: Metastore,
                 overrides: Optional[Dict[str, float]] = None,
                 handler_resolver=None):
        self.hms = hms
        self.overrides = overrides or {}
        # resolves a TableDesc.handler name to a connector so federated
        # scans can be costed on the connector's remote row-count/NDV
        # estimates instead of the empty-stats default (§6)
        self.handler_resolver = handler_resolver
        self._stats_cache: Dict[str, object] = {}

    # -- public ---------------------------------------------------------------
    def estimate(self, node: P.PlanNode) -> Estimate:
        est = self._estimate(node)
        if node.digest() in self.overrides:  # runtime actuals win (§4.2)
            actual = self.overrides[node.digest()]
            if est.rows > 0:
                est = est.scaled(actual / est.rows)
            else:
                est = Estimate(actual, est.columns)
        return est

    def cost(self, node: P.PlanNode) -> float:
        """CPU+shuffle cost proxy: sum of intermediate result sizes."""
        total = self.estimate(node).rows
        for child in node.inputs:
            total += self.cost(child)
        if isinstance(node, P.Join):
            total += self.estimate(node.right).rows * 0.5  # build cost
        if isinstance(node, P.Sort):
            r = self.estimate(node.input).rows
            total += r * max(math.log2(max(r, 2)), 1) * 0.1
        return total

    # -- internals --------------------------------------------------------------
    def _table_stats(self, name: str, node: Optional[P.PlanNode] = None):
        if name not in self._stats_cache:
            from ..stats import TableStats

            try:
                stats = self.hms.get_stats(name)
            except KeyError:
                # catalog-mounted external table: no HMS stats (§6)
                stats = TableStats()
            if (isinstance(node, P.FederatedScan)
                    and not getattr(stats, "row_count", 0)):
                # external data never flowed through local writes, so HMS
                # stats are empty: ask the connector for remote estimates
                stats = self._remote_stats(node) or stats
            self._stats_cache[name] = stats
        return self._stats_cache[name]

    def _remote_stats(self, node: P.FederatedScan):
        if self.handler_resolver is None:
            return None
        try:
            handler = self.handler_resolver(node.table.handler)
            if handler is None:
                return None
            return handler.scan_builder(node.table, {}).estimate_stats()
        except Exception:  # noqa: BLE001 - stats must never break planning
            return None

    def _estimate(self, node: P.PlanNode) -> Estimate:
        if isinstance(node, (P.Scan, P.FederatedScan)):
            ts = self._table_stats(node.table.name, node)
            cols = {}
            for c, cs in ts.columns.items():
                cols[f"{node.alias}.{c}"] = ColumnInfo(
                    ndv=cs.ndv or None, min=cs.min_value, max=cs.max_value,
                    rows=ts.row_count,
                )
            est = Estimate(ts.row_count or 1.0, cols)
            pf = getattr(node, "pushed_filter", None)
            if pf is not None:
                est = est.scaled(self.selectivity(pf, est, alias=node.alias))
            pp = getattr(node, "partition_filter", None)
            if pp is not None:
                est = est.scaled(self.selectivity(pp, est, alias=node.alias))
            for rf in getattr(node, "runtime_filters", []) or []:
                est = est.scaled(0.5)
            return est
        if isinstance(node, P.Filter):
            child = self.estimate(node.input)
            return child.scaled(self.selectivity(node.predicate, child))
        if isinstance(node, P.Project):
            child = self.estimate(node.input)
            cols = {}
            for e, n in node.exprs:
                if isinstance(e, A.Col):
                    cols[n] = child.col(e.qualified)
                else:
                    cols[n] = ColumnInfo(rows=child.rows)
            return Estimate(child.rows, cols)
        if isinstance(node, P.Join):
            left = self.estimate(node.left)
            right = self.estimate(node.right)
            cols = {**left.columns, **right.columns}
            if node.kind == "cross" and not node.left_keys:
                return Estimate(left.rows * right.rows, cols)
            sel = 1.0
            for lk, rk in zip(node.left_keys, node.right_keys):
                nl = left.col(lk).ndv or max(left.rows * 0.1, 1)
                nr = right.col(rk).ndv or max(right.rows * 0.1, 1)
                sel /= max(nl, nr, 1.0)
            rows = left.rows * right.rows * sel
            if node.kind in ("semi", "anti"):
                match_frac = min(1.0, rows / max(left.rows, 1e-9))
                rows = left.rows * (
                    match_frac if node.kind == "semi" else (1 - match_frac)
                )
                cols = left.columns
            if node.kind == "left":
                rows = max(rows, left.rows)
            if node.residual is not None:
                rows *= DEFAULT_RANGE_SELECTIVITY
            return Estimate(rows, cols)
        if isinstance(node, P.Aggregate):
            child = self.estimate(node.input)
            if not node.group_keys:
                return Estimate(1.0, {a.out_name: ColumnInfo(rows=1) for a in node.aggs})
            ndv = 1.0
            for k in node.group_keys:
                ndv *= child.col(k).ndv or max(child.rows ** 0.5, 1)
            rows = min(ndv, child.rows)
            cols = {k: child.col(k) for k in node.group_keys}
            for a in node.aggs:
                cols[a.out_name] = ColumnInfo(rows=rows)
            if node.grouping_sets:
                rows *= len(node.grouping_sets)
            return Estimate(rows, cols)
        if isinstance(node, P.WindowOp):
            child = self.estimate(node.input)
            cols = dict(child.columns)
            for _, n in node.funcs:
                cols[n] = ColumnInfo(rows=child.rows)
            return Estimate(child.rows, cols)
        if isinstance(node, (P.Sort,)):
            return self.estimate(node.input)
        if isinstance(node, P.ShuffleRead):
            # one hash lane of the source stream: 1/N of its rows, so plans
            # stacked above partition-expanded consumers (an aggregation
            # over an expanded join) still cost on real cardinalities
            child = self.estimate(node.source)
            return child.scaled(1.0 / max(node.num_partitions, 1))
        if isinstance(node, P.Limit):
            child = self.estimate(node.input)
            return child.scaled(min(1.0, node.n / max(child.rows, 1)))
        if isinstance(node, P.Union):
            ests = [self.estimate(i) for i in node.inputs]
            rows = sum(e.rows for e in ests)
            return Estimate(rows, ests[0].columns if ests else {})
        if isinstance(node, P.ValuesNode):
            return Estimate(len(node.rows), {n: ColumnInfo() for n in node.names})
        return Estimate(1000.0, {})

    # -- selectivity -----------------------------------------------------------
    def selectivity(self, pred: A.Expr, est: Estimate, alias: Optional[str] = None) -> float:
        def colinfo(c: A.Col) -> ColumnInfo:
            name = c.qualified
            if c.table is None and alias is not None:
                name = f"{alias}.{c.name}"
            return est.col(name)

        def sel(e: A.Expr) -> float:
            if isinstance(e, A.BinOp):
                if e.op == "AND":
                    return sel(e.left) * sel(e.right)
                if e.op == "OR":
                    return min(1.0, sel(e.left) + sel(e.right))
                col, lit = None, None
                if isinstance(e.left, A.Col) and isinstance(e.right, A.Lit):
                    col, lit, op = e.left, e.right.value, e.op
                elif isinstance(e.right, A.Col) and isinstance(e.left, A.Lit):
                    flip = {"<": ">", "<=": ">=", ">": "<", ">=": "<=",
                            "=": "=", "!=": "!="}
                    col, lit, op = e.right, e.left.value, flip.get(e.op, e.op)
                if col is not None:
                    ci = colinfo(col)
                    if op == "=":
                        return 1.0 / ci.ndv if ci.ndv else DEFAULT_EQ_SELECTIVITY
                    if op == "!=":
                        return 1.0 - (1.0 / ci.ndv if ci.ndv else DEFAULT_EQ_SELECTIVITY)
                    if op in ("<", "<=", ">", ">=") and _numeric(ci.min) and _numeric(ci.max) and _numeric(lit):
                        span = float(ci.max) - float(ci.min)
                        if span <= 0:
                            return DEFAULT_RANGE_SELECTIVITY
                        if op in ("<", "<="):
                            return _clip((float(lit) - float(ci.min)) / span)
                        return _clip((float(ci.max) - float(lit)) / span)
                    return DEFAULT_RANGE_SELECTIVITY
                if e.op == "LIKE":
                    return DEFAULT_LIKE_SELECTIVITY
                return DEFAULT_RANGE_SELECTIVITY
            if isinstance(e, A.UnOp) and e.op == "NOT":
                return 1.0 - sel(e.operand)
            if isinstance(e, A.InList) and isinstance(e.expr, A.Col):
                ci = colinfo(e.expr)
                s = len(e.values) / ci.ndv if ci.ndv else DEFAULT_EQ_SELECTIVITY * len(e.values)
                s = _clip(s)
                return 1.0 - s if e.negated else s
            if isinstance(e, A.Between) and isinstance(e.expr, A.Col):
                ci = colinfo(e.expr)
                if (
                    _numeric(ci.min) and _numeric(ci.max)
                    and isinstance(e.low, A.Lit) and isinstance(e.high, A.Lit)
                    and _numeric(e.low.value) and _numeric(e.high.value)
                ):
                    span = float(ci.max) - float(ci.min)
                    if span > 0:
                        s = _clip((float(e.high.value) - float(e.low.value)) / span)
                        return 1.0 - s if e.negated else s
                return DEFAULT_RANGE_SELECTIVITY
            if isinstance(e, A.IsNull):
                return 0.05 if not e.negated else 0.95
            return DEFAULT_RANGE_SELECTIVITY

        return _clip(sel(pred))


def _numeric(x) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def _clip(x: float) -> float:
    return min(1.0, max(1e-6, x))
