"""Dynamic semijoin reduction (paper §4.6).

For star joins ``fact JOIN dim ON fact.k = dim.k`` where the dimension side
carries a selective filter, the optimizer attaches a *semijoin reducer* to
the fact-table scan:

  * **dynamic partition pruning** when the fact table is partitioned by the
    join column — partition directories are skipped while the query runs;
  * **index semijoin** otherwise — a min/max range + Bloom filter built from
    the dimension values is pushed into the fact scan, skipping whole row
    groups (ORC-style) and filtering rows.

The reducer's producer subplan is executed first by the DAG scheduler (it
becomes an upstream vertex), exactly like Hive/Tez ships bloom filters from
the dimension vertex to fact-table mappers.
"""
from __future__ import annotations

from typing import Optional, Tuple

from ..sql import ast as A
from . import plan as P
from .cost import CostModel


class SemijoinConfig:
    def __init__(self, enabled: bool = True, max_producer_rows: float = 500_000.0,
                 min_fact_ratio: float = 2.0):
        self.enabled = enabled
        self.max_producer_rows = max_producer_rows
        self.min_fact_ratio = min_fact_ratio


def insert_semijoin_reducers(
    plan: P.PlanNode, cost_model: CostModel, cfg: Optional[SemijoinConfig] = None
) -> int:
    """Mutates the plan, attaching RuntimeFilterSpecs; returns #reducers added."""
    cfg = cfg or SemijoinConfig()
    if not cfg.enabled:
        return 0
    added = 0

    for node in list(P.walk_plan(plan)):
        if not isinstance(node, P.Join) or node.kind not in ("inner", "semi"):
            continue
        for lk, rk, fact_side, dim_side in _both_orientations(node):
            dim_est = cost_model.estimate(dim_side)
            fact_est = cost_model.estimate(fact_side)
            if dim_est.rows > cfg.max_producer_rows:
                continue
            if fact_est.rows < dim_est.rows * cfg.min_fact_ratio:
                continue
            if not _is_selective(dim_side):
                continue
            hit = _resolve_to_scan(fact_side, lk)
            if hit is None:
                continue
            scan, raw_col = hit
            producer = _producer_plan(dim_side, rk)
            if producer is None:
                continue
            kind = (
                "partition"
                if raw_col in scan.table.partition_cols
                else "index"
            )
            spec = P.RuntimeFilterSpec(producer, rk, raw_col, kind)
            if any(r.key() == spec.key() for r in scan.runtime_filters):
                continue
            scan.runtime_filters.append(spec)
            added += 1
    return added


def _both_orientations(join: P.Join):
    for lk, rk in zip(join.left_keys, join.right_keys):
        yield lk, rk, join.left, join.right
        yield rk, lk, join.right, join.left


def _is_selective(node: P.PlanNode) -> bool:
    """The dimension side must actually be filtered for a reducer to help."""
    for n in P.walk_plan(node):
        if isinstance(n, P.Filter):
            return True
        if isinstance(n, P.Scan) and (n.pushed_filter or n.partition_filter):
            return True
        if isinstance(n, P.Aggregate):
            return True
    return False


def _resolve_to_scan(node: P.PlanNode, qualified: str) -> Optional[Tuple[P.Scan, str]]:
    """Trace a qualified column down to the Scan producing it."""
    if isinstance(node, P.Scan):
        alias_prefix = node.alias + "."
        if qualified.startswith(alias_prefix):
            raw = qualified[len(alias_prefix):]
            if raw in node.columns or raw in node.table.partition_cols:
                return node, raw
        return None
    if isinstance(node, P.Project):
        for e, n in node.exprs:
            if n == qualified:
                if isinstance(e, A.Col):
                    return _resolve_to_scan(node.input, e.qualified)
                return None
        return None
    if isinstance(node, P.Join):
        for side in node.inputs:
            if qualified in side.output_names():
                return _resolve_to_scan(side, qualified)
        return None
    if isinstance(node, (P.Filter, P.Sort, P.Limit)):
        return _resolve_to_scan(node.inputs[0], qualified)
    return None


def _producer_plan(dim_side: P.PlanNode, key: str) -> Optional[P.PlanNode]:
    if key not in dim_side.output_names():
        return None
    from ..sql.binder import _base, _qual

    proj = P.Project(dim_side, [(A.Col(_base(key), _qual(key)), key)])
    return P.Aggregate(proj, [key], [])  # distinct values only
