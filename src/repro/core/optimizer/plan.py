"""Logical/physical plan algebra (paper §2 Fig. 2, §4).

One algebra serves both roles (Calcite-style): the optimizer rewrites these
nodes, then the task compiler (core/runtime/dag.py) breaks the tree into a
DAG of executable tasks at exchange boundaries.

Column naming convention: every node's ``output_names`` is a list of unique
strings; bound `Col` expressions reference them by qualified name
(``alias.column``) and projections introduce new names.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from ..metastore import TableDesc
from ..sql import ast as A


class PlanNode:
    inputs: List["PlanNode"] = []
    # inferred output schema (repro.core.schema.Schema), attached by the
    # binder / pipeline via annotate_plan; None = not (re)inferred yet.
    # Deliberately NOT part of key()/digest(): schema is derived metadata.
    schema = None

    def output_names(self) -> List[str]:
        raise NotImplementedError

    def key(self) -> str:
        """Structural identity — drives shared-work merging and result cache."""
        raise NotImplementedError

    def digest(self) -> str:
        return hashlib.blake2b(self.key().encode(), digest_size=8).hexdigest()

    def pretty(self, indent: int = 0) -> str:
        head = " " * indent + self.describe()
        lines = [head]
        if self.schema is not None:
            lines.append(" " * indent + "  schema: " + self.schema.describe())
        return "\n".join(lines + [c.pretty(indent + 2) for c in self.inputs])

    def describe(self) -> str:
        return type(self).__name__


@dataclass
class RuntimeFilterSpec:
    """Dynamic semijoin reducer (paper §4.6) attached to a Scan.

    ``producer`` is a plan subtree emitting the filter column; at runtime the
    DAG executes it first and ships {bloom, min/max, value set} to the scan.
    """

    producer: PlanNode
    producer_column: str
    target_column: str  # raw column name in the scanned table
    kind: str  # 'partition' (dynamic partition pruning) or 'index' (bloom+minmax)

    def key(self) -> str:
        return f"rf({self.producer.key()},{self.producer_column},{self.target_column},{self.kind})"


class Scan(PlanNode):
    def __init__(
        self,
        table: TableDesc,
        alias: str,
        columns: Optional[List[str]] = None,  # raw column names to read
        pushed_filter: Optional[A.Expr] = None,  # over raw (unqualified) cols
        partition_filter: Optional[A.Expr] = None,
        runtime_filters: Optional[List[RuntimeFilterSpec]] = None,
        min_writeid: Optional[int] = None,  # incremental MV rebuild reads (§4.4)
    ):
        self.table = table
        self.alias = alias
        self.columns = columns or [c for c, _ in table.schema]
        self.pushed_filter = pushed_filter
        self.partition_filter = partition_filter
        self.runtime_filters = runtime_filters or []
        self.min_writeid = min_writeid
        self.inputs = []

    def output_names(self) -> List[str]:
        return [f"{self.alias}.{c}" for c in self.columns]

    def key(self) -> str:
        pf = self.pushed_filter.key() if self.pushed_filter else ""
        pp = self.partition_filter.key() if self.partition_filter else ""
        rf = ",".join(r.key() for r in self.runtime_filters)
        mw = f",minw={self.min_writeid}" if self.min_writeid else ""
        return f"scan({self.table.name} as {self.alias},[{','.join(self.columns)}],{pf},{pp},{rf}{mw})"

    def describe(self) -> str:
        extra = []
        if self.pushed_filter:
            extra.append(f"filter={self.pushed_filter.key()}")
        if self.partition_filter:
            extra.append(f"partitions={self.partition_filter.key()}")
        if self.runtime_filters:
            extra.append(f"runtime_filters={len(self.runtime_filters)}")
        return f"Scan[{self.table.name} as {self.alias}]" + (
            " (" + ", ".join(extra) + ")" if extra else ""
        )


class FederatedScan(PlanNode):
    """Scan against an external DataSource (paper §6.2, redesigned).

    ``spec`` is the capability-negotiated
    :class:`~repro.core.federation.datasource.ScanSpec` — the filters /
    projection / (partial) aggregate / limit the connector agreed to absorb;
    whatever it declined stays above this node as ordinary plan operators,
    so ``EXPLAIN`` shows pushed-vs-residual directly.  ``split`` (set by
    compile-time split expansion) pins the node to one of the connector's
    parallel work units."""

    def __init__(self, table: TableDesc, alias: str, columns: List[str],
                 spec=None, output_cols: Optional[List[str]] = None,
                 split=None, total_splits: Optional[int] = None):
        self.table = table
        self.alias = alias
        self.columns = columns
        self.spec = spec
        self._output_cols = output_cols
        self.split = split
        self.total_splits = total_splits
        self.inputs = []

    def output_names(self) -> List[str]:
        if self._output_cols is not None:
            return list(self._output_cols)
        return [f"{self.alias}.{c}" for c in self.columns]

    @property
    def pushed_filter(self) -> Optional[A.Expr]:
        """Conjunction of pushed raw-column filters (cost estimation)."""
        if self.spec is None or not self.spec.filters:
            return None
        out = self.spec.filters[0]
        for c in self.spec.filters[1:]:
            out = A.BinOp("AND", out, c)
        return out

    def key(self) -> str:
        sp = self.spec.key() if self.spec is not None else ""
        split = f",split={self.split!r}" if self.split is not None else ""
        return f"fedscan({self.table.name} as {self.alias},{sp}{split})"

    def describe(self) -> str:
        extra = []
        if self.spec is not None:
            pushed = self.spec.summary()
            if pushed:
                extra.append("pushed=" + ",".join(
                    f"{k}:{v}" for k, v in pushed.items()))
        if self.split is not None and self.total_splits:
            extra.append(f"split={self.split!r}/{self.total_splits}")
        return f"FederatedScan[{self.table.name} via {self.table.handler}]" + (
            " (" + " ".join(extra) + ")" if extra else ""
        )


class Filter(PlanNode):
    def __init__(self, input: PlanNode, predicate: A.Expr):
        self.inputs = [input]
        self.predicate = predicate

    @property
    def input(self):
        return self.inputs[0]

    def output_names(self):
        return self.input.output_names()

    def key(self):
        return f"filter({self.predicate.key()},{self.input.key()})"

    def describe(self):
        return f"Filter[{self.predicate.key()}]"


class Project(PlanNode):
    def __init__(self, input: PlanNode, exprs: List[Tuple[A.Expr, str]]):
        self.inputs = [input]
        self.exprs = exprs  # (expr, output_name)

    @property
    def input(self):
        return self.inputs[0]

    def output_names(self):
        return [n for _, n in self.exprs]

    def key(self):
        es = ",".join(f"{e.key()} as {n}" for e, n in self.exprs)
        return f"project([{es}],{self.input.key()})"

    def describe(self):
        return f"Project[{', '.join(n for _, n in self.exprs)}]"


class Join(PlanNode):
    def __init__(
        self,
        left: PlanNode,
        right: PlanNode,
        kind: str,  # inner | left | semi | anti | cross
        left_keys: List[str],
        right_keys: List[str],
        residual: Optional[A.Expr] = None,
        strategy: Optional[str] = None,  # 'shuffle' | 'broadcast' (set by CBO)
    ):
        self.inputs = [left, right]
        self.kind = kind
        self.left_keys = left_keys
        self.right_keys = right_keys
        self.residual = residual
        self.strategy = strategy

    @property
    def left(self):
        return self.inputs[0]

    @property
    def right(self):
        return self.inputs[1]

    def output_names(self):
        if self.kind in ("semi", "anti"):
            return self.left.output_names()
        return self.left.output_names() + self.right.output_names()

    def key(self):
        r = self.residual.key() if self.residual else ""
        return (
            f"join({self.kind},{self.left_keys},{self.right_keys},{r},"
            f"{self.left.key()},{self.right.key()})"
        )

    def describe(self):
        strat = f" [{self.strategy}]" if self.strategy else ""
        return f"Join[{self.kind}{strat} {self.left_keys}={self.right_keys}" + (
            f" residual={self.residual.key()}" if self.residual else ""
        ) + "]"


@dataclass
class AggSpec:
    fn: str  # sum | count | min | max | avg
    arg: Optional[A.Expr]  # None for count(*)
    distinct: bool
    out_name: str

    def key(self) -> str:
        a = self.arg.key() if self.arg else "*"
        return f"{self.fn}({'D' if self.distinct else ''}{a})->{self.out_name}"


class Aggregate(PlanNode):
    def __init__(
        self,
        input: PlanNode,
        group_keys: List[str],  # input column names
        aggs: List[AggSpec],
        grouping_sets: Optional[List[List[str]]] = None,
    ):
        self.inputs = [input]
        self.group_keys = group_keys
        self.aggs = aggs
        self.grouping_sets = grouping_sets

    @property
    def input(self):
        return self.inputs[0]

    def output_names(self):
        return list(self.group_keys) + [a.out_name for a in self.aggs]

    def key(self):
        gs = f",{self.grouping_sets}" if self.grouping_sets else ""
        return (
            f"agg([{','.join(self.group_keys)}],"
            f"[{','.join(a.key() for a in self.aggs)}]{gs},{self.input.key()})"
        )

    def describe(self):
        return f"Aggregate[keys={self.group_keys} aggs={[a.key() for a in self.aggs]}]"


class WindowOp(PlanNode):
    def __init__(self, input: PlanNode, funcs: List[Tuple[A.WindowFunc, str]]):
        self.inputs = [input]
        self.funcs = funcs

    @property
    def input(self):
        return self.inputs[0]

    def output_names(self):
        return self.input.output_names() + [n for _, n in self.funcs]

    def key(self):
        fs = ",".join(f"{w.key()} as {n}" for w, n in self.funcs)
        return f"window([{fs}],{self.input.key()})"

    def describe(self):
        return f"Window[{', '.join(n for _, n in self.funcs)}]"


class Sort(PlanNode):
    def __init__(self, input: PlanNode, keys: List[Tuple[str, bool]]):
        self.inputs = [input]
        self.keys = keys  # (column name, descending)

    @property
    def input(self):
        return self.inputs[0]

    def output_names(self):
        return self.input.output_names()

    def key(self):
        return f"sort({self.keys},{self.input.key()})"

    def describe(self):
        return f"Sort[{self.keys}]"


class Limit(PlanNode):
    def __init__(self, input: PlanNode, n: int):
        self.inputs = [input]
        self.n = n

    @property
    def input(self):
        return self.inputs[0]

    def output_names(self):
        return self.input.output_names()

    def key(self):
        return f"limit({self.n},{self.input.key()})"

    def describe(self):
        return f"Limit[{self.n}]"


class Union(PlanNode):
    def __init__(self, inputs: List[PlanNode], all: bool = True):
        self.inputs = list(inputs)
        self.all = all

    def output_names(self):
        return self.inputs[0].output_names()

    def key(self):
        return f"union({self.all},[{','.join(i.key() for i in self.inputs)}])"

    def describe(self):
        return f"Union[{'ALL' if self.all else 'DISTINCT'}]"


class ShuffleRead(PlanNode):
    """One partition lane of a hash-partitioned SHUFFLE edge.

    Inserted at compile time by the shuffle service
    (:func:`repro.core.runtime.shuffle.expand_shuffle_partitions`): the
    per-partition clones of a pipeline-breaker consumer each read one lane
    of the shared producer subtree, which executes exactly once and
    hash-partitions its output stream on ``keys``.  The task compiler lowers
    this node into a lane-aware edge placeholder — it never reaches the
    executor."""

    def __init__(self, source: PlanNode, keys: List[str], partition: int,
                 num_partitions: int, est_rows: Optional[float] = None):
        self.inputs = [source]
        self.keys = list(keys)
        self.partition = partition
        self.num_partitions = num_partitions
        # the CBO row estimate the lane count was derived from — the
        # adaptive runtime compares live producer rows against it to
        # decide whether the fan-out actually pays (payoff gate)
        self.est_rows = est_rows

    @property
    def source(self) -> PlanNode:
        return self.inputs[0]

    def output_names(self):
        return self.source.output_names()

    def key(self):
        return (f"shuffleread(p{self.partition}/{self.num_partitions},"
                f"[{','.join(self.keys)}],{self.source.key()})")

    def describe(self):
        return (f"ShuffleRead[p{self.partition}/{self.num_partitions} "
                f"keys={self.keys}]")


class ValuesNode(PlanNode):
    def __init__(self, names: List[str], rows: List[list]):
        self.names = names
        self.rows = rows
        self.inputs = []

    def output_names(self):
        return list(self.names)

    def key(self):
        return f"values({self.names},{self.rows})"

    def describe(self):
        return f"Values[{len(self.rows)} rows]"


# ---------------------------------------------------------------------------
# tree utilities
# ---------------------------------------------------------------------------

def walk_plan(node: PlanNode):
    yield node
    for i in node.inputs:
        yield from walk_plan(i)
    if isinstance(node, Scan):
        for rf in node.runtime_filters:
            yield from walk_plan(rf.producer)


def find_scans(node: PlanNode) -> List[Scan]:
    return [n for n in walk_plan(node) if isinstance(n, Scan)]


def replace_child(parent: PlanNode, old: PlanNode, new: PlanNode) -> None:
    parent.inputs = [new if c is old else c for c in parent.inputs]
