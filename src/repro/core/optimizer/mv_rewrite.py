"""Materialized view rewriting (paper §4.4, Figure 4).

Calcite-style SPJA unification: a query whose Select-Project-Join-Aggregate
core matches a registered materialized view is rewritten to read the MV
instead —

  * **full containment** (Fig 4b): the query's filter region is contained in
    the MV's; the rewrite scans the MV, applies the query's residual
    predicates, and re-aggregates (rollup) when the query groups more
    coarsely;
  * **partial containment** (Fig 4c): the query region exceeds the MV region
    along one column's range; the rewrite UNION ALLs the MV part with a
    recomputation over base tables restricted to the *complement* range, then
    re-aggregates on top.

The same machinery drives incremental MV maintenance (§4.4): a rebuild is a
partially-contained rewrite whose "complement" is the WriteId range above the
MV's build snapshot.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from ..metastore import Metastore
from ..sql import ast as A
from ..sql.binder import Binder, conjoin, split_conjuncts
from ..sql.parser import parse
from . import plan as P


# ===========================================================================
# SPJA descriptor extraction
# ===========================================================================
@dataclasses.dataclass
class Interval:
    lo: float = float("-inf")
    hi: float = float("inf")
    lo_open: bool = False
    hi_open: bool = False

    def contains(self, other: "Interval") -> bool:
        lo_ok = (self.lo < other.lo) or (
            self.lo == other.lo and (not self.lo_open or other.lo_open)
        )
        hi_ok = (self.hi > other.hi) or (
            self.hi == other.hi and (not self.hi_open or other.hi_open)
        )
        return lo_ok and hi_ok

    def is_universe(self) -> bool:
        return self.lo == float("-inf") and self.hi == float("inf")


@dataclasses.dataclass
class SPJA:
    tables: Dict[str, str]  # alias -> table name (each table used once)
    join_pairs: Set[frozenset]  # {frozenset({"t1.c1", "t2.c2"}), ...} table-name qualified
    intervals: Dict[str, Interval]  # table-qualified col -> interval constraint
    other_filters: List[str]  # canonical keys of non-interval conjuncts
    other_filter_exprs: List[A.Expr]
    group_keys: List[str]  # table-qualified cols (exprs unsupported -> bail)
    aggs: List[Tuple[str, str, bool]]  # (fn, canonical arg key | '*', distinct)
    agg_out: List[str]  # aggregate output names in the original plan
    group_out: List[str]  # group key output names in the original plan
    alias_of_table: Dict[str, str] = dataclasses.field(default_factory=dict)


def _canon(e: A.Expr, alias_to_table: Dict[str, str]) -> A.Expr:
    """Rewrite alias-qualified cols to table-name-qualified ones."""
    from ..sql.binder import _rebuild

    if isinstance(e, A.Col):
        t = alias_to_table.get(e.table, e.table)
        return A.Col(e.name, t)
    return _rebuild(e, [_canon(c, alias_to_table) for c in e.children()])


def extract_spja(plan: P.PlanNode) -> Optional[SPJA]:
    """Match Project?(Aggregate(Project?(Filter*(JoinTree(Scan*))))) cores."""
    node = plan
    while isinstance(node, (P.Sort, P.Limit, P.Project)):
        node = node.inputs[0]
    if not isinstance(node, P.Aggregate):
        return None
    agg: P.Aggregate = node

    # below the aggregate: optional pre-projection, filters, join tree of scans
    inner = agg.input
    pre_exprs: Dict[str, A.Expr] = {}
    if isinstance(inner, P.Project):
        pre_exprs = {n: e for e, n in inner.exprs}
        inner = inner.input
    filters: List[A.Expr] = []
    while isinstance(inner, P.Filter):
        filters.extend(split_conjuncts(inner.predicate))
        inner = inner.input

    tables: Dict[str, str] = {}
    join_pairs: Set[frozenset] = set()
    alias_to_table: Dict[str, str] = {}

    def collect(n: P.PlanNode) -> bool:
        if isinstance(n, P.Scan):
            if n.table.name in tables.values():
                return False  # self-joins unsupported by the matcher
            tables[n.alias] = n.table.name
            alias_to_table[n.alias] = n.table.name
            if n.pushed_filter is not None:
                from .rules import _retarget  # qualify with alias again

                for c in split_conjuncts(n.pushed_filter):
                    filters.append(_qualify_with(c, n.alias))
            if n.partition_filter is not None:
                filters.extend(split_conjuncts(n.partition_filter))
            return True
        if isinstance(n, P.Join) and n.kind in ("inner", "cross"):
            if n.residual is not None:
                return False
            if not collect(n.left) or not collect(n.right):
                return False
            for lk, rk in zip(n.left_keys, n.right_keys):
                join_pairs.add(
                    frozenset({_canon_name(lk, alias_to_table),
                               _canon_name(rk, alias_to_table)})
                )
            return True
        if isinstance(n, P.Filter):
            filters.extend(split_conjuncts(n.predicate))
            return collect(n.input)
        return False

    if not collect(inner):
        return None

    # classify filters into per-column intervals vs. opaque conjuncts
    intervals: Dict[str, Interval] = {}
    other: List[A.Expr] = []
    for f in filters:
        fc = _canon(f, alias_to_table)
        hit = _as_interval(fc)
        if hit is not None:
            col, iv = hit
            cur = intervals.setdefault(col, Interval())
            intervals[col] = _intersect(cur, iv)
        else:
            other.append(fc)

    group_keys: List[str] = []
    for k in agg.group_keys:
        e = pre_exprs.get(k, A.Col(_b(k), _q(k)))
        if not isinstance(e, A.Col):
            return None
        group_keys.append(_canon_name(e.qualified, alias_to_table))

    aggs: List[Tuple[str, str, bool]] = []
    for spec in agg.aggs:
        if spec.arg is None:
            aggs.append((spec.fn, "*", spec.distinct))
            continue
        arg = spec.arg
        if isinstance(arg, A.Col):
            arg = pre_exprs.get(arg.qualified, arg)
        aggs.append(
            (spec.fn, _canon(arg, alias_to_table).key(), spec.distinct)
        )

    return SPJA(
        tables=tables,
        join_pairs=join_pairs,
        intervals=intervals,
        other_filters=sorted(x.key() for x in other),
        other_filter_exprs=other,
        group_keys=group_keys,
        aggs=aggs,
        agg_out=[s.out_name for s in agg.aggs],
        group_out=list(agg.group_keys),
        alias_of_table={v: k for k, v in tables.items()},
    )


# ===========================================================================
# the rewriter
# ===========================================================================
class MVRewriter:
    def __init__(self, hms: Metastore):
        self.hms = hms

    def try_rewrite(self, plan: P.PlanNode, allow_stale: bool = False):
        """Return (new_plan, mv_name, mode) or None."""
        q = extract_spja(plan)
        if q is None:
            return None
        for mv in self.hms.list_mvs():
            if not allow_stale and not self._fresh(mv):
                continue
            try:
                mv_desc = self.hms.get_table(mv["name"])
            except KeyError:
                continue
            mv_plan = Binder(self.hms).bind(parse(mv["sql"]))
            m = extract_spja(mv_plan)
            if m is None:
                continue
            if set(q.tables.values()) != set(m.tables.values()):
                continue
            if q.join_pairs != m.join_pairs:
                continue
            if not set(q.group_keys) <= set(m.group_keys):
                continue
            # non-interval query filters over MV-exposed group keys can be
            # re-applied on the MV (e.g. d_moy IN (1,2,3) in Fig 4b); the
            # rest must match the MV's own opaque filters exactly
            extra_residual = [
                e for e in q.other_filter_exprs
                if _cols_of(e) <= set(m.group_keys)
            ]
            rest_keys = sorted(
                e.key() for e in q.other_filter_exprs if e not in extra_residual
            )
            if rest_keys != sorted(m.other_filters):
                continue
            agg_map = self._map_aggs(q, m)
            if agg_map is None:
                continue
            mode, residual, complement = self._containment(q, m)
            if mode is None:
                continue
            mv_out_cols = self._mv_output_columns(m, mv_desc)
            if mv_out_cols is None:
                continue
            if mode == "full":
                new = self._build_full(plan, q, m, mv_desc, agg_map,
                                       residual, mv_out_cols, extra_residual)
                if new is not None:
                    return new, mv["name"], "full"
            else:
                new = self._build_partial(plan, q, m, mv_desc, agg_map,
                                          residual, complement, mv_out_cols,
                                          extra_residual)
                if new is not None:
                    return new, mv["name"], "partial"
        return None

    # -- validity ---------------------------------------------------------------
    def _fresh(self, mv: dict) -> bool:
        import time

        snap = self.hms.get_snapshot()
        for t, wid in mv["build_snapshot"].items():
            cur = self.hms.writeid_list(t, snap)
            if cur.hwm != wid:
                # stale — allowed only within the declared staleness window
                window = mv.get("staleness_window") or 0
                if window and time.time() - (mv.get("last_rebuild_at") or 0) <= window:
                    continue
                return False
        return True

    # -- agg compatibility --------------------------------------------------------
    @staticmethod
    def _map_aggs(q: SPJA, m: SPJA) -> Optional[List[Tuple[str, int]]]:
        """For each query agg, (rollup_fn, index into MV aggs)."""
        out = []
        for fn, arg, distinct in q.aggs:
            if distinct and set(q.group_keys) != set(m.group_keys):
                return None
            try:
                idx = m.aggs.index((fn, arg, distinct))
            except ValueError:
                return None
            rollup = {"sum": "sum", "count": "sum", "min": "min", "max": "max"}.get(fn)
            if rollup is None:
                return None
            out.append((rollup, idx))
        return out

    # -- containment over interval regions -----------------------------------------
    @staticmethod
    def _containment(q: SPJA, m: SPJA):
        """Return (mode, residual_conjuncts, complement) where complement is
        (col, Interval) for the base-table recomputation branch."""
        residual: List[Tuple[str, Interval]] = []
        complement: Optional[Tuple[str, Interval]] = None
        for col in set(q.intervals) | set(m.intervals):
            qi = q.intervals.get(col, Interval())
            mi = m.intervals.get(col, Interval())
            if mi.contains(qi):
                if not qi.is_universe():
                    residual.append((col, qi))
                continue
            # MV does not cover the query on this column
            if complement is not None:
                return None, None, None  # only one overflowing column supported
            # complement = query minus MV region (must be one interval):
            # supported pattern: both are lower-bounded rays (Fig 4c)
            if (
                qi.hi == float("inf") and mi.hi == float("inf")
                and mi.lo > qi.lo
            ):
                comp = Interval(qi.lo, mi.lo, qi.lo_open, not mi.lo_open)
                complement = (col, comp)
                residual.append((col, qi))
            elif (
                qi.lo == float("-inf") and mi.lo == float("-inf")
                and mi.hi < qi.hi
            ):
                comp = Interval(mi.hi, qi.hi, not mi.hi_open, qi.hi_open)
                complement = (col, comp)
                residual.append((col, qi))
            else:
                return None, None, None
        mode = "partial" if complement is not None else "full"
        return mode, residual, complement

    # -- MV output schema mapping ----------------------------------------------------
    @staticmethod
    def _mv_output_columns(m: SPJA, mv_desc) -> Optional[Dict[str, str]]:
        """Map canonical group-key/agg identity -> MV table column name.

        MV tables are stored with the MV query's output names, in order:
        group keys first (matching m.group_out), then aggregates.
        """
        cols = [c for c, _ in mv_desc.schema]
        if len(cols) != len(m.group_keys) + len(m.aggs):
            return None
        out: Dict[str, str] = {}
        for gk, col in zip(m.group_keys, cols[: len(m.group_keys)]):
            out[f"key:{gk}"] = col
        for (fn, arg, d), col in zip(m.aggs, cols[len(m.group_keys):]):
            out[f"agg:{fn}:{arg}:{d}"] = col
        return out

    # -- plan construction --------------------------------------------------------------
    def _scan_mv(self, mv_desc) -> P.PlanNode:
        alias = "__mv__"
        if mv_desc.handler:
            return P.FederatedScan(mv_desc, alias, [c for c, _ in mv_desc.schema])
        return P.Scan(mv_desc, alias, [c for c, _ in mv_desc.schema])

    def _build_full(self, plan, q, m, mv_desc, agg_map, residual, mv_cols,
                    extra_residual=()):
        scan = self._scan_mv(mv_desc)
        alias = "__mv__"
        preds = []
        for e in extra_residual:
            sub = _remap_to_mv(e, mv_cols, alias)
            if sub is None:
                return None
            preds.append(sub)
        for col, iv in residual:
            mv_col = mv_cols.get(f"key:{col}")
            if mv_col is None:
                # filtered column not exposed by the MV: only OK when the MV
                # applies the *same* constraint (already checked containment
                # equality here)
                mi = m.intervals.get(col, Interval())
                qi = q.intervals.get(col, Interval())
                if (mi.lo, mi.hi, mi.lo_open, mi.hi_open) == (
                    qi.lo, qi.hi, qi.lo_open, qi.hi_open,
                ):
                    continue
                return None
            preds.extend(_interval_preds(A.Col(mv_col, alias), iv))
        node: P.PlanNode = scan
        if preds:
            node = P.Filter(node, conjoin(preds))
        return self._regroup(plan, q, m, node, alias, agg_map, mv_cols)

    def _build_partial(self, plan, q, m, mv_desc, agg_map, residual,
                       complement, mv_cols, extra_residual=()):
        comp_col, comp_iv = complement
        # branch A: the MV part (with the query's residual region)
        scan = self._scan_mv(mv_desc)
        alias = "__mv__"
        preds = []
        for e in extra_residual:
            sub = _remap_to_mv(e, mv_cols, alias)
            if sub is None:
                return None
            preds.append(sub)
        for col, iv in residual:
            mv_col = mv_cols.get(f"key:{col}")
            if mv_col is None:
                if col == comp_col:
                    continue  # MV region is implied for its own branch
                return None
            # intersect with MV region for branch A
            mi = m.intervals.get(col, Interval())
            preds.extend(_interval_preds(A.Col(mv_col, alias), _intersect(iv, mi)))
        branch_a: P.PlanNode = P.Filter(scan, conjoin(preds)) if preds else scan
        a_cols = [mv_cols[f"key:{gk}"] for gk in q.group_keys]
        a_aggs = [mv_cols[f"agg:{fn}:{arg}:{d}"] for fn, arg, d in q.aggs]
        proj_a = P.Project(
            branch_a,
            [(A.Col(c, alias), out) for c, out in zip(a_cols, q.group_out)]
            + [(A.Col(c, alias), out) for c, out in zip(a_aggs, q.agg_out)],
        )

        # branch B: recompute over base tables on the complement region
        agg_node = plan
        while not isinstance(agg_node, P.Aggregate):
            agg_node = agg_node.inputs[0]
        qalias = q.alias_of_table.get(_q(comp_col)) or _q(comp_col)
        comp_pred = conjoin(
            _interval_preds(A.Col(_b(comp_col), qalias), comp_iv)
        )
        branch_b_inner = P.Filter(agg_node.input, comp_pred)
        branch_b_agg = P.Aggregate(branch_b_inner, list(agg_node.group_keys),
                                   list(agg_node.aggs))
        proj_b = P.Project(
            branch_b_agg,
            [(A.Col(_b(n), _q(n)), out)
             for n, out in zip(agg_node.group_keys, q.group_out)]
            + [(A.Col(_b(s.out_name), _q(s.out_name)), out)
               for s, out in zip(agg_node.aggs, q.agg_out)],
        )

        union = P.Union([proj_a, proj_b], all=True)
        final = P.Aggregate(
            union,
            list(q.group_out),
            [
                P.AggSpec(rollup, A.Col(_b(out), _q(out)), False, out)
                for (rollup, _), out in zip(agg_map, q.agg_out)
            ],
        )
        return _replace_agg(plan, final)

    def _regroup(self, plan, q, m, mv_input, alias, agg_map, mv_cols):
        group_cols = [mv_cols[f"key:{gk}"] for gk in q.group_keys]
        specs = []
        for (rollup, mv_idx), out in zip(agg_map, q.agg_out):
            fn, arg, d = m.aggs[mv_idx]
            col = mv_cols[f"agg:{fn}:{arg}:{d}"]
            specs.append(P.AggSpec(rollup, A.Col(col, alias), False, out))
        pre = P.Project(
            mv_input,
            [(A.Col(c, alias), out) for c, out in zip(group_cols, q.group_out)]
            + [(A.Col(mv_cols[f"agg:{m.aggs[i][0]}:{m.aggs[i][1]}:{m.aggs[i][2]}"],
                      alias), f"__mva_{j}")
               for j, (_, i) in enumerate(agg_map)],
        )
        specs = [
            P.AggSpec(rollup, A.Col(f"__mva_{j}"), False, out)
            for j, ((rollup, _), out) in enumerate(zip(agg_map, q.agg_out))
        ]
        agg = P.Aggregate(pre, list(q.group_out), specs)
        return _replace_agg(plan, agg)


# ---------------------------------------------------------------------------
def _replace_agg(plan: P.PlanNode, replacement: P.PlanNode) -> P.PlanNode:
    """Swap the SPJA core (the Aggregate node) for the rewritten subtree."""
    if isinstance(plan, P.Aggregate):
        return replacement

    def visit(node):
        for i, c in enumerate(node.inputs):
            if isinstance(c, P.Aggregate):
                node.inputs[i] = replacement
                return True
            if visit(c):
                return True
        return False

    visit(plan)
    return plan


def _as_interval(e: A.Expr) -> Optional[Tuple[str, Interval]]:
    if isinstance(e, A.BinOp) and e.op in ("<", "<=", ">", ">=", "="):
        col, lit, op = None, None, e.op
        if isinstance(e.left, A.Col) and isinstance(e.right, A.Lit):
            col, lit = e.left, e.right.value
        elif isinstance(e.right, A.Col) and isinstance(e.left, A.Lit):
            flip = {"<": ">", "<=": ">=", ">": "<", ">=": "<=", "=": "="}
            col, lit, op = e.right, e.left.value, flip[e.op]
        if col is None or not isinstance(lit, (int, float)) or isinstance(lit, bool):
            return None
        v = float(lit)
        if op == "=":
            return col.qualified, Interval(v, v)
        if op == "<":
            return col.qualified, Interval(hi=v, hi_open=True)
        if op == "<=":
            return col.qualified, Interval(hi=v)
        if op == ">":
            return col.qualified, Interval(lo=v, lo_open=True)
        if op == ">=":
            return col.qualified, Interval(lo=v)
    if isinstance(e, A.Between) and not e.negated and isinstance(e.expr, A.Col):
        if isinstance(e.low, A.Lit) and isinstance(e.high, A.Lit) and \
           isinstance(e.low.value, (int, float)) and isinstance(e.high.value, (int, float)):
            return e.expr.qualified, Interval(float(e.low.value), float(e.high.value))
    return None


def _intersect(a: Interval, b: Interval) -> Interval:
    lo, lo_open = max((a.lo, a.lo_open), (b.lo, b.lo_open))
    hi, hi_open = min((a.hi, not a.hi_open), (b.hi, not b.hi_open))
    return Interval(lo, hi, lo_open, not hi_open)


def _interval_preds(col: A.Col, iv: Interval) -> List[A.Expr]:
    preds = []
    if iv.lo == iv.hi and not iv.lo_open and not iv.hi_open and iv.lo != float("-inf"):
        return [A.BinOp("=", col, A.Lit(_maybe_int(iv.lo)))]
    if iv.lo != float("-inf"):
        preds.append(A.BinOp(">" if iv.lo_open else ">=", col, A.Lit(_maybe_int(iv.lo))))
    if iv.hi != float("inf"):
        preds.append(A.BinOp("<" if iv.hi_open else "<=", col, A.Lit(_maybe_int(iv.hi))))
    return preds


def _maybe_int(v: float):
    return int(v) if float(v).is_integer() else v


def _cols_of(e: A.Expr) -> set:
    return {n.qualified for n in A.walk(e) if isinstance(n, A.Col)}


def _remap_to_mv(e: A.Expr, mv_cols: Dict[str, str], alias: str) -> Optional[A.Expr]:
    """Rewrite canonical (table.col) refs onto the MV table's columns."""
    from ..sql.binder import _rebuild

    if isinstance(e, A.Col):
        mv_col = mv_cols.get(f"key:{e.qualified}")
        if mv_col is None:
            return None
        return A.Col(mv_col, alias)
    kids = []
    for c in e.children():
        k = _remap_to_mv(c, mv_cols, alias)
        if k is None:
            return None
        kids.append(k)
    return _rebuild(e, kids)


def _canon_name(qualified: str, alias_to_table: Dict[str, str]) -> str:
    t, c = qualified.split(".", 1)
    return f"{alias_to_table.get(t, t)}.{c}"


def _qualify_with(e: A.Expr, alias: str) -> A.Expr:
    from ..sql.binder import _rebuild

    if isinstance(e, A.Col) and e.table is None:
        return A.Col(e.name, alias)
    if isinstance(e, A.Col):
        return e
    return _rebuild(e, [_qualify_with(c, alias) for c in e.children()])


def _b(q: str) -> str:
    return q.split(".", 1)[1] if "." in q else q


def _q(q: str):
    return q.split(".", 1)[0] if "." in q else None
