"""Query results cache (paper §4.3).

Keyed by the *resolved* query text (table references qualified) so two
queries with identical text against different databases don't collide.  Each
entry remembers the per-table WriteId snapshot it was computed under; a hit
is only served when the participating tables still have the same
transactional state.  A *pending entry* mode serializes a thundering herd of
identical queries behind the first executor (§4.3).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from ...analysis.lockdep import make_lock
from ..metastore import Metastore, WriteIdList
from ..runtime.vector import VectorBatch


@dataclass
class CacheEntry:
    result: Optional[VectorBatch]
    snapshot: Dict[str, Tuple[int, frozenset]]  # table -> (hwm, invalid set)
    created_at: float = field(default_factory=time.time)
    hits: int = 0
    pending: Optional[threading.Event] = None


class QueryResultCache:
    def __init__(self, max_entries: int = 256, ttl_seconds: float = 3600.0):
        self.max_entries = max_entries
        self.ttl = ttl_seconds
        self._lock = make_lock("optimizer.result_cache")
        self._entries: Dict[str, CacheEntry] = {}
        self.stats = {"hits": 0, "misses": 0, "pending_waits": 0}

    # -- snapshot helpers ------------------------------------------------------
    @staticmethod
    def _current_state(hms: Metastore, tables) -> Dict[str, Tuple[int, frozenset]]:
        snap = hms.get_snapshot()
        return {
            t: (wl.hwm, wl.invalid)
            for t in tables
            for wl in [hms.writeid_list(t, snap)]
        }

    def lookup(self, key: str, hms: Metastore, tables) -> Optional[VectorBatch]:
        """Return cached results if valid; may block on a pending entry."""
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.stats["misses"] += 1
                return None
            pending = entry.pending
        if pending is not None:
            self.stats["pending_waits"] += 1
            pending.wait(timeout=60)
            with self._lock:
                entry = self._entries.get(key)
                if entry is None or entry.pending is not None:
                    self.stats["misses"] += 1
                    return None
        if time.time() - entry.created_at > self.ttl:
            with self._lock:
                self._entries.pop(key, None)
            self.stats["misses"] += 1
            return None
        # transactional validity: tables must not contain new/modified data
        if self._current_state(hms, entry.snapshot.keys()) != entry.snapshot:
            with self._lock:
                self._entries.pop(key, None)
            self.stats["misses"] += 1
            return None
        entry.hits += 1
        self.stats["hits"] += 1
        return entry.result

    def begin_pending(self, key: str, hms: Metastore, tables) -> bool:
        """Install a pending entry; True if we are the filling query."""
        with self._lock:
            if key in self._entries:
                return False
            self._entries[key] = CacheEntry(
                result=None,
                snapshot=self._current_state(hms, tables),
                pending=threading.Event(),
            )
            return True

    def fill(self, key: str, result: VectorBatch) -> None:
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                return
            entry.result = result
            entry.created_at = time.time()
            ev, entry.pending = entry.pending, None
        if ev is not None:
            ev.set()
        self._expunge()

    def cancel_pending(self, key: str) -> None:
        with self._lock:
            entry = self._entries.pop(key, None)
        if entry is not None and entry.pending is not None:
            entry.pending.set()

    def _expunge(self) -> None:
        with self._lock:
            if len(self._entries) <= self.max_entries:
                return
            # drop stale/least-hit entries first
            victims = sorted(
                self._entries.items(), key=lambda kv: (kv[1].hits, kv[1].created_at)
            )
            for k, _ in victims[: len(self._entries) - self.max_entries]:
                self._entries.pop(k, None)

    def invalidate_all(self) -> None:
        with self._lock:
            self._entries.clear()
