"""Multi-stage rule & cost based optimizer (paper §4.1).

Mirrors Hive's Calcite integration: a sequence of optimization *stages*, each
pairing a planner discipline with a rule set:

  stage 1 (exhaustive/fixpoint): constant folding, predicate simplification
      and propagation (transitive inference over equi-joins), filter pushdown,
      partition pruning, projection (column) pruning;
  stage 2 (cost-based): join reordering over the extracted join graph and
      join-algorithm selection (broadcast "map join" vs shuffle) driven by the
      HMS statistics in ``CostModel``;
  stage 3+ (cost-based, separate modules): materialized-view rewriting
      (§4.4), dynamic semijoin reduction (§4.6); shared-work runs last against
      the physical plan (§4.5).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from ..metastore import Metastore
from ..sql import ast as A
from ..sql.binder import conjoin, split_conjuncts, _rebuild
from . import plan as P
from .cost import CostModel


# ===========================================================================
# expression utilities
# ===========================================================================
def expr_columns(e: Optional[A.Expr]) -> Set[str]:
    if e is None:
        return set()
    return {n.qualified for n in A.walk(e) if isinstance(n, A.Col)}


def fold_constants(e: A.Expr) -> A.Expr:
    kids = [fold_constants(c) for c in e.children()]
    e = _rebuild(e, kids)
    if isinstance(e, A.BinOp) and isinstance(e.left, A.Lit) and isinstance(e.right, A.Lit):
        l, r = e.left.value, e.right.value
        try:
            if e.op == "+":
                return A.Lit(l + r)
            if e.op == "-":
                return A.Lit(l - r)
            if e.op == "*":
                return A.Lit(l * r)
            if e.op == "/":
                return A.Lit(l / r)
            if e.op == "=":
                return A.Lit(l == r)
            if e.op == "!=":
                return A.Lit(l != r)
            if e.op == "<":
                return A.Lit(l < r)
            if e.op == "<=":
                return A.Lit(l <= r)
            if e.op == ">":
                return A.Lit(l > r)
            if e.op == ">=":
                return A.Lit(l >= r)
        except TypeError:
            return e
    if isinstance(e, A.BinOp) and e.op == "AND":
        if isinstance(e.left, A.Lit):
            return e.right if e.left.value else A.Lit(False)
        if isinstance(e.right, A.Lit):
            return e.left if e.right.value else A.Lit(False)
    if isinstance(e, A.BinOp) and e.op == "OR":
        if isinstance(e.left, A.Lit):
            return A.Lit(True) if e.left.value else e.right
        if isinstance(e.right, A.Lit):
            return A.Lit(True) if e.right.value else e.left
    if isinstance(e, A.UnOp) and e.op == "NOT" and isinstance(e.operand, A.Lit):
        return A.Lit(not e.operand.value)
    if isinstance(e, A.UnOp) and e.op == "-" and isinstance(e.operand, A.Lit):
        return A.Lit(-e.operand.value)
    return e


def substitute(e: A.Expr, mapping: Dict[str, A.Expr]) -> A.Expr:
    """Replace column refs by definition expressions (inverse projection)."""
    if isinstance(e, A.Col):
        return mapping.get(e.qualified, e)
    return _rebuild(e, [substitute(c, mapping) for c in e.children()])


def strip_alias(e: A.Expr) -> A.Expr:
    """alias.col -> col (for pushing into Scan.pushed_filter)."""
    if isinstance(e, A.Col):
        return A.Col(e.name)
    return _rebuild(e, [strip_alias(c) for c in e.children()])


# ===========================================================================
# the optimizer
# ===========================================================================
@dataclasses.dataclass
class OptimizerConfig:
    cbo: bool = True
    pushdown: bool = True
    prune_columns: bool = True
    join_reorder: bool = True
    transitive_inference: bool = True
    broadcast_threshold_rows: float = 200_000.0
    partition_pruning: bool = True


class Optimizer:
    def __init__(self, hms: Metastore, config: Optional[OptimizerConfig] = None,
                 runtime_overrides: Optional[Dict[str, float]] = None,
                 handler_resolver=None):
        self.hms = hms
        self.config = config or OptimizerConfig()
        self.cost_model = CostModel(hms, runtime_overrides,
                                    handler_resolver=handler_resolver)

    def optimize(self, plan: P.PlanNode) -> P.PlanNode:
        cfg = self.config
        if cfg.pushdown:
            for _ in range(5):  # fixpoint over the logical rewrites
                before = plan.key()
                plan = self.rewrite_filters(plan)
                if cfg.transitive_inference:
                    plan = self.infer_transitive(plan)
                plan = self.rewrite_filters(plan)
                if plan.key() == before:
                    break
        if cfg.prune_columns:
            plan = self.prune_columns(plan, set(plan.output_names()))
        if cfg.cbo and cfg.join_reorder:
            plan = self.reorder_joins(plan)
        if cfg.cbo:
            plan = self.choose_join_strategy(plan)
        return plan

    # ------------------------------------------------------------------ stage 1
    def rewrite_filters(self, node: P.PlanNode) -> P.PlanNode:
        node.inputs = [self.rewrite_filters(c) for c in node.inputs]
        if not isinstance(node, P.Filter):
            return node
        pred = fold_constants(node.predicate)
        if isinstance(pred, A.Lit):
            if pred.value:
                return node.input
            # FALSE filter: empty result; keep as unsatisfiable filter
            node.predicate = pred
            return node
        child = node.input

        # merge adjacent filters
        if isinstance(child, P.Filter):
            merged = conjoin(split_conjuncts(pred) + split_conjuncts(child.predicate))
            return self.rewrite_filters(P.Filter(child.input, merged))

        # push through Project (substituting definitions)
        if isinstance(child, P.Project):
            defs = {n: e for e, n in child.exprs}
            pushable, stuck = [], []
            for c in split_conjuncts(pred):
                sub = substitute(c, defs)
                if not any(isinstance(x, (A.Func, A.WindowFunc)) and
                           getattr(x, "name", "") in A.AGG_FUNCS
                           for x in A.walk(sub)):
                    pushable.append(sub)
                else:
                    stuck.append(c)
            if pushable:
                child.inputs = [P.Filter(child.input, conjoin(pushable))]
                child.inputs = [self.rewrite_filters(child.inputs[0])]
                return P.Filter(child, conjoin(stuck)) if stuck else child
            return node

        # push through Join: route conjuncts by referenced side
        if isinstance(child, P.Join):
            lnames = set(child.left.output_names())
            rnames = set(child.right.output_names())
            to_left, to_right, keep = [], [], []
            for c in split_conjuncts(pred):
                cols = expr_columns(c)
                if cols and cols <= lnames:
                    to_left.append(c)
                elif cols and cols <= rnames and child.kind in ("inner", "cross", "semi"):
                    to_right.append(c)
                elif cols and cols <= rnames and child.kind == "left":
                    keep.append(c)  # can't push below a null-producing side
                else:
                    keep.append(c)
            if to_left:
                child.inputs[0] = self.rewrite_filters(
                    P.Filter(child.left, conjoin(to_left)))
            if to_right:
                child.inputs[1] = self.rewrite_filters(
                    P.Filter(child.right, conjoin(to_right)))
            # two-side conjuncts on an inner/cross join: equi column pairs
            # become join keys (cross -> inner), the rest goes to the residual
            if keep and child.kind in ("inner", "cross"):
                rest = []
                for c in keep:
                    cols = expr_columns(c)
                    if not cols or not cols <= (lnames | rnames):
                        rest.append(c)
                        continue
                    if (
                        isinstance(c, A.BinOp) and c.op == "="
                        and isinstance(c.left, A.Col) and isinstance(c.right, A.Col)
                    ):
                        lq, rq = c.left.qualified, c.right.qualified
                        if lq in lnames and rq in rnames:
                            child.left_keys.append(lq)
                            child.right_keys.append(rq)
                            child.kind = "inner"
                            continue
                        if rq in lnames and lq in rnames:
                            child.left_keys.append(rq)
                            child.right_keys.append(lq)
                            child.kind = "inner"
                            continue
                    child.residual = conjoin(split_conjuncts(child.residual) + [c])
                    child.kind = "inner"
                keep = rest
            return P.Filter(child, conjoin(keep)) if keep else child

        # push through Union
        if isinstance(child, P.Union):
            names = child.output_names()
            for i, inp in enumerate(child.inputs):
                mapping = {n: A.Col(_b(c), _q(c)) for n, c in
                           zip(names, inp.output_names())}
                child.inputs[i] = self.rewrite_filters(
                    P.Filter(inp, substitute(pred, mapping)))
            return child

        # push through Aggregate when predicate only touches group keys
        if isinstance(child, P.Aggregate):
            gk = set(child.group_keys)
            pushable = [c for c in split_conjuncts(pred) if expr_columns(c) <= gk]
            stuck = [c for c in split_conjuncts(pred) if c not in pushable]
            if pushable and not child.grouping_sets:
                child.inputs = [self.rewrite_filters(
                    P.Filter(child.input, conjoin(pushable)))]
                return P.Filter(child, conjoin(stuck)) if stuck else child
            return node

        # land on a Scan: split into partition filter + pushed storage filter
        if isinstance(child, P.Scan):
            pcols = {f"{child.alias}.{c}" for c in child.table.partition_cols}
            part, data, keep = [], [], []
            for c in split_conjuncts(pred):
                cols = expr_columns(c)
                if not cols:
                    keep.append(c)
                elif cols <= pcols and self.config.partition_pruning:
                    part.append(c)
                else:
                    data.append(c)
            if part:
                child.partition_filter = conjoin(
                    split_conjuncts(child.partition_filter) + part
                )
            if data:
                stripped = [strip_alias(c) for c in data]
                child.pushed_filter = conjoin(
                    split_conjuncts(child.pushed_filter) + stripped
                )
            return P.Filter(child, conjoin(keep)) if keep else child

        if isinstance(child, P.Sort):
            child.inputs = [self.rewrite_filters(P.Filter(child.input, pred))]
            return child
        node.predicate = pred
        return node

    # transitive predicate inference over equi-join keys (§4.1)
    def infer_transitive(self, node: P.PlanNode) -> P.PlanNode:
        node.inputs = [self.infer_transitive(c) for c in node.inputs]
        if not isinstance(node, P.Join) or node.kind not in ("inner", "semi"):
            return node
        l_preds = _single_col_preds(node.left)
        r_preds = _single_col_preds(node.right)
        for lk, rk in zip(node.left_keys, node.right_keys):
            for (col, tmpl) in list(l_preds):
                if col == lk:
                    derived = _retarget(tmpl, rk)
                    if not _has_pred(node.right, derived):
                        node.inputs[1] = P.Filter(node.right, derived)
            for (col, tmpl) in list(r_preds):
                if col == rk and node.kind == "inner":
                    derived = _retarget(tmpl, lk)
                    if not _has_pred(node.left, derived):
                        node.inputs[0] = P.Filter(node.left, derived)
        return node

    # projection pruning: narrow scans & projects to required columns
    def prune_columns(self, node: P.PlanNode, required: Set[str]) -> P.PlanNode:
        if isinstance(node, P.Scan):
            pcols = set(node.table.partition_cols)
            needed_raw = {
                c for c in node.columns
                if f"{node.alias}.{c}" in required
            }
            needed_raw |= {c.name for c in
                           (A.walk(node.pushed_filter) if node.pushed_filter else [])
                           if isinstance(c, A.Col)}
            for rf in node.runtime_filters:
                needed_raw.add(rf.target_column)
            kept = [c for c in node.columns if c in needed_raw or c in pcols]
            if not kept and node.columns:
                kept = [node.columns[0]]  # COUNT(*): keep one column for cardinality
            node.columns = kept
            for rf in node.runtime_filters:
                rf.producer = self.prune_columns(
                    rf.producer, set(rf.producer.output_names()))
            return node
        if isinstance(node, P.FederatedScan):
            # narrow the logical column set; whether the narrowing reaches
            # the remote system is decided later by push_projection during
            # the capability negotiation
            if node.spec is None and node._output_cols is None:
                needed = [c for c in node.columns
                          if f"{node.alias}.{c}" in required]
                if needed:
                    node.columns = needed
            return node
        if isinstance(node, P.Project):
            node.exprs = [(e, n) for e, n in node.exprs if n in required] or \
                node.exprs[:1]
            child_req = set()
            for e, _ in node.exprs:
                child_req |= expr_columns(e)
            node.inputs = [self.prune_columns(node.input, child_req)]
            return node
        if isinstance(node, P.Filter):
            child_req = required | expr_columns(node.predicate)
            node.inputs = [self.prune_columns(node.input, child_req)]
            return node
        if isinstance(node, P.Join):
            child_req = set(required)
            child_req |= set(node.left_keys) | set(node.right_keys)
            child_req |= expr_columns(node.residual)
            lnames = set(node.left.output_names())
            rnames = set(node.right.output_names())
            node.inputs[0] = self.prune_columns(node.left, child_req & lnames)
            node.inputs[1] = self.prune_columns(node.right, child_req & rnames)
            return node
        if isinstance(node, P.Aggregate):
            child_req = set(node.group_keys)
            for a in node.aggs:
                child_req |= expr_columns(a.arg)
            node.inputs = [self.prune_columns(node.input, child_req)]
            return node
        if isinstance(node, P.WindowOp):
            child_req = set(required)
            for wf, _ in node.funcs:
                child_req |= expr_columns(wf)
            node.inputs = [self.prune_columns(
                node.input, child_req & set(node.input.output_names()))]
            return node
        if isinstance(node, P.Sort):
            child_req = required | {k for k, _ in node.keys}
            node.inputs = [self.prune_columns(node.input, child_req)]
            return node
        if isinstance(node, (P.Limit,)):
            node.inputs = [self.prune_columns(node.input, required)]
            return node
        if isinstance(node, P.Union):
            names = node.output_names()
            for i, inp in enumerate(node.inputs):
                mapping = dict(zip(names, inp.output_names()))
                node.inputs[i] = self.prune_columns(
                    inp, {mapping[n] for n in names})
            return node
        node.inputs = [self.prune_columns(c, set(c.output_names()))
                       for c in node.inputs]
        return node

    # ------------------------------------------------------------------ stage 2
    def reorder_joins(self, node: P.PlanNode) -> P.PlanNode:
        node.inputs = [self.reorder_joins(c) for c in node.inputs]
        if not isinstance(node, P.Join) or node.kind != "inner":
            return node
        rels, edges, residuals = [], [], []
        if not _collect_join_tree(node, rels, edges, residuals):
            return node
        if len(rels) < 3:
            return node
        return self._greedy_join_order(rels, edges, residuals,
                                       node.output_names())

    def _greedy_join_order(self, rels, edges, residuals, out_names):
        remaining = list(range(len(rels)))
        plans: Dict[int, P.PlanNode] = {i: r for i, r in enumerate(rels)}
        groups: Dict[int, Set[int]] = {i: {i} for i in remaining}

        def edge_between(ga: Set[int], gb: Set[int]):
            keys_l, keys_r = [], []
            for (i, lk, j, rk) in edges:
                if i in ga and j in gb:
                    keys_l.append(lk)
                    keys_r.append(rk)
                elif j in ga and i in gb:
                    keys_l.append(rk)
                    keys_r.append(lk)
            return keys_l, keys_r

        while len(remaining) > 1:
            best = None
            for ai in range(len(remaining)):
                for bi in range(ai + 1, len(remaining)):
                    a, b = remaining[ai], remaining[bi]
                    kl, kr = edge_between(groups[a], groups[b])
                    if not kl:
                        continue
                    cand = P.Join(plans[a], plans[b], "inner", kl, kr)
                    rows = self.cost_model.estimate(cand).rows
                    if best is None or rows < best[0]:
                        best = (rows, a, b, cand)
            if best is None:  # only cross joins left: pick smallest pair
                a, b = remaining[0], remaining[1]
                cand = P.Join(plans[a], plans[b], "cross", [], [])
                best = (0, a, b, cand)
            _, a, b, joined = best
            plans[a] = joined
            groups[a] |= groups[b]
            remaining.remove(b)
        plan = plans[remaining[0]]
        if residuals:
            plan = P.Filter(plan, conjoin(residuals))
        # restore the original column order expected by parents
        if plan.output_names() != out_names and set(out_names) <= set(plan.output_names()):
            plan = P.Project(plan, [(A.Col(_b(n), _q(n)), n) for n in out_names])
        return plan

    def choose_join_strategy(self, node: P.PlanNode) -> P.PlanNode:
        node.inputs = [self.choose_join_strategy(c) for c in node.inputs]
        if isinstance(node, P.Join) and node.kind in ("inner", "semi", "anti", "left"):
            left_rows = self.cost_model.estimate(node.left).rows
            right_rows = self.cost_model.estimate(node.right).rows
            # orient the smaller side as build (right) when legal
            if node.kind == "inner" and left_rows < right_rows:
                node.inputs = [node.right, node.left]
                node.left_keys, node.right_keys = node.right_keys, node.left_keys
                left_rows, right_rows = right_rows, left_rows
                # output order changes; re-project to original order
                # (callers read columns by name, order only matters at the top)
            node.strategy = (
                "broadcast"
                if right_rows <= self.config.broadcast_threshold_rows
                else "shuffle"
            )
        return node


# ---------------------------------------------------------------------------
def _b(qualified: str) -> str:
    return qualified.split(".", 1)[1] if "." in qualified else qualified


def _q(qualified: str):
    return qualified.split(".", 1)[0] if "." in qualified else None


def _single_col_preds(node: P.PlanNode) -> List[Tuple[str, A.Expr]]:
    """Collect (column, predicate) pairs filtering a single column under node."""
    out = []
    if isinstance(node, P.Filter):
        for c in split_conjuncts(node.predicate):
            cols = expr_columns(c)
            if len(cols) == 1 and _is_value_pred(c):
                out.append((next(iter(cols)), c))
        out.extend(_single_col_preds(node.input))
    elif isinstance(node, P.Scan):
        for src in (node.pushed_filter, node.partition_filter):
            if src is not None:
                for c in split_conjuncts(src):
                    cols = expr_columns(c)
                    if len(cols) == 1 and _is_value_pred(c):
                        col = next(iter(cols))
                        if "." not in col:
                            col = f"{node.alias}.{col}"
                            c = _retarget(c, col)
                        out.append((col, c))
    return out


def _is_value_pred(e: A.Expr) -> bool:
    if isinstance(e, A.BinOp) and e.op in ("=", "<", "<=", ">", ">=", "!="):
        return isinstance(e.left, A.Lit) or isinstance(e.right, A.Lit)
    if isinstance(e, (A.InList, A.Between)):
        return True
    return False


def _retarget(e: A.Expr, new_col: str) -> A.Expr:
    if isinstance(e, A.Col):
        return A.Col(_b(new_col), _q(new_col))
    return _rebuild(e, [_retarget(c, new_col) for c in e.children()])


def _has_pred(node: P.PlanNode, pred: A.Expr) -> bool:
    key = pred.key()
    for n in P.walk_plan(node):
        if isinstance(n, P.Filter):
            if any(c.key() == key for c in split_conjuncts(n.predicate)):
                return True
        if isinstance(n, P.Scan):
            for src in (n.pushed_filter, n.partition_filter):
                if src is not None:
                    stripped_key = strip_alias(pred).key()
                    if any(c.key() in (key, stripped_key)
                           for c in split_conjuncts(src)):
                        return True
    return False


def _collect_join_tree(node, rels: list, edges: list, residuals: list) -> bool:
    """Flatten a tree of inner joins into relations + equi edges.

    Returns False if the subtree contains anything but inner joins (outer
    joins constrain ordering and are left untouched).
    """
    if isinstance(node, P.Join) and node.kind == "inner":
        if node.residual is not None:
            residuals.extend(split_conjuncts(node.residual))
        ok_l = _collect_join_tree(node.left, rels, edges, residuals)
        if not ok_l:
            return False
        # record which relation indices each side covers BEFORE adding right
        left_count = len(rels)
        ok_r = _collect_join_tree(node.right, rels, edges, residuals)
        if not ok_r:
            return False
        name_to_rel = {}
        for idx, r in enumerate(rels):
            for n in r.output_names():
                name_to_rel[n] = idx
        for lk, rk in zip(node.left_keys, node.right_keys):
            if lk in name_to_rel and rk in name_to_rel:
                edges.append((name_to_rel[lk], lk, name_to_rel[rk], rk))
        return True
    rels.append(node)
    return True
