"""Capability-negotiated DataSource scan/write contract (paper §6, redesigned).

The old federation surface was all-or-nothing: the optimizer handed a whole
plan prefix to ``StorageHandler.try_pushdown`` and either the handler
absorbed everything or nothing, and ``read()`` materialized the external
table into one batch.  This module replaces that with a *negotiation*:

  * the optimizer builds a :class:`ScanBuilder` for each federated scan and
    offers work piecewise — ``push_filters(conjuncts)`` returns the
    *residual* conjuncts the connector cannot evaluate (kept as a local
    Filter), ``push_projection(cols)`` narrows the remote read,
    ``push_aggregate(...)`` may be absorbed fully, *partially* (the
    connector returns per-split partial aggregates and the local Aggregate
    is rewritten into a merging fold), or not at all, and
    ``push_limit(n, sort)`` likewise supports per-split top-n with a local
    merge;
  * the negotiated state is recorded as a plain-data :class:`ScanSpec` on
    the plan's ``FederatedScan`` node (deep-copyable, plan-cache safe);
    execution rebuilds the builder and replays the spec;
  * ``ScanBuilder.to_splits()`` enumerates parallel work units whose
    readers are *generators* yielding ``VectorBatch`` morsels, so external
    reads stream through the exchange layer like native scans;
  * writes go through :class:`Writer` (``write_batch``/``commit``) instead
    of a one-shot ``write``.

``EXPLAIN`` shows the outcome: the ``FederatedScan`` node describes what was
pushed, and whatever the connector declined remains visible as ordinary
Filter/Project/Aggregate/Sort/Limit nodes above it.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from ..optimizer import plan as P
from ..sql import ast as A
from ..sql.binder import conjoin, split_conjuncts
from ..runtime.vector import VectorBatch

# how a pushed-down partial aggregate folds in the local merging Aggregate
FOLD_FN = {"sum": "sum", "count": "sum", "min": "min", "max": "max"}

# pushdown outcome for aggregates and limits
NONE, PARTIAL, FULL = "none", "partial", "full"


# ===========================================================================
# the negotiated scan description (plain data; lives on the plan node)
# ===========================================================================
@dataclasses.dataclass
class AggPush:
    """A pushed aggregation, in the connector's raw column terms."""

    group_keys: List[str]                      # raw column names
    aggs: List[Tuple[str, Optional[str], str]]  # (fn, raw_arg|None, out_name)
    mode: str = FULL                           # 'partial' | 'full'

    def key(self) -> str:
        a = ",".join(f"{fn}({arg or '*'})->{out}" for fn, arg, out in self.aggs)
        return f"agg[{','.join(self.group_keys)}|{a}|{self.mode}]"


@dataclasses.dataclass
class ScanSpec:
    """Everything the connector agreed to take, in raw-column terms."""

    filters: List[A.Expr] = dataclasses.field(default_factory=list)
    projection: Optional[List[str]] = None      # raw columns (None = all)
    agg: Optional[AggPush] = None
    limit: Optional[int] = None
    limit_mode: str = NONE                      # 'partial' | 'full' when set
    sort: List[Tuple[int, bool]] = dataclasses.field(default_factory=list)
    # ``sort`` keys are positions into the scan's output columns

    def key(self) -> str:
        parts = []
        if self.filters:
            parts.append("f[" + ",".join(c.key() for c in self.filters) + "]")
        if self.projection is not None:
            parts.append("p[" + ",".join(self.projection) + "]")
        if self.agg is not None:
            parts.append(self.agg.key())
        if self.limit is not None:
            parts.append(f"l[{self.limit}|{self.limit_mode}|{self.sort}]")
        return ";".join(parts)

    def summary(self) -> Dict[str, object]:
        out: Dict[str, object] = {}
        if self.filters:
            out["filters"] = len(self.filters)
        if self.projection is not None:
            out["projection"] = list(self.projection)
        if self.agg is not None:
            out["aggregate"] = self.agg.mode
        if self.limit is not None:
            out["limit"] = self.limit_mode
        return out


# ===========================================================================
# the connector-side contract
# ===========================================================================
class ScanBuilder:
    """Per-scan negotiation + split enumeration for one external table.

    Negotiation methods mutate ``self.spec`` when (part of) the offer is
    accepted; each returns what the optimizer must keep locally.  The base
    class declines everything, so a minimal connector only implements
    ``read_split`` (and optionally ``to_splits``).
    """

    def __init__(self, handler, table, config: Optional[dict] = None):
        self.handler = handler
        self.table = table
        self.config = config or {}
        self.spec = ScanSpec()

    # ---- negotiation ------------------------------------------------------
    def push_filters(self, conjuncts: List[A.Expr]) -> List[A.Expr]:
        """Offer raw-column filter conjuncts; return the residuals."""
        return list(conjuncts)

    def push_projection(self, columns: List[str]) -> bool:
        return False

    def push_aggregate(self, group_keys: List[str],
                       aggs: List[Tuple[str, Optional[str], str]]) -> str:
        return NONE

    def push_limit(self, n: int, sort: List[Tuple[int, bool]]) -> str:
        return NONE

    # ---- statistics -------------------------------------------------------
    def estimate_stats(self) -> Optional["RemoteTableStats"]:
        """Remote row-count/NDV estimates for the CBO; None = unknown."""
        return None

    # ---- execution --------------------------------------------------------
    def output_columns(self) -> List[str]:
        """Raw names of the columns each read batch carries, in order."""
        if self.spec.agg is not None:
            return list(self.spec.agg.group_keys) + [
                out for _, _, out in self.spec.agg.aggs
            ]
        if self.spec.projection is not None:
            return list(self.spec.projection)
        return [c for c, _ in self.table.schema]

    def to_splits(self) -> List[object]:
        """Parallel work units; default: one whole-table split."""
        return [None]

    def read_split(self, split) -> Iterator[VectorBatch]:
        raise NotImplementedError

    def empty_batch(self) -> VectorBatch:
        """Schema-carrying empty batch in ``output_columns`` order."""
        from ..acid import _np_dtype

        dtypes = dict(self.table.schema)
        cols = {}
        for c in self.output_columns():
            ty = dtypes.get(c)
            cols[c] = np.empty(0, dtype=_np_dtype(ty) if ty else np.float64)
        return VectorBatch(cols)


def apply_spec(builder: ScanBuilder, spec: Optional[ScanSpec]) -> None:
    """Replay a negotiated spec onto a fresh builder (compile/exec time)."""
    if spec is None:
        return
    if spec.filters:
        residual = builder.push_filters(list(spec.filters))
        if residual:
            raise RuntimeError(
                f"connector {builder.handler.name} no longer accepts a "
                f"previously negotiated filter: {[c.key() for c in residual]}"
            )
    if spec.projection is not None:
        builder.push_projection(list(spec.projection))
    if spec.agg is not None:
        mode = builder.push_aggregate(list(spec.agg.group_keys),
                                      list(spec.agg.aggs))
        if mode == NONE:
            raise RuntimeError(
                f"connector {builder.handler.name} no longer accepts a "
                f"previously negotiated aggregate pushdown"
            )
        # the plan's shape (local merging Aggregate present or not) was
        # fixed at negotiation time; replay honors it — connectors consult
        # spec.agg.mode when enumerating splits, so a FULL plan reads one
        # global split even if the remote side gained parallelism since
        builder.spec.agg.mode = spec.agg.mode
    if spec.limit is not None:
        builder.push_limit(spec.limit, list(spec.sort))
        builder.spec.limit = spec.limit
        builder.spec.sort = list(spec.sort)
        builder.spec.limit_mode = spec.limit_mode


@dataclasses.dataclass
class RemoteColumnStats:
    """Connector-estimated per-column statistics (CostModel-compatible)."""

    ndv: int = 0
    min_value: Optional[object] = None
    max_value: Optional[object] = None


@dataclasses.dataclass
class RemoteTableStats:
    """Connector-estimated table statistics: the shape
    :class:`~repro.core.optimizer.cost.CostModel` reads (``row_count`` +
    per-column ``ndv``/``min_value``/``max_value``), so federated join
    order, broadcast choices, and ``shuffle.partitions: auto`` are costed
    on remote estimates instead of the empty-stats default."""

    row_count: float = 0.0
    columns: Dict[str, RemoteColumnStats] = dataclasses.field(
        default_factory=dict)


def stats_from_batch(batch: VectorBatch,
                     sample_rows: int = 1 << 17) -> RemoteTableStats:
    """Estimate RemoteTableStats from an in-memory batch (shared by the
    embedded connectors): NDV from a bounded sample, min/max for numerics."""
    n = batch.num_rows
    out = RemoteTableStats(row_count=float(n))
    for name, col in batch.cols.items():
        sample = col[:sample_rows]
        ndv = int(len(np.unique(sample)))
        if len(sample) < n and ndv == len(sample):
            ndv = n  # looks unique in the sample: assume a key column
        cs = RemoteColumnStats(ndv=ndv)
        if col.dtype.kind in "iuf" and n:
            cs.min_value = col.min().item()
            cs.max_value = col.max().item()
        out.columns[name] = cs
    return out


class Writer:
    """Batched write channel to an external system (replaces one-shot
    ``StorageHandler.write``): stream morsels in, make them visible on
    ``commit``."""

    def write_batch(self, batch: VectorBatch) -> None:
        raise NotImplementedError

    def commit(self) -> None:
        raise NotImplementedError

    def abort(self) -> None:  # pragma: no cover - connectors may override
        pass


# ===========================================================================
# optimizer-side negotiation
# ===========================================================================
def _to_raw(e: A.Expr, alias: str, proj_defs: Dict[str, A.Expr]) -> Optional[A.Expr]:
    """Rewrite a bound expr over the scan's qualified columns into raw
    column names; None when it references anything else.

    A column defined by a *computed* projection expression (the binder's
    synthetic ``aa_N``/``gk_N`` names) is NOT a remote column — it must
    resolve to None so the aggregate/filter stays local instead of pushing
    a nonexistent column name to the connector.
    """
    if isinstance(e, A.Col):
        q = e.qualified
        d = proj_defs.get(q)
        if d is not None and d.key() != e.key():
            if isinstance(d, A.Col):
                return _to_raw(d, alias, proj_defs)
            return None  # computed projection output, not a remote column
        if q.startswith(alias + "."):
            return A.Col(q[len(alias) + 1:])
        return None
    if isinstance(e, A.SubqueryExpr):
        return None
    kids = [_to_raw(c, alias, proj_defs) for c in e.children()]
    if any(k is None for k in kids):
        return None
    from ..sql.binder import _rebuild

    return _rebuild(e, kids)


@dataclasses.dataclass
class _Chain:
    """The single-input plan prefix above one FederatedScan, top-down."""

    limit: Optional[P.Limit] = None
    sort: Optional[P.Sort] = None
    rename: Optional[P.Project] = None   # pure-Col rename above the aggregate
    agg: Optional[P.Aggregate] = None
    proj: Optional[P.Project] = None
    filter: Optional[P.Filter] = None
    scan: Optional[P.FederatedScan] = None


def _match_chain(node: P.PlanNode) -> Optional[_Chain]:
    c = _Chain()
    if isinstance(node, P.Limit):
        c.limit = node
        node = node.input
    if isinstance(node, P.Sort):
        c.sort = node
        node = node.input
    if isinstance(node, P.Project) and all(
        isinstance(e, A.Col) for e, _ in node.exprs
    ) and isinstance(node.input, P.Aggregate):
        c.rename = node
        node = node.input
    if isinstance(node, P.Aggregate):
        c.agg = node
        node = node.input
    if isinstance(node, P.Project):
        c.proj = node
        node = node.input
    if isinstance(node, P.Filter):
        c.filter = node
        node = node.input
    if not isinstance(node, P.FederatedScan) or node.spec is not None:
        return None
    c.scan = node
    return c


def negotiate_federated(plan: P.PlanNode, resolve_handler: Callable,
                        config: dict) -> Tuple[P.PlanNode, Dict[str, dict]]:
    """Negotiate pushdown for every federated scan in ``plan``.

    Returns ``(new_plan, summary)`` where ``summary`` maps table name to a
    pushed-vs-residual report (surfaced as ``info['federated_pushdown']``
    and visible in EXPLAIN through the rewritten plan itself).
    """
    out: Dict[str, dict] = {}

    def try_at(node: P.PlanNode, parent: Optional[P.PlanNode],
               idx: int) -> None:
        chain = _match_chain(node)
        if chain is not None:
            handler = resolve_handler(chain.scan.table.handler)
            if handler is not None:
                new_top, summary = _negotiate_chain(chain, handler, config)
                out[chain.scan.table.name] = summary
                if parent is None:
                    nonlocal plan
                    plan = new_top
                else:
                    parent.inputs[idx] = new_top
                return
        for i, child in enumerate(node.inputs):
            try_at(child, node, i)

    try_at(plan, None, 0)
    return plan, out


def _negotiate_chain(c: _Chain, handler, config: dict) -> Tuple[P.PlanNode, dict]:
    scan = c.scan
    alias = scan.alias
    proj_defs: Dict[str, A.Expr] = (
        {n: e for e, n in c.proj.exprs} if c.proj is not None else {}
    )
    builder = handler.scan_builder(scan.table, config)

    # ---- filters: partial pushdown, untranslatable/declined stay local ----
    pushed_filters: List[A.Expr] = []
    residual_filters: List[A.Expr] = []
    if c.filter is not None:
        conjuncts = split_conjuncts(c.filter.predicate)
        if config.get("federation.push_filters", True):
            offer, originals = [], []
            for conj in conjuncts:
                raw = _to_raw(conj, alias, proj_defs)
                if raw is None:
                    residual_filters.append(conj)
                else:
                    offer.append(raw)
                    originals.append(conj)
            declined = builder.push_filters(offer) if offer else []
            declined_keys = {d.key() for d in declined}
            for raw, orig in zip(offer, originals):
                if raw.key() in declined_keys:
                    residual_filters.append(orig)
                else:
                    pushed_filters.append(raw)
        else:
            residual_filters = list(conjuncts)

    # ---- aggregate: full / partial / none ---------------------------------
    agg_mode = NONE
    if (
        c.agg is not None
        and not c.agg.grouping_sets
        and not residual_filters
        and config.get("federation.push_aggregate", True)
        and all(s.fn in FOLD_FN and not s.distinct for s in c.agg.aggs)
    ):
        raw_keys, raw_aggs, ok = [], [], True
        for k in c.agg.group_keys:
            raw = _to_raw(A.Col(_base(k), _qual(k)), alias, proj_defs)
            if not isinstance(raw, A.Col):
                ok = False
                break
            raw_keys.append(raw.name)
        if ok:
            for s in c.agg.aggs:
                if s.arg is None:
                    raw_aggs.append((s.fn, None, s.out_name))
                    continue
                raw = _to_raw(s.arg, alias, proj_defs)
                if not isinstance(raw, A.Col):
                    ok = False
                    break
                raw_aggs.append((s.fn, raw.name, s.out_name))
        if ok:
            agg_mode = builder.push_aggregate(raw_keys, raw_aggs)

    # ---- projection (when the aggregate stays local) ----------------------
    projection_pushed = False
    if agg_mode == NONE and config.get("federation.push_projection", True):
        needed: List[str] = []
        seen = set()

        def need(e: Optional[A.Expr]):
            if e is None:
                return
            raw = _to_raw(e, alias, {})
            for col in (A.walk(raw) if raw is not None else ()):
                if isinstance(col, A.Col) and col.name not in seen:
                    seen.add(col.name)
                    needed.append(col.name)

        consumers: List[A.Expr] = []
        if c.proj is not None:
            consumers.extend(e for e, _ in c.proj.exprs)
        if c.agg is not None:
            consumers.extend(A.Col(_base(k), _qual(k)) for k in c.agg.group_keys)
            consumers.extend(s.arg for s in c.agg.aggs if s.arg is not None)
        if c.proj is None and c.agg is None:
            consumers.extend(
                A.Col(_base(n), _qual(n)) for n in scan.output_names())
        for e in consumers:
            need(e)
        for e in residual_filters:
            need(e)
        table_cols = [col for col, _ in scan.table.schema]
        if (needed and all(n in table_cols for n in needed)
                and set(needed) != set(table_cols)):
            projection_pushed = builder.push_projection(needed)

    # ---- scan output naming ----------------------------------------------
    if agg_mode in (PARTIAL, FULL):
        output_cols = c.agg.output_names()
    elif projection_pushed:
        output_cols = [f"{alias}.{n}" for n in builder.output_columns()]
    else:
        output_cols = [f"{alias}.{col}" for col, _ in scan.table.schema]

    # ---- sort + limit -----------------------------------------------------
    # LIMIT commutes through row-wise Projects, so it is pushable whenever
    # the filter was fully absorbed and the aggregate (if any) was absorbed
    # FULL; a sort must additionally translate its keys down to scan-output
    # positions (through the rename/projection definitions), else both stay.
    limit_mode = NONE
    absorbed_below = not residual_filters and (c.agg is None or agg_mode == FULL)
    if (
        c.limit is not None and absorbed_below
        and config.get("federation.push_limit", True)
    ):
        sort_pos: Optional[List[Tuple[int, bool]]] = []
        if c.sort is not None:
            for key, desc in c.sort.keys:
                pos = _sort_position(key, c, output_cols)
                if pos is None:
                    sort_pos = None
                    break
                sort_pos.append((pos, desc))
        if sort_pos is not None:
            limit_mode = builder.push_limit(int(c.limit.n), sort_pos)

    # ---- rebuild the local chain over the negotiated scan -----------------
    new_scan = P.FederatedScan(
        scan.table, alias, scan.columns,
        spec=builder.spec, output_cols=output_cols,
    )
    sub: P.PlanNode = new_scan
    if residual_filters:
        c.filter.inputs = [sub]
        c.filter.predicate = conjoin(residual_filters)
        sub = c.filter
    if agg_mode == NONE and c.proj is not None:
        c.proj.inputs = [sub]
        sub = c.proj
    if agg_mode in (NONE, PARTIAL) and c.agg is not None:
        if agg_mode == PARTIAL:
            # the connector returns per-split partials; the local aggregate
            # becomes the merging fold (COUNT partials re-combine with SUM)
            c.agg.aggs = [
                P.AggSpec(FOLD_FN[s.fn], A.Col(s.out_name), False, s.out_name)
                for s in c.agg.aggs
            ]
        c.agg.inputs = [sub]
        sub = c.agg
    if c.rename is not None:
        c.rename.inputs = [sub]
        sub = c.rename
    if c.sort is not None and limit_mode != FULL:
        c.sort.inputs = [sub]
        sub = c.sort
    if c.limit is not None and limit_mode != FULL:
        c.limit.inputs = [sub]
        sub = c.limit

    summary = {
        "pushed": builder.spec.summary(),
        "residual": {
            k: v for k, v in {
                "filters": len(residual_filters),
                "aggregate": (
                    "merge" if agg_mode == PARTIAL
                    else "local" if (c.agg is not None and agg_mode == NONE)
                    else None),
                "limit": ("merge" if (c.limit is not None
                                      and limit_mode == PARTIAL)
                          else "local" if (c.limit is not None
                                           and limit_mode == NONE)
                          else None),
            }.items() if v
        },
    }
    return sub, summary


# ===========================================================================
# split expansion (compile time, after plan-cache deepcopy)
# ===========================================================================
def expand_federated_splits(plan: P.PlanNode, resolve_handler: Callable,
                            config: dict) -> P.PlanNode:
    """Fan each federated scan out over its connector's splits.

    A multi-split scan becomes ``UNION ALL`` of per-split scans; the DAG
    compiler turns every ``FederatedScan`` into its own vertex, so splits
    execute in parallel and stream through the exchange layer.
    """

    def visit(node: P.PlanNode, parent: Optional[P.PlanNode], idx: int):
        for i, child in enumerate(list(node.inputs)):
            visit(child, node, i)
        if not isinstance(node, P.FederatedScan) or node.split is not None:
            return
        handler = resolve_handler(node.table.handler)
        if handler is None:
            return
        builder = handler.scan_builder(node.table, config)
        apply_spec(builder, node.spec)
        splits = builder.to_splits() or [None]
        if len(splits) <= 1:
            node.split = splits[0]
            node.total_splits = 1
            return
        parts = [
            P.FederatedScan(node.table, node.alias, node.columns,
                            spec=node.spec, output_cols=node._output_cols,
                            split=s, total_splits=len(splits))
            for s in splits
        ]
        union = P.Union(parts, all=True)
        if parent is None:
            nonlocal plan
            plan = union
        else:
            parent.inputs[idx] = union

    visit(plan, None, 0)
    return plan


def _sort_position(key: str, c: _Chain,
                   output_cols: List[str]) -> Optional[int]:
    """Map a sort key (an output name of the node below the Sort) to a
    position in the scan's output columns, chasing pure-Col definitions
    through the rename/projection nodes kept locally."""
    if key in output_cols:
        return output_cols.index(key)
    for prj in (c.rename, c.proj):
        if prj is None:
            continue
        defs = {n: e for e, n in prj.exprs}
        e = defs.get(key)
        if isinstance(e, A.Col) and e.qualified in output_cols:
            return output_cols.index(e.qualified)
    return None


def _base(qualified: str) -> str:
    return qualified.split(".", 1)[1] if "." in qualified else qualified


def _qual(qualified: str) -> Optional[str]:
    return qualified.split(".", 1)[0] if "." in qualified else None
