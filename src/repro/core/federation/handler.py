"""Storage-handler interface (paper §6.1).

A handler consists of (i) an *input format* — how to read (and split) data
from the external engine, (ii) an *output format* — how to write to it,
(iii) a *SerDe* translating between Hive's internal columnar representation
and the engine's, and (iv) a *metastore hook* receiving notifications for
transactions against HMS (table creation, row inserts, ...).

The minimum usable handler implements the input format + deserializer; a
handler that supports Calcite-generated pushdown additionally accepts a
``pushed_query`` (engine-native query object) in its input format and may
split it into parallel sub-queries (paper §6.2).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from ..metastore import TableDesc
from ..runtime.vector import VectorBatch


class SerDe:
    """Serializer/deserializer between Tahoe columns and engine rows."""

    def serialize(self, batch: VectorBatch) -> List[dict]:
        names = batch.column_names
        return [dict(zip(names, row)) for row in batch.to_rows()]

    def deserialize(self, rows: List[dict], dtypes: Optional[Dict[str, str]] = None) -> VectorBatch:
        if not rows:
            return VectorBatch({})
        cols = {k: np.array([r[k] for r in rows]) for k in rows[0]}
        return VectorBatch(cols)


class StorageHandler:
    """Base class; subclasses register under a handler name."""

    name: str = "base"
    serde: SerDe = SerDe()
    supports_pushdown: bool = False

    # ---- input format -------------------------------------------------------
    def splits(self, table: TableDesc, pushed_query: Optional[dict]) -> List[object]:
        """Work units for parallel reads; default: one split."""
        return [None]

    def read_split(self, table: TableDesc, split: object,
                   pushed_query: Optional[dict]) -> VectorBatch:
        raise NotImplementedError

    def read(self, table: TableDesc, pushed_query: Optional[dict] = None) -> VectorBatch:
        parts = [
            self.read_split(table, s, pushed_query)
            for s in self.splits(table, pushed_query)
        ]
        parts = [p for p in parts if p.num_rows or len(parts) == 1]
        return VectorBatch.concat(parts) if parts else VectorBatch({})

    # ---- output format -------------------------------------------------------
    def write(self, table: TableDesc, batch: VectorBatch) -> None:
        raise NotImplementedError(f"{self.name} handler is read-only")

    # ---- schema inference (CREATE EXTERNAL TABLE without column list) --------
    def infer_schema(self, props: Dict[str, str]) -> Optional[List[tuple]]:
        return None

    # ---- pushdown (paper §6.2) -------------------------------------------------
    def try_pushdown(self, plan, table: TableDesc) -> Optional[dict]:
        """Translate a plan subtree rooted over this table's scan into an
        engine-native query; None if unsupported."""
        return None

    # ---- metastore hook --------------------------------------------------------
    def metastore_hook(self):
        return None


class HandlerRegistry:
    def __init__(self):
        self._handlers: Dict[str, StorageHandler] = {}

    def register(self, handler: StorageHandler, hms=None) -> None:
        self._handlers[handler.name] = handler
        hook = handler.metastore_hook()
        if hook is not None and hms is not None:
            hms.register_hook(hook)

    def get(self, name: str) -> Optional[StorageHandler]:
        # allow full class-style names like the paper's
        # 'org.apache.hadoop.hive.druid.DruidStorageHandler'
        if name in self._handlers:
            return self._handlers[name]
        for key, h in self._handlers.items():
            if key in name.lower():
                return h
        return None

    def as_dict(self) -> Dict[str, StorageHandler]:
        return dict(self._handlers)
