"""Storage-handler / DataSource interface (paper §6.1, redesigned).

A handler (connector) consists of (i) a *scan builder* — the
capability-negotiated read path (filter/projection/aggregate/limit pushdown
plus split-parallel streaming readers; see
:mod:`repro.core.federation.datasource`), (ii) a *writer* — a batched
``write_batch``/``commit`` output channel, (iii) a *SerDe* translating
between the warehouse's columnar representation and the engine's rows, and
(iv) a *metastore hook* receiving notifications for transactions against
HMS (table creation, row inserts, ...).

Handlers also expose a *catalog surface* (``list_schemas`` /
``list_tables`` / ``discover``) so a whole external system can be mounted
at once via ``CREATE CATALOG`` instead of table-by-table ``STORED BY``
(which stays supported on the same API).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from ..metastore import TableDesc
from ..runtime.vector import VectorBatch
from .datasource import ScanBuilder, Writer


class SerDe:
    """Serializer/deserializer between Tahoe columns and engine rows."""

    def serialize(self, batch: VectorBatch) -> List[dict]:
        names = batch.column_names
        return [dict(zip(names, row)) for row in batch.to_rows()]

    def deserialize(self, rows: List[dict], dtypes: Optional[Dict[str, str]] = None) -> VectorBatch:
        """Rows may have heterogeneous keys: columns are the *union* of the
        keys across all rows (not just ``rows[0]``), with missing values
        null-filled (NaN for numerics, empty string otherwise)."""
        if not rows:
            return VectorBatch({})
        keys: List[str] = []
        seen = set()
        for r in rows:
            for k in r:
                if k not in seen:
                    seen.add(k)
                    keys.append(k)
        cols: Dict[str, np.ndarray] = {}
        for k in keys:
            vals = [r.get(k) for r in rows]
            present = [v for v in vals if v is not None]
            numeric = all(isinstance(v, (int, float, np.integer, np.floating))
                          and not isinstance(v, bool) for v in present)
            if numeric and present:
                cols[k] = np.array(
                    [float(v) if v is not None else np.nan for v in vals])
            elif present:
                cols[k] = np.array(["" if v is None else str(v) for v in vals])
            else:  # all-null column: no type evidence, default numeric NULLs
                cols[k] = np.full(len(vals), np.nan)
        return VectorBatch(cols)


class StorageHandler:
    """Base connector; subclasses register under a handler name."""

    name: str = "base"
    serde: SerDe = SerDe()
    default_schema: str = "default"

    # ---- scan path (capability negotiation + split-parallel streams) -------
    def scan_builder(self, table: TableDesc,
                     config: Optional[dict] = None) -> ScanBuilder:
        """A fresh negotiation context for one scan of ``table``."""
        return ScanBuilder(self, table, config)

    # ---- write path ----------------------------------------------------------
    def writer(self, table: TableDesc) -> Writer:
        raise NotImplementedError(f"{self.name} handler is read-only")

    # ---- schema inference (CREATE EXTERNAL TABLE without column list) --------
    def infer_schema(self, props: Dict[str, str]) -> Optional[List[tuple]]:
        return None

    # ---- catalog surface (CREATE CATALOG ... USING <name>) -------------------
    def list_schemas(self) -> List[str]:
        return [self.default_schema]

    def list_tables(self, schema: str) -> List[str]:
        return []

    def discover(self, schema: str, table: str) -> Optional[List[Tuple[str, str]]]:
        """Remote schema of ``schema.table``; None when it does not exist."""
        return None

    def table_props(self, schema: str, table: str) -> Dict[str, str]:
        """Connector props identifying ``schema.table`` in a TableDesc."""
        return {}

    # ---- metastore hook --------------------------------------------------------
    def metastore_hook(self):
        return None


class HandlerRegistry:
    def __init__(self):
        self._handlers: Dict[str, StorageHandler] = {}

    def register(self, handler: StorageHandler, hms=None) -> None:
        self._handlers[handler.name] = handler
        hook = handler.metastore_hook()
        if hook is not None and hms is not None:
            hms.register_hook(hook)

    def get(self, name: str) -> Optional[StorageHandler]:
        # allow full class-style names like the paper's
        # 'org.apache.hadoop.hive.druid.DruidStorageHandler'
        if name in self._handlers:
            return self._handlers[name]
        for key, h in self._handlers.items():
            if key in name.lower():
                return h
        return None

    def as_dict(self) -> Dict[str, StorageHandler]:
        return dict(self._handlers)
