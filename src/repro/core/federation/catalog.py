"""First-class catalogs: mount a whole external system at once (paper §6).

``CREATE CATALOG sales USING jdbc WITH (db = '/data/crm.db')`` registers a
named connector instance; queries then address its tables with three-part
names (``sales.main.customers``, or two-part ``sales.customers`` through
the connector's default schema) without any per-table ``STORED BY`` DDL.

Remote schemas are discovered *lazily*: the first reference to
``catalog.schema.table`` asks the connector for the table's columns and the
resulting ``TableDesc`` is cached on the catalog (dropped by
``invalidate()``/``DROP CATALOG``).  Catalog definitions persist in the
metastore, so a re-opened warehouse re-mounts its catalogs.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

from ..metastore import Metastore, TableDesc

# connector name -> factory(props) -> StorageHandler instance
CONNECTORS: Dict[str, Callable[[dict], object]] = {}


def register_connector(name: str, factory: Callable[[dict], object]) -> None:
    CONNECTORS[name] = factory


def _builtin_connectors() -> None:
    if CONNECTORS:
        return
    from .druid import DruidHandler
    from .jdbc import JdbcHandler
    from .memtable import MemTableHandler

    register_connector("jdbc", JdbcHandler.from_props)
    register_connector("druid", DruidHandler.from_props)
    register_connector("memtable", MemTableHandler.from_props)


class Catalog:
    """One mounted external system: a connector instance + lazy schema cache."""

    def __init__(self, name: str, connector: str, props: Dict[str, str],
                 handler) -> None:
        self.name = name
        self.connector = connector
        self.props = dict(props)
        self.handler = handler
        self._descs: Dict[str, TableDesc] = {}

    @property
    def default_schema(self) -> str:
        return self.props.get("default_schema", self.handler.default_schema)

    def list_schemas(self) -> List[str]:
        return self.handler.list_schemas()

    def list_tables(self, schema: Optional[str] = None) -> List[str]:
        return self.handler.list_tables(schema or self.default_schema)

    def table_desc(self, schema: Optional[str], table: str) -> TableDesc:
        """Lazy remote-schema discovery, cached per (schema, table)."""
        schema = schema or self.default_schema
        key = f"{schema}.{table}"
        desc = self._descs.get(key)
        if desc is not None:
            return desc
        cols = self.handler.discover(schema, table)
        if cols is None:
            raise KeyError(
                f"catalog {self.name!r} has no table {schema}.{table}"
            )
        desc = TableDesc(
            name=f"{self.name}.{key}",
            schema=[tuple(c) for c in cols],
            partition_cols=[],
            location="",
            props={**self.props, **self.handler.table_props(schema, table)},
            handler=f"catalog:{self.name}",
        )
        self._descs[key] = desc
        return desc

    def invalidate(self) -> None:
        self._descs.clear()


class CatalogRegistry:
    """``Warehouse.catalogs``: name -> :class:`Catalog`, metastore-persisted."""

    def __init__(self, hms: Metastore):
        _builtin_connectors()
        self.hms = hms
        self._catalogs: Dict[str, Catalog] = {}
        for name, connector, props in hms.list_catalogs():
            self._catalogs[name] = self._instantiate(name, connector, props)

    @staticmethod
    def _instantiate(name: str, connector: str, props: Dict[str, str]) -> Catalog:
        factory = CONNECTORS.get(connector)
        if factory is None:
            raise ValueError(
                f"unknown connector {connector!r}; "
                f"available: {sorted(CONNECTORS)}"
            )
        return Catalog(name, connector, props, factory(props))

    def create(self, name: str, connector: str,
               props: Optional[Dict[str, str]] = None) -> Catalog:
        if name in self._catalogs:
            raise ValueError(f"catalog {name!r} already exists")
        cat = self._instantiate(name, connector, props or {})
        self.hms.create_catalog(name, connector, props or {})
        self._catalogs[name] = cat
        return cat

    def drop(self, name: str, if_exists: bool = False) -> None:
        if name not in self._catalogs:
            if if_exists:
                return
            raise KeyError(f"no catalog {name!r}")
        self.hms.drop_catalog(name)
        del self._catalogs[name]

    def get(self, name: str) -> Optional[Catalog]:
        return self._catalogs.get(name)

    def names(self) -> List[str]:
        return sorted(self._catalogs)

    def items(self):
        return self._catalogs.items()

    def handler_map(self) -> Dict[str, object]:
        """Execution-context handler entries for every mounted catalog."""
        return {f"catalog:{n}": c.handler for n, c in self._catalogs.items()}

    def __contains__(self, name: str) -> bool:
        return name in self._catalogs

    def __len__(self) -> int:
        return len(self._catalogs)
