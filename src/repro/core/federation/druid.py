"""Druid storage handler (paper §6.1-§6.2, Figures 6 & 8).

An embedded columnar mini-OLAP store standing in for Apache Druid: data
sources are time-partitioned columnar segments optimized for filtered
groupBy/topN aggregations.  The handler supports:

  * registering existing data sources (schema inferred from Druid metadata),
  * creating data sources from Hive (output format),
  * Calcite-style computation pushdown: the optimizer matches
    Scan->Filter?->Aggregate->Sort?->Limit? plan prefixes over Druid tables
    and translates them into Druid JSON queries (groupBy / timeseries / scan
    query types), which the input format executes split-parallel.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..metastore import TableDesc
from ..optimizer import plan as P
from ..runtime.vector import VectorBatch
from ..sql import ast as A
from ..sql.binder import split_conjuncts
from .handler import StorageHandler


class DruidSegment:
    """One time-chunked columnar segment."""

    def __init__(self, batch: VectorBatch):
        self.batch = batch

    @property
    def num_rows(self):
        return self.batch.num_rows


class DruidStore:
    """The embedded 'cluster': datasource name -> list of segments."""

    def __init__(self, segment_rows: int = 100_000):
        self.datasources: Dict[str, List[DruidSegment]] = {}
        self.segment_rows = segment_rows
        self.queries_served: List[dict] = []

    def create_datasource(self, name: str, batch: VectorBatch) -> None:
        segs = [
            DruidSegment(batch.slice(i, min(i + self.segment_rows, batch.num_rows)))
            for i in range(0, max(batch.num_rows, 1), self.segment_rows)
        ]
        self.datasources[name] = segs

    def append(self, name: str, batch: VectorBatch) -> None:
        if name not in self.datasources:
            self.create_datasource(name, batch)
        else:
            self.datasources[name].append(DruidSegment(batch))

    def schema(self, name: str) -> Optional[List[Tuple[str, str]]]:
        segs = self.datasources.get(name)
        if not segs:
            return None
        out = []
        for col, arr in segs[0].batch.cols.items():
            kind = arr.dtype.kind
            sql_t = {"i": "BIGINT", "u": "BIGINT", "f": "DOUBLE", "b": "BOOLEAN"}.get(
                kind, "STRING"
            )
            out.append((col, sql_t))
        return out


class DruidHandler(StorageHandler):
    name = "druid"
    supports_pushdown = True

    def __init__(self, store: Optional[DruidStore] = None):
        self.store = store or DruidStore()

    # ---- input format ----------------------------------------------------------
    def splits(self, table: TableDesc, pushed_query):
        src = table.props.get("druid.datasource", table.name)
        segs = self.store.datasources.get(src, [])
        # queries with ordering/limit can't split blindly; aggregate queries
        # split per-segment and merge (the paper notes handlers may split
        # pushed queries into parallel sub-queries)
        if pushed_query and pushed_query.get("limitSpec"):
            return [("all", None)]
        return [("seg", i) for i in range(len(segs))] or [("all", None)]

    def read_split(self, table: TableDesc, split, pushed_query):
        src = table.props.get("druid.datasource", table.name)
        segs = self.store.datasources.get(src, [])
        if split is None or split[0] == "all":
            batch = VectorBatch.concat([s.batch for s in segs]) if segs else VectorBatch({})
            return self._run_query(batch, pushed_query, final=True)
        batch = segs[split[1]].batch
        return self._run_query(batch, pushed_query, final=False)

    def read(self, table: TableDesc, pushed_query: Optional[dict] = None) -> VectorBatch:
        if pushed_query is not None:
            self.store.queries_served.append(pushed_query)
        parts = [
            self.read_split(table, s, pushed_query)
            for s in self.splits(table, pushed_query)
        ]
        out = VectorBatch.concat([p for p in parts if p.cols]) if parts else VectorBatch({})
        # merge partial per-segment aggregates
        if pushed_query and pushed_query.get("queryType") in ("groupBy", "timeseries") \
           and len(parts) > 1:
            out = _merge_partials(out, pushed_query)
        if pushed_query and pushed_query.get("limitSpec"):
            out = _apply_limitspec(out, pushed_query["limitSpec"])
        return out

    # ---- output format -----------------------------------------------------------
    def write(self, table: TableDesc, batch: VectorBatch) -> None:
        src = table.props.get("druid.datasource", table.name)
        self.store.append(src, batch)

    def infer_schema(self, props: Dict[str, str]):
        src = props.get("druid.datasource")
        return self.store.schema(src) if src else None

    # ---- pushdown translation (paper §6.2, Figure 6) ---------------------------------
    def try_pushdown(self, plan: P.PlanNode, table: TableDesc) -> Optional[dict]:
        return translate_to_druid(plan, table)

    # ---- execution of Druid JSON over a segment -----------------------------------------
    def _run_query(self, batch: VectorBatch, q: Optional[dict], final: bool) -> VectorBatch:
        if q is None:
            return batch
        if q.get("filter"):
            mask = _eval_druid_filter(batch, q["filter"])
            batch = batch.select(mask)
        if q["queryType"] in ("groupBy", "timeseries"):
            dims = q.get("dimensions", [])
            from ..optimizer.plan import AggSpec
            from .handler import VectorBatch as _VB  # noqa

            from ..runtime.exec import _group_codes, _agg_column

            codes, first = _group_codes(batch, dims) if dims else (
                np.zeros(batch.num_rows, dtype=np.int64),
                np.array([0] if batch.num_rows else [], dtype=np.int64),
            )
            ng = len(first) if dims else 1
            order_of_first = np.argsort(first) if dims else np.array([0])
            remap = np.empty(max(ng, 1), dtype=np.int64)
            remap[order_of_first] = np.arange(ng)
            codes = remap[codes] if batch.num_rows else codes
            out = {}
            for d in dims:
                out[d] = batch.cols[d][np.sort(first)]
            for agg in q.get("aggregations", []):
                fn = {"doubleSum": "sum", "floatSum": "sum", "longSum": "sum",
                      "count": "count", "doubleMin": "min", "doubleMax": "max",
                      "longMin": "min", "longMax": "max"}[agg["type"]]
                arg = batch.cols.get(agg.get("fieldName")) if agg.get("fieldName") else None
                spec = AggSpec(fn, A.Col("x") if arg is not None else None, False, agg["name"])
                out[agg["name"]] = _agg_column(spec, arg, codes, ng)
            return VectorBatch(out)
        if q["queryType"] == "scan":
            cols = q.get("columns") or batch.column_names
            return batch.project([c for c in cols if c in batch.cols])
        raise ValueError(f"unsupported druid queryType {q['queryType']}")


# ---------------------------------------------------------------------------
# plan -> Druid JSON translation
# ---------------------------------------------------------------------------
def translate_to_druid(plan: P.PlanNode, table: TableDesc) -> Optional[dict]:
    """Match Aggregate(Project?(Filter?(FederatedScan))) / Filter?(FederatedScan)
    prefixes and emit Druid JSON.  Sort+Limit over the aggregate fold into
    ``limitSpec`` (Figure 6)."""
    node = plan
    limit_spec = None
    if isinstance(node, P.Limit):
        limit = node.n
        inner = node.input
        columns = []
        if isinstance(inner, P.Sort):
            columns = [
                {"dimension": k, "direction": "descending" if d else "ascending"}
                for k, d in inner.keys
            ]
            inner = inner.input
        limit_spec = {"limit": limit, "columns": columns}
        node = inner

    # the binder's final projection may sit between sort/limit and the
    # aggregate: unwrap it, remembering the output renames (Figure 6 shape)
    rename: Dict[str, str] = {}
    if isinstance(node, P.Project) and not isinstance(node, P.FederatedScan):
        if all(isinstance(e, A.Col) for e, _ in node.exprs) and any(
            isinstance(c, P.Aggregate) for c in node.inputs
        ):
            rename = {n: e.qualified for e, n in node.exprs}
            node = node.input
    if limit_spec is not None and rename:
        for col in limit_spec["columns"]:
            col["dimension"] = rename.get(col["dimension"], col["dimension"])

    agg_node = None
    if isinstance(node, P.Aggregate) and not node.grouping_sets:
        agg_node = node
        node = node.input
    proj_defs: Dict[str, A.Expr] = {}
    if isinstance(node, P.Project):
        ok = all(isinstance(e, A.Col) for e, _ in node.exprs)
        if not ok:
            return None
        proj_defs = {n: e for e, n in node.exprs}
        node = node.input
    filt = None
    if isinstance(node, P.Filter):
        filt = node.predicate
        node = node.input
    if not isinstance(node, P.FederatedScan) or node.table.name != table.name:
        return None
    if node.pushed_query is not None:
        return None

    alias = node.alias
    src = table.props.get("druid.datasource", table.name)

    def raw(col_name: str) -> Optional[str]:
        e = proj_defs.get(col_name, None)
        if e is not None and isinstance(e, A.Col) and e.qualified != col_name:
            return raw(e.qualified)
        if col_name.startswith(alias + "."):
            return col_name[len(alias) + 1:]
        return col_name if "." not in col_name else None

    dfilter = None
    if filt is not None:
        dfilter = _filter_to_druid(filt, raw)
        if dfilter is None:
            return None

    q: dict = {"queryType": "scan", "dataSource": src, "granularity": "all"}
    if dfilter is not None:
        q["filter"] = dfilter

    if agg_node is not None:
        dims = []
        for k in agg_node.group_keys:
            r = raw(k)
            if r is None:
                return None
            dims.append(r)
        aggs = []
        for spec in agg_node.aggs:
            if spec.distinct:
                return None
            if spec.arg is None:
                aggs.append({"type": "count", "name": spec.out_name})
                continue
            if not isinstance(spec.arg, A.Col):
                return None
            r = raw(spec.arg.qualified)
            if r is None:
                return None
            ty = {"sum": "doubleSum", "min": "doubleMin", "max": "doubleMax",
                  "count": "count"}.get(spec.fn)
            if ty is None:
                return None
            aggs.append({"type": ty, "name": spec.out_name, "fieldName": r})
        q["queryType"] = "groupBy" if dims else "timeseries"
        q["dimensions"] = dims
        q["aggregations"] = aggs
        inner_names = list(agg_node.group_keys) + [a.out_name for a in agg_node.aggs]
        if rename:  # surface the outer projection's output names
            inv = {v: k for k, v in rename.items()}
            q["outputNames"] = [inv.get(n, n) for n in inner_names]
        else:
            q["outputNames"] = inner_names
        q["dimensionOutputs"] = dict(zip(dims, agg_node.group_keys))
    else:
        if limit_spec is not None:
            return None  # plain scan+limit not worth pushing
        out_names = plan.output_names()
        cols = []
        for n in out_names:
            r = raw(n)
            if r is None:
                return None
            cols.append(r)
        q["columns"] = cols
        q["outputNames"] = out_names

    if limit_spec is not None:
        # limitSpec column names refer to aggregate outputs
        q["limitSpec"] = limit_spec
    return q


def _filter_to_druid(pred: A.Expr, raw) -> Optional[dict]:
    fields = []
    for c in split_conjuncts(pred):
        f = _one_filter(c, raw)
        if f is None:
            return None
        fields.append(f)
    if len(fields) == 1:
        return fields[0]
    return {"type": "and", "fields": fields}


def _one_filter(c: A.Expr, raw) -> Optional[dict]:
    if isinstance(c, A.BinOp) and c.op in ("=", "<", "<=", ">", ">=", "!="):
        col, lit, op = None, None, c.op
        if isinstance(c.left, A.Col) and isinstance(c.right, A.Lit):
            col, lit = c.left, c.right.value
        elif isinstance(c.right, A.Col) and isinstance(c.left, A.Lit):
            flip = {"<": ">", "<=": ">=", ">": "<", ">=": "<=", "=": "=", "!=": "!="}
            col, lit, op = c.right, c.left.value, flip[c.op]
        if col is None:
            return None
        dim = raw(col.qualified)
        if dim is None:
            return None
        if op == "=":
            return {"type": "selector", "dimension": dim, "value": lit}
        if op == "!=":
            return {"type": "not", "field": {"type": "selector", "dimension": dim, "value": lit}}
        bound = {"type": "bound", "dimension": dim, "ordering": "numeric"}
        if op in ("<", "<="):
            bound["upper"] = lit
            bound["upperStrict"] = op == "<"
        else:
            bound["lower"] = lit
            bound["lowerStrict"] = op == ">"
        return bound
    if isinstance(c, A.Between) and not c.negated and isinstance(c.expr, A.Col):
        dim = raw(c.expr.qualified)
        if dim is None or not isinstance(c.low, A.Lit) or not isinstance(c.high, A.Lit):
            return None
        return {"type": "bound", "dimension": dim, "ordering": "numeric",
                "lower": c.low.value, "upper": c.high.value,
                "lowerStrict": False, "upperStrict": False}
    if isinstance(c, A.InList) and isinstance(c.expr, A.Col):
        dim = raw(c.expr.qualified)
        if dim is None:
            return None
        f = {"type": "in", "dimension": dim,
             "values": [v.value for v in c.values if isinstance(v, A.Lit)]}
        return {"type": "not", "field": f} if c.negated else f
    return None


def _eval_druid_filter(batch: VectorBatch, f: dict) -> np.ndarray:
    n = batch.num_rows
    t = f["type"]
    if t == "and":
        m = np.ones(n, dtype=bool)
        for sub in f["fields"]:
            m &= _eval_druid_filter(batch, sub)
        return m
    if t == "not":
        return ~_eval_druid_filter(batch, f["field"])
    col = batch.cols[f["dimension"]]
    if t == "selector":
        v = f["value"]
        if col.dtype.kind in ("U", "S"):
            v = str(v)
        return col == v
    if t == "in":
        vals = f["values"]
        if col.dtype.kind in ("U", "S"):
            vals = [str(v) for v in vals]
        return np.isin(col, np.array(vals))
    if t == "bound":
        m = np.ones(n, dtype=bool)
        if "lower" in f:
            m &= (col > f["lower"]) if f.get("lowerStrict") else (col >= f["lower"])
        if "upper" in f:
            m &= (col < f["upper"]) if f.get("upperStrict") else (col <= f["upper"])
        return m
    raise ValueError(f"unknown druid filter {t}")


def _merge_partials(out: VectorBatch, q: dict) -> VectorBatch:
    from ..optimizer.plan import AggSpec
    from ..runtime.exec import _agg_column, _group_codes

    dims = q.get("dimensions", [])
    codes, first = _group_codes(out, dims) if dims else (
        np.zeros(out.num_rows, dtype=np.int64),
        np.array([0] if out.num_rows else [], dtype=np.int64),
    )
    ng = len(first) if dims else 1
    order_of_first = np.argsort(first) if dims else np.array([0])
    remap = np.empty(max(ng, 1), dtype=np.int64)
    remap[order_of_first] = np.arange(ng)
    codes = remap[codes] if out.num_rows else codes
    merged = {}
    for d in dims:
        merged[d] = out.cols[d][np.sort(first)]
    for agg in q.get("aggregations", []):
        # partials merge with SUM for sums/counts, MIN/MAX for min/max
        fold = {"doubleSum": "sum", "floatSum": "sum", "longSum": "sum",
                "count": "sum", "doubleMin": "min", "doubleMax": "max",
                "longMin": "min", "longMax": "max"}[agg["type"]]
        spec = AggSpec(fold, A.Col("x"), False, agg["name"])
        merged[agg["name"]] = _agg_column(spec, out.cols[agg["name"]], codes, ng)
    return VectorBatch(merged)


def _apply_limitspec(out: VectorBatch, spec: dict) -> VectorBatch:
    cols = spec.get("columns") or []
    if cols:
        out = out.sort_by([c["dimension"] for c in cols],
                          [c["direction"] == "descending" for c in cols])
    return out.slice(0, spec["limit"])
