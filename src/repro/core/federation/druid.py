"""Druid connector (paper §6.1-§6.2, Figures 6 & 8).

An embedded columnar mini-OLAP store standing in for Apache Druid: data
sources are time-partitioned columnar segments optimized for filtered
groupBy/topN aggregations.  The :class:`DruidScanBuilder` negotiates:

  * filters -> Druid filter JSON, conjunct-by-conjunct (untranslatable
    conjuncts stay local as a residual Filter);
  * projection -> scan-query column list;
  * aggregates -> groupBy / timeseries queries.  With multiple segments the
    pushdown is **partial**: each segment split returns per-segment partial
    aggregates and the warehouse's local Aggregate merges them (the paper's
    "handlers may split pushed queries into parallel sub-queries");
  * limit (+sort) -> ``limitSpec``, full only over a single split.

Splits map to segments and stream morsels through the exchange layer.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from ..metastore import TableDesc
from ..runtime.vector import DEFAULT_BATCH_ROWS, VectorBatch
from ..sql import ast as A
from .datasource import FULL, NONE, PARTIAL, AggPush, ScanBuilder, Writer
from .handler import StorageHandler


class DruidSegment:
    """One time-chunked columnar segment."""

    def __init__(self, batch: VectorBatch):
        self.batch = batch

    @property
    def num_rows(self):
        return self.batch.num_rows


class DruidStore:
    """The embedded 'cluster': datasource name -> list of segments."""

    def __init__(self, segment_rows: int = 100_000):
        self.datasources: Dict[str, List[DruidSegment]] = {}
        self.segment_rows = segment_rows
        self.queries_served: List[dict] = []
        self._stats_cache: Dict[str, object] = {}

    def create_datasource(self, name: str, batch: VectorBatch) -> None:
        segs = [
            DruidSegment(batch.slice(i, min(i + self.segment_rows, batch.num_rows)))
            for i in range(0, max(batch.num_rows, 1), self.segment_rows)
        ]
        self.datasources[name] = segs
        self._stats_cache.pop(name, None)

    def append(self, name: str, batch: VectorBatch) -> None:
        if name not in self.datasources:
            self.create_datasource(name, batch)
        else:
            self.datasources[name].append(DruidSegment(batch))
            self._stats_cache.pop(name, None)

    def stats(self, name: str):
        """Datasource row-count/NDV estimates (sampled per segment)."""
        if name not in self._stats_cache:
            from .datasource import stats_from_batch

            segs = self.datasources.get(name)
            if not segs:
                return None
            sample = VectorBatch.concat(
                [s.batch.slice(0, 1 << 15) for s in segs])
            stats = stats_from_batch(sample)
            stats.row_count = float(sum(s.num_rows for s in segs))
            self._stats_cache[name] = stats
        return self._stats_cache[name]

    def schema(self, name: str) -> Optional[List[Tuple[str, str]]]:
        segs = self.datasources.get(name)
        if not segs:
            return None
        out = []
        for col, arr in segs[0].batch.cols.items():
            kind = arr.dtype.kind
            sql_t = {"i": "BIGINT", "u": "BIGINT", "f": "DOUBLE", "b": "BOOLEAN"}.get(
                kind, "STRING"
            )
            out.append((col, sql_t))
        return out


class DruidHandler(StorageHandler):
    name = "druid"

    def __init__(self, store: Optional[DruidStore] = None):
        self.store = store or DruidStore()

    @classmethod
    def from_props(cls, props: Dict[str, str]) -> "DruidHandler":
        return cls(DruidStore(int(props.get("segment_rows", 100_000))))

    # ---- scan path -------------------------------------------------------------
    def scan_builder(self, table: TableDesc, config=None) -> "DruidScanBuilder":
        return DruidScanBuilder(self, table, config)

    # ---- write path ------------------------------------------------------------
    def writer(self, table: TableDesc) -> "DruidWriter":
        return DruidWriter(self, table)

    # ---- schema inference / catalog surface -------------------------------------
    def infer_schema(self, props: Dict[str, str]):
        src = props.get("druid.datasource")
        return self.store.schema(src) if src else None

    def list_tables(self, schema: str) -> List[str]:
        return sorted(self.store.datasources)

    def discover(self, schema: str, table: str):
        return self.store.schema(table)

    def table_props(self, schema: str, table: str) -> Dict[str, str]:
        return {"druid.datasource": table}


class DruidScanBuilder(ScanBuilder):
    """Plan -> Druid JSON negotiation (paper §6.2, Figure 6)."""

    def __init__(self, handler: DruidHandler, table: TableDesc, config=None):
        super().__init__(handler, table, config)
        self._dfilters: List[dict] = []
        self._recorded = False

    def _segments(self) -> List[DruidSegment]:
        src = self.table.props.get("druid.datasource", self.table.name)
        return self.handler.store.datasources.get(src, [])

    def estimate_stats(self):
        src = self.table.props.get("druid.datasource", self.table.name)
        return self.handler.store.stats(src)

    # ---- negotiation ------------------------------------------------------
    def push_filters(self, conjuncts: List[A.Expr]) -> List[A.Expr]:
        residual = []
        for c in conjuncts:
            f = _one_filter(c)
            if f is None:
                residual.append(c)
            else:
                self.spec.filters.append(c)
                self._dfilters.append(f)
        return residual

    def push_projection(self, columns: List[str]) -> bool:
        self.spec.projection = list(columns)
        return True

    def push_aggregate(self, group_keys, aggs) -> str:
        druid_aggs = []
        for fn, arg, out in aggs:
            if fn == "count" and arg is None:
                druid_aggs.append({"type": "count", "name": out})
                continue
            ty = {"sum": "doubleSum", "min": "doubleMin", "max": "doubleMax",
                  "count": "count"}.get(fn)
            if ty is None or arg is None:
                return NONE
            druid_aggs.append({"type": ty, "name": out, "fieldName": arg})
        mode = PARTIAL if len(self._segments()) > 1 else FULL
        self.spec.agg = AggPush(list(group_keys), list(aggs), mode)
        self._druid_aggs = druid_aggs
        return mode

    def push_limit(self, n: int, sort) -> str:
        if self.spec.agg is not None and self.spec.agg.mode != FULL:
            return NONE  # per-segment partial aggregates can't be top-n'd
        # scan-type queries push sorted top-n too: each segment split issues
        # a sorted scan with a limitSpec, and with multiple segments the
        # local Sort+Limit stay as the merge (PARTIAL) instead of bailing to
        # a local-only sort over every remote row
        mode = FULL if len(self.to_splits()) <= 1 or self.spec.agg is not None \
            else PARTIAL
        self.spec.limit = int(n)
        self.spec.sort = list(sort)
        self.spec.limit_mode = mode
        return mode

    # ---- the native query -------------------------------------------------
    def native_query(self) -> dict:
        spec = self.spec
        src = self.table.props.get("druid.datasource", self.table.name)
        q: dict = {"queryType": "scan", "dataSource": src, "granularity": "all"}
        if self._dfilters:
            q["filter"] = (self._dfilters[0] if len(self._dfilters) == 1
                           else {"type": "and", "fields": list(self._dfilters)})
        if spec.agg is not None:
            q["queryType"] = "groupBy" if spec.agg.group_keys else "timeseries"
            q["dimensions"] = list(spec.agg.group_keys)
            q["aggregations"] = list(self._druid_aggs)
        else:
            q["columns"] = self.output_columns()
        if spec.limit is not None:
            names = self.output_columns()
            q["limitSpec"] = {
                "limit": spec.limit,
                "columns": [
                    {"dimension": names[pos],
                     "direction": "descending" if d else "ascending"}
                    for pos, d in spec.sort
                ],
            }
        return q

    # ---- execution --------------------------------------------------------
    def to_splits(self) -> List[object]:
        segs = self._segments()
        if (self.spec.agg is not None and self.spec.agg.mode == FULL) or \
                self.spec.limit_mode == FULL:
            return [("all", None)]
        return [("seg", i) for i in range(len(segs))] or [("all", None)]

    def read_split(self, split) -> Iterator[VectorBatch]:
        q = self.native_query()
        if not self._recorded:
            self.handler.store.queries_served.append(q)
            self._recorded = True
        segs = self._segments()
        if split is None or split[0] == "all":
            batch = (VectorBatch.concat([s.batch for s in segs])
                     if segs else VectorBatch({}))
        else:
            batch = segs[split[1]].batch
        out = self._run_query(batch, q)
        if out.cols:
            out = out.project(self.output_columns())
        batch_rows = int(self.config.get("exchange.batch_rows",
                                         DEFAULT_BATCH_ROWS) or DEFAULT_BATCH_ROWS)
        if out.num_rows == 0:
            yield out if out.cols else self.empty_batch()
            return
        yield from out.iter_chunks(batch_rows)

    # ---- execution of Druid JSON over a segment ----------------------------
    def _run_query(self, batch: VectorBatch, q: dict) -> VectorBatch:
        if not batch.cols:
            return batch
        if q.get("filter"):
            mask = _eval_druid_filter(batch, q["filter"])
            batch = batch.select(mask)
        if q["queryType"] in ("groupBy", "timeseries"):
            from ..optimizer.plan import AggSpec
            from ..runtime.exec import _agg_column, _group_codes

            dims = q.get("dimensions", [])
            codes, first = _group_codes(batch, dims) if dims else (
                np.zeros(batch.num_rows, dtype=np.int64),
                np.array([0] if batch.num_rows else [], dtype=np.int64),
            )
            ng = len(first) if dims else 1
            order_of_first = np.argsort(first) if dims else np.array([0])
            remap = np.empty(max(ng, 1), dtype=np.int64)
            remap[order_of_first] = np.arange(ng)
            codes = remap[codes] if batch.num_rows else codes
            out = {}
            for d in dims:
                out[d] = batch.cols[d][np.sort(first)]
            for agg in q.get("aggregations", []):
                fn = {"doubleSum": "sum", "floatSum": "sum", "longSum": "sum",
                      "count": "count", "doubleMin": "min", "doubleMax": "max",
                      "longMin": "min", "longMax": "max"}[agg["type"]]
                arg = batch.cols.get(agg.get("fieldName")) if agg.get("fieldName") else None
                spec = AggSpec(fn, A.Col("x") if arg is not None else None,
                               False, agg["name"])
                out[agg["name"]] = _agg_column(spec, arg, codes, ng)
            result = VectorBatch(out)
        elif q["queryType"] == "scan":
            cols = q.get("columns") or batch.column_names
            result = batch.project([c for c in cols if c in batch.cols])
        else:  # pragma: no cover - defensive
            raise ValueError(f"unsupported druid queryType {q['queryType']}")
        if q.get("limitSpec"):
            result = _apply_limitspec(result, q["limitSpec"])
        return result


class DruidWriter(Writer):
    def __init__(self, handler: DruidHandler, table: TableDesc):
        self.handler = handler
        self.table = table
        self._pending: List[VectorBatch] = []

    def write_batch(self, batch: VectorBatch) -> None:
        if batch.num_rows:
            self._pending.append(batch)

    def commit(self) -> None:
        if not self._pending:
            return
        src = self.table.props.get("druid.datasource", self.table.name)
        self.handler.store.append(src, VectorBatch.concat(self._pending))
        self._pending = []


# ---------------------------------------------------------------------------
# filter translation + evaluation
# ---------------------------------------------------------------------------
def _one_filter(c: A.Expr) -> Optional[dict]:
    """One raw-column conjunct -> Druid filter JSON; None if untranslatable."""
    if isinstance(c, A.BinOp) and c.op in ("=", "<", "<=", ">", ">=", "!="):
        col, lit, op = None, None, c.op
        if isinstance(c.left, A.Col) and isinstance(c.right, A.Lit):
            col, lit = c.left, c.right.value
        elif isinstance(c.right, A.Col) and isinstance(c.left, A.Lit):
            flip = {"<": ">", "<=": ">=", ">": "<", ">=": "<=", "=": "=", "!=": "!="}
            col, lit, op = c.right, c.left.value, flip[c.op]
        if col is None or col.table is not None:
            return None
        dim = col.name
        if op == "=":
            return {"type": "selector", "dimension": dim, "value": lit}
        if op == "!=":
            return {"type": "not", "field": {"type": "selector", "dimension": dim, "value": lit}}
        bound = {"type": "bound", "dimension": dim, "ordering": "numeric"}
        if op in ("<", "<="):
            bound["upper"] = lit
            bound["upperStrict"] = op == "<"
        else:
            bound["lower"] = lit
            bound["lowerStrict"] = op == ">"
        return bound
    if isinstance(c, A.Between) and not c.negated and isinstance(c.expr, A.Col) \
            and c.expr.table is None:
        if not isinstance(c.low, A.Lit) or not isinstance(c.high, A.Lit):
            return None
        return {"type": "bound", "dimension": c.expr.name, "ordering": "numeric",
                "lower": c.low.value, "upper": c.high.value,
                "lowerStrict": False, "upperStrict": False}
    if isinstance(c, A.InList) and isinstance(c.expr, A.Col) and c.expr.table is None:
        f = {"type": "in", "dimension": c.expr.name,
             "values": [v.value for v in c.values if isinstance(v, A.Lit)]}
        return {"type": "not", "field": f} if c.negated else f
    return None


def _eval_druid_filter(batch: VectorBatch, f: dict) -> np.ndarray:
    n = batch.num_rows
    t = f["type"]
    if t == "and":
        m = np.ones(n, dtype=bool)
        for sub in f["fields"]:
            m &= _eval_druid_filter(batch, sub)
        return m
    if t == "not":
        return ~_eval_druid_filter(batch, f["field"])
    col = batch.cols[f["dimension"]]
    if t == "selector":
        v = f["value"]
        if col.dtype.kind in ("U", "S"):
            v = str(v)
        return col == v
    if t == "in":
        vals = f["values"]
        if col.dtype.kind in ("U", "S"):
            vals = [str(v) for v in vals]
        return np.isin(col, np.array(vals))
    if t == "bound":
        m = np.ones(n, dtype=bool)
        if "lower" in f:
            m &= (col > f["lower"]) if f.get("lowerStrict") else (col >= f["lower"])
        if "upper" in f:
            m &= (col < f["upper"]) if f.get("upperStrict") else (col <= f["upper"])
        return m
    raise ValueError(f"unknown druid filter {t}")


def _apply_limitspec(out: VectorBatch, spec: dict) -> VectorBatch:
    cols = spec.get("columns") or []
    if cols:
        out = out.sort_by([c["dimension"] for c in cols],
                          [c["direction"] == "descending" for c in cols])
    return out.slice(0, spec["limit"])
