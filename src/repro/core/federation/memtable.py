"""In-memory DataSource connector for tests and benchmarks.

``memtable`` serves tables held in process memory with *configurable
production latency and batch size*, which makes streaming behavior
observable: a reader that sleeps ``latency_s`` per produced morsel lets
tests assert that the first batch reached the client **before** the
connector finished producing, and that splits ran in parallel through the
exchange layer.

Capabilities: filter pushdown (evaluated vectorized against the stored
batch), projection, and per-split (partial) limit.  Aggregates stay local
on purpose, so queries over memtable exercise the residual/merge paths.

Tables are keyed ``schema.table`` (default schema ``default``); rows can be
loaded either as a ``VectorBatch`` or as a list of dicts with possibly
heterogeneous keys (routed through :class:`SerDe.deserialize`, which
null-fills missing columns).
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from ...analysis.lockdep import make_lock
from ..metastore import TableDesc
from ..obs import clock
from ..runtime.vector import DEFAULT_BATCH_ROWS, VectorBatch
from ..sql import ast as A
from .datasource import NONE, PARTIAL, ScanBuilder, Writer
from .handler import SerDe, StorageHandler


class MemTableHandler(StorageHandler):
    name = "memtable"
    default_schema = "default"

    def __init__(self, latency_s: float = 0.0, batch_rows: int = 0):
        self.tables: Dict[str, VectorBatch] = {}
        self.latency_s = float(latency_s)
        self.batch_rows = int(batch_rows)
        self._lock = make_lock("federation.memtable")
        # remote statistics cache (planning runs per query; the per-column
        # NDV scans should not) — dropped whenever a table is (re)loaded
        self._stats_cache: Dict[str, object] = {}
        # production telemetry (streaming tests/benchmarks read these)
        self.produced: List[Tuple[float, int]] = []  # (monotonic time, rows)
        self.active_readers = 0
        self.peak_active_readers = 0

    @classmethod
    def from_props(cls, props: Dict[str, str]) -> "MemTableHandler":
        return cls(latency_s=float(props.get("latency_s", 0) or 0),
                   batch_rows=int(props.get("batch_rows", 0) or 0))

    # ---- table management -----------------------------------------------------
    def _key(self, schema: str, table: str) -> str:
        return f"{schema}.{table}"

    def load(self, name: str, data, schema: Optional[str] = None) -> None:
        """Load a table; ``data`` is a VectorBatch or a list of row dicts
        (heterogeneous keys allowed — missing values are null-filled)."""
        if not isinstance(data, VectorBatch):
            data = self.serde.deserialize(list(data))
        key = self._key(schema or self.default_schema, name) \
            if "." not in name else name
        with self._lock:
            self.tables[key] = data
            self._stats_cache.pop(key, None)

    def _resolve_key(self, table: TableDesc) -> str:
        """The storage key a TableDesc addresses (the key load() writes)."""
        key = table.props.get("memtable.table", table.name)
        with self._lock:
            if key in self.tables:
                return key
        return self._key(self.default_schema, key) if "." not in key else key

    def _resolve(self, table: TableDesc) -> VectorBatch:
        key = self._resolve_key(table)
        with self._lock:
            return self.tables.get(key, VectorBatch({}))

    # ---- telemetry ------------------------------------------------------------
    def reset_telemetry(self) -> None:
        with self._lock:
            self.produced = []
            self.active_readers = 0
            self.peak_active_readers = 0

    def note_produced(self, rows: int) -> None:
        with self._lock:
            self.produced.append((clock.monotonic(), rows))

    def last_produced_at(self) -> Optional[float]:
        with self._lock:
            return self.produced[-1][0] if self.produced else None

    def _reader_enter(self) -> None:
        with self._lock:
            self.active_readers += 1
            self.peak_active_readers = max(self.peak_active_readers,
                                           self.active_readers)

    def _reader_exit(self) -> None:
        with self._lock:
            self.active_readers -= 1

    # ---- connector surface ----------------------------------------------------
    def scan_builder(self, table: TableDesc, config=None) -> "MemTableScanBuilder":
        return MemTableScanBuilder(self, table, config)

    def writer(self, table: TableDesc) -> "MemTableWriter":
        return MemTableWriter(self, table)

    def infer_schema(self, props: Dict[str, str]):
        key = props.get("memtable.table")
        return self.discover(None, key) if key else None

    def list_schemas(self) -> List[str]:
        with self._lock:
            schemas = sorted({k.split(".", 1)[0] for k in self.tables})
        return schemas or [self.default_schema]

    def list_tables(self, schema: str) -> List[str]:
        prefix = f"{schema}."
        with self._lock:
            return sorted(k[len(prefix):] for k in self.tables
                          if k.startswith(prefix))

    def discover(self, schema: Optional[str], table: str):
        key = table if "." in table else \
            self._key(schema or self.default_schema, table)
        with self._lock:
            batch = self.tables.get(key)
        if batch is None:
            return None
        kinds = {"i": "BIGINT", "u": "BIGINT", "f": "DOUBLE", "b": "BOOLEAN"}
        return [(c, "FLOAT" if v.dtype == np.float32
                 else kinds.get(v.dtype.kind, "STRING"))
                for c, v in batch.cols.items()]

    def table_props(self, schema: str, table: str) -> Dict[str, str]:
        return {"memtable.table": self._key(schema, table)}


class MemTableScanBuilder(ScanBuilder):
    def estimate_stats(self):
        from .datasource import stats_from_batch

        h: MemTableHandler = self.handler
        key = h._resolve_key(self.table)
        with h._lock:
            cached = h._stats_cache.get(key)
        if cached is not None:
            return cached
        stats = stats_from_batch(h._resolve(self.table))
        with h._lock:
            h._stats_cache[key] = stats
        return stats

    def push_filters(self, conjuncts: List[A.Expr]) -> List[A.Expr]:
        table_cols = {c for c, _ in self.table.schema}
        residual = []
        for c in conjuncts:
            cols = {n.name for n in A.walk(c) if isinstance(n, A.Col)}
            if cols and cols <= table_cols and _evaluable(c):
                self.spec.filters.append(c)
            else:
                residual.append(c)
        return residual

    def push_projection(self, columns: List[str]) -> bool:
        self.spec.projection = list(columns)
        return True

    def push_limit(self, n: int, sort) -> str:
        if sort:
            return NONE  # memtable returns storage order
        self.spec.limit = int(n)
        self.spec.limit_mode = PARTIAL  # per-split limit, merged locally
        return PARTIAL

    # ---- execution --------------------------------------------------------
    def to_splits(self) -> List[object]:
        batch = self.handler._resolve(self.table)
        n = batch.num_rows
        want = max(int(self.config.get("federation.splits", 1) or 1), 1)
        if n == 0 or want <= 1:
            return [(0, n)]
        want = min(want, max(n, 1))
        bounds = np.linspace(0, n, want + 1).astype(int)
        return [(int(lo), int(hi)) for lo, hi in zip(bounds[:-1], bounds[1:])
                if hi > lo]

    def read_split(self, split) -> Iterator[VectorBatch]:
        handler: MemTableHandler = self.handler
        lo, hi = split if split is not None else (0, None)
        batch = handler._resolve(self.table)
        part = batch.slice(lo, batch.num_rows if hi is None else hi)
        from ..runtime.exec import eval_expr

        for f in self.spec.filters:
            if part.num_rows:
                part = part.select(eval_expr(f, part, None).astype(bool))
        if self.spec.projection is not None:
            part = part.project([c for c in self.spec.projection
                                 if c in part.cols])
        if self.spec.limit is not None:
            part = part.slice(0, self.spec.limit)
        rows = handler.batch_rows or int(
            self.config.get("exchange.batch_rows", DEFAULT_BATCH_ROWS)
            or DEFAULT_BATCH_ROWS)
        handler._reader_enter()
        try:
            if part.num_rows == 0:
                handler.note_produced(0)
                yield part if part.cols else self.empty_batch()
                return
            for chunk in part.iter_chunks(rows):
                if handler.latency_s:
                    time.sleep(handler.latency_s)
                handler.note_produced(chunk.num_rows)
                yield chunk
        finally:
            handler._reader_exit()


class MemTableWriter(Writer):
    def __init__(self, handler: MemTableHandler, table: TableDesc):
        self.handler = handler
        self.table = table
        self._pending: List[VectorBatch] = []

    def write_batch(self, batch: VectorBatch) -> None:
        if batch.num_rows:
            self._pending.append(batch)

    def commit(self) -> None:
        if not self._pending:
            return
        key = self.table.props.get("memtable.table", self.table.name)
        h = self.handler
        with h._lock:
            prev = h.tables.get(key)
            parts = ([prev] if prev is not None and prev.num_rows else []) \
                + self._pending
            h.tables[key] = VectorBatch.concat(parts)
            h._stats_cache.pop(key, None)
        self._pending = []


def _evaluable(e: A.Expr) -> bool:
    """Only expression forms the vectorized evaluator handles make it in."""
    ok = (A.Col, A.Lit, A.BinOp, A.UnOp, A.Between, A.InList, A.IsNull, A.Case,
          A.Cast)
    return all(isinstance(x, ok) for x in A.walk(e))
