"""JDBC storage handler (paper §6.2 "multiple engines with JDBC support").

Calcite can generate SQL in many dialects; here the external RDBMS is an
embedded sqlite3 database and the handler translates plan subtrees into SQL
text pushed down over the "JDBC" connection.
"""
from __future__ import annotations

import sqlite3
import threading
from typing import Dict, List, Optional

import numpy as np

from ..metastore import TableDesc
from ..optimizer import plan as P
from ..runtime.vector import VectorBatch
from ..sql import ast as A
from .handler import StorageHandler


class JdbcHandler(StorageHandler):
    name = "jdbc"
    supports_pushdown = True

    def __init__(self, db_path: str = ":memory:"):
        self.conn = sqlite3.connect(db_path, check_same_thread=False)
        self._lock = threading.Lock()
        self.queries_served: List[str] = []

    # ---- external-side table management (for tests/benchmarks) ----------------
    def load_table(self, name: str, batch: VectorBatch) -> None:
        cols = batch.column_names
        decls = ", ".join(f'"{c}" {_sqlite_type(batch.cols[c])}' for c in cols)
        with self._lock:
            self.conn.execute(f'DROP TABLE IF EXISTS "{name}"')
            self.conn.execute(f'CREATE TABLE "{name}" ({decls})')
            rows = batch.to_rows()
            ph = ",".join("?" * len(cols))
            self.conn.executemany(f'INSERT INTO "{name}" VALUES ({ph})',
                                  [tuple(_py(v) for v in r) for r in rows])
            self.conn.commit()

    # ---- input format -----------------------------------------------------------
    def read_split(self, table: TableDesc, split, pushed_query) -> VectorBatch:
        remote = table.props.get("jdbc.table", table.name)
        sql = pushed_query["sql"] if pushed_query else f'SELECT * FROM "{remote}"'
        with self._lock:
            cur = self.conn.execute(sql)
            names = [d[0] for d in cur.description]
            rows = cur.fetchall()
        self.queries_served.append(sql)
        if not rows:
            return VectorBatch({n: np.empty(0) for n in names})
        cols = {n: np.array([r[i] for r in rows]) for i, n in enumerate(names)}
        return VectorBatch(cols)

    def write(self, table: TableDesc, batch: VectorBatch) -> None:
        remote = table.props.get("jdbc.table", table.name)
        with self._lock:
            existing = self.conn.execute(
                "SELECT name FROM sqlite_master WHERE type='table' AND name=?",
                (remote,),
            ).fetchone()
        if existing is None:
            self.load_table(remote, batch)
        else:
            cols = batch.column_names
            ph = ",".join("?" * len(cols))
            with self._lock:
                self.conn.executemany(
                    f'INSERT INTO "{remote}" VALUES ({ph})',
                    [tuple(_py(v) for v in r) for r in batch.to_rows()],
                )
                self.conn.commit()

    def infer_schema(self, props: Dict[str, str]):
        remote = props.get("jdbc.table")
        if not remote:
            return None
        with self._lock:
            rows = self.conn.execute(f'PRAGMA table_info("{remote}")').fetchall()
        if not rows:
            return None
        m = {"INTEGER": "BIGINT", "REAL": "DOUBLE", "TEXT": "STRING"}
        return [(r[1], m.get((r[2] or "TEXT").upper(), "STRING")) for r in rows]

    # ---- SQL generation pushdown (paper §6.2 footnote 4) ---------------------------
    def try_pushdown(self, plan: P.PlanNode, table: TableDesc) -> Optional[dict]:
        node = plan
        limit = None
        order = []
        if isinstance(node, P.Limit):
            limit = node.n
            node = node.input
        if isinstance(node, P.Sort):
            order = node.keys
            node = node.input
        agg = None
        if isinstance(node, P.Aggregate) and not node.grouping_sets:
            agg = node
            node = node.input
        projs = None
        if isinstance(node, P.Project):
            if not all(isinstance(e, A.Col) for e, _ in node.exprs):
                return None
            projs = node.exprs
            node = node.input
        filt = None
        if isinstance(node, P.Filter):
            filt = node.predicate
            node = node.input
        if not isinstance(node, P.FederatedScan) or node.table.name != table.name \
           or node.pushed_query is not None:
            return None
        alias = node.alias
        remote = table.props.get("jdbc.table", table.name)

        def raw(q: str) -> str:
            if projs is not None:
                for e, n in projs:
                    if n == q and isinstance(e, A.Col) and e.qualified != q:
                        return raw(e.qualified)
            return q.split(".", 1)[1] if q.startswith(alias + ".") else q

        out_names: List[str] = []
        if agg is not None:
            sel = []
            for k in agg.group_keys:
                sel.append(f'"{raw(k)}"')
                out_names.append(k)
            for s in agg.aggs:
                if s.distinct:
                    return None
                arg = f'"{raw(s.arg.qualified)}"' if s.arg is not None else "*"
                sel.append(f"{s.fn.upper()}({arg})")
                out_names.append(s.out_name)
            group = ", ".join(f'"{raw(k)}"' for k in agg.group_keys)
            sql = f'SELECT {", ".join(sel)} FROM "{remote}"'
            if filt is not None:
                w = _expr_to_sql(filt, raw)
                if w is None:
                    return None
                sql += f" WHERE {w}"
            if group:
                sql += f" GROUP BY {group}"
        else:
            cols = [n for n in (projs and [n for _, n in projs] or node.output_names())]
            sel = ", ".join(f'"{raw(c)}"' for c in cols)
            out_names = cols
            sql = f'SELECT {sel} FROM "{remote}"'
            if filt is not None:
                w = _expr_to_sql(filt, raw)
                if w is None:
                    return None
                sql += f" WHERE {w}"
        if order:
            try:
                terms = []
                for k, d in order:
                    idx = out_names.index(k) + 1
                    terms.append(f"{idx} {'DESC' if d else 'ASC'}")
                sql += " ORDER BY " + ", ".join(terms)
            except ValueError:
                return None
        if limit is not None:
            sql += f" LIMIT {limit}"
        return {"sql": sql, "outputNames": out_names}


def _expr_to_sql(e: A.Expr, raw) -> Optional[str]:
    if isinstance(e, A.Col):
        return f'"{raw(e.qualified)}"'
    if isinstance(e, A.Lit):
        if isinstance(e.value, str):
            return "'" + e.value.replace("'", "''") + "'"
        if e.value is None:
            return "NULL"
        if isinstance(e.value, bool):
            return "1" if e.value else "0"
        return repr(e.value)
    if isinstance(e, A.BinOp):
        l, r = _expr_to_sql(e.left, raw), _expr_to_sql(e.right, raw)
        if l is None or r is None:
            return None
        op = {"AND": "AND", "OR": "OR", "=": "=", "!=": "<>", "LIKE": "LIKE"}.get(
            e.op, e.op
        )
        return f"({l} {op} {r})"
    if isinstance(e, A.UnOp):
        v = _expr_to_sql(e.operand, raw)
        return None if v is None else (f"(NOT {v})" if e.op == "NOT" else f"(-{v})")
    if isinstance(e, A.Between):
        v = _expr_to_sql(e.expr, raw)
        lo = _expr_to_sql(e.low, raw)
        hi = _expr_to_sql(e.high, raw)
        if None in (v, lo, hi):
            return None
        neg = "NOT " if e.negated else ""
        return f"({v} {neg}BETWEEN {lo} AND {hi})"
    if isinstance(e, A.InList):
        v = _expr_to_sql(e.expr, raw)
        vals = [_expr_to_sql(x, raw) for x in e.values]
        if v is None or None in vals:
            return None
        neg = "NOT " if e.negated else ""
        return f"({v} {neg}IN ({', '.join(vals)}))"
    return None


def _sqlite_type(arr: np.ndarray) -> str:
    return {"i": "INTEGER", "u": "INTEGER", "f": "REAL", "b": "INTEGER"}.get(
        arr.dtype.kind, "TEXT"
    )


def _py(v):
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, np.str_):
        return str(v)
    return v
