"""JDBC connector (paper §6.2 "multiple engines with JDBC support").

Calcite can generate SQL in many dialects; here the external RDBMS is an
embedded sqlite3 database.  The :class:`JdbcScanBuilder` negotiates pushdown
capability-by-capability — filters translate conjunct-by-conjunct into SQL
(untranslatable ones stay local), projection narrows the SELECT list,
aggregates/sort/limit fold into the generated statement — and plain scans
split into ``rowid % N`` shards that stream morsels through a server-side
cursor (``fetchmany``), so large remote tables never materialize in one
batch.
"""
from __future__ import annotations

import sqlite3
import threading
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from ...analysis.lockdep import make_lock
from ..metastore import TableDesc
from ..runtime.vector import DEFAULT_BATCH_ROWS, VectorBatch
from ..sql import ast as A
from .datasource import FULL, NONE, ScanBuilder, Writer
from .handler import StorageHandler


class JdbcHandler(StorageHandler):
    name = "jdbc"
    default_schema = "main"

    def __init__(self, db_path: str = ":memory:"):
        self.conn = sqlite3.connect(db_path, check_same_thread=False)
        self._lock = make_lock("federation.jdbc")
        self.queries_served: List[str] = []
        # remote statistics cache (planning runs per query; the remote
        # COUNT/NDV probes should not) — dropped whenever this handler writes
        self._stats_cache: Dict[str, object] = {}

    @classmethod
    def from_props(cls, props: Dict[str, str]) -> "JdbcHandler":
        return cls(props.get("db", props.get("jdbc.db", ":memory:")))

    # ---- external-side table management (for tests/benchmarks) ----------------
    def load_table(self, name: str, batch: VectorBatch) -> None:
        cols = batch.column_names
        decls = ", ".join(f'"{c}" {_sqlite_type(batch.cols[c])}' for c in cols)
        with self._lock:
            self.conn.execute(f'DROP TABLE IF EXISTS "{name}"')
            self.conn.execute(f'CREATE TABLE "{name}" ({decls})')
            rows = batch.to_rows()
            ph = ",".join("?" * len(cols))
            self.conn.executemany(f'INSERT INTO "{name}" VALUES ({ph})',
                                  [tuple(_py(v) for v in r) for r in rows])
            self.conn.commit()
            self._stats_cache.pop(name, None)

    # ---- scan path ------------------------------------------------------------
    def scan_builder(self, table: TableDesc, config=None) -> "JdbcScanBuilder":
        return JdbcScanBuilder(self, table, config)

    # ---- write path -----------------------------------------------------------
    def writer(self, table: TableDesc) -> "JdbcWriter":
        return JdbcWriter(self, table)

    # ---- schema inference / catalog surface -----------------------------------
    def infer_schema(self, props: Dict[str, str]):
        remote = props.get("jdbc.table")
        return self.discover(self.default_schema, remote) if remote else None

    def list_tables(self, schema: str) -> List[str]:
        with self._lock:
            rows = self.conn.execute(
                "SELECT name FROM sqlite_master WHERE type='table'"
                " ORDER BY name").fetchall()
        return [r[0] for r in rows]

    def discover(self, schema: str, table: str):
        with self._lock:
            rows = self.conn.execute(f'PRAGMA table_info("{table}")').fetchall()
        if not rows:
            return None
        m = {"INTEGER": "BIGINT", "REAL": "DOUBLE", "TEXT": "STRING"}
        return [(r[1], m.get((r[2] or "TEXT").upper(), "STRING")) for r in rows]

    def table_props(self, schema: str, table: str) -> Dict[str, str]:
        return {"jdbc.table": table}


class JdbcScanBuilder(ScanBuilder):
    """SQL-generating negotiation (paper §6.2 footnote 4)."""

    def __init__(self, handler: JdbcHandler, table: TableDesc, config=None):
        super().__init__(handler, table, config)
        self._where: List[str] = []

    # ---- negotiation ------------------------------------------------------
    def push_filters(self, conjuncts: List[A.Expr]) -> List[A.Expr]:
        residual = []
        for c in conjuncts:
            sql = _expr_to_sql(c)
            if sql is None:
                residual.append(c)
            else:
                self.spec.filters.append(c)
                self._where.append(sql)
        return residual

    def push_projection(self, columns: List[str]) -> bool:
        self.spec.projection = list(columns)
        return True

    def push_aggregate(self, group_keys, aggs) -> str:
        if any(fn not in ("sum", "count", "min", "max") for fn, _, _ in aggs):
            return NONE
        from .datasource import AggPush

        self.spec.agg = AggPush(list(group_keys), list(aggs), FULL)
        return FULL

    def push_limit(self, n: int, sort) -> str:
        self.spec.limit = int(n)
        self.spec.limit_mode = FULL
        self.spec.sort = list(sort)
        return FULL

    # ---- statistics -------------------------------------------------------
    def estimate_stats(self):
        """Remote row-count + per-column NDV/min/max via generated SQL
        (COUNT(*) / COUNT(DISTINCT c) / MIN / MAX), cached per table on the
        handler until its next write."""
        from .datasource import RemoteColumnStats, RemoteTableStats

        remote = self._remote()
        h = self.handler
        with h._lock:
            cached = h._stats_cache.get(remote)
        if cached is not None:
            return cached
        cols = [c for c, _ in self.table.schema]
        sel = ["COUNT(*)"]
        for c in cols:
            sel += [f'COUNT(DISTINCT "{c}")', f'MIN("{c}")', f'MAX("{c}")']
        sql = f'SELECT {", ".join(sel)} FROM "{remote}"'
        with h._lock:
            try:
                row = h.conn.execute(sql).fetchone()
            except sqlite3.Error:
                return None
        stats = RemoteTableStats(row_count=float(row[0]))
        for i, c in enumerate(cols):
            ndv, mn, mx = row[1 + 3 * i: 4 + 3 * i]
            stats.columns[c] = RemoteColumnStats(
                ndv=int(ndv or 0), min_value=mn, max_value=mx)
        with h._lock:
            h._stats_cache[remote] = stats
        return stats

    # ---- execution --------------------------------------------------------
    def _remote(self) -> str:
        return self.table.props.get("jdbc.table", self.table.name)

    def _sql(self, split) -> str:
        spec = self.spec
        if spec.agg is not None:
            sel = [f'"{k}"' for k in spec.agg.group_keys]
            for fn, arg, _out in spec.agg.aggs:
                sel.append(f"{fn.upper()}({_quote(arg) if arg else '*'})")
            group = ", ".join(f'"{k}"' for k in spec.agg.group_keys)
        else:
            sel = [f'"{c}"' for c in self.output_columns()]
            group = ""
        where = list(self._where)
        if split is not None and split[0] == "mod":
            _, i, n = split
            where.append(f"(rowid % {n}) = {i}")
        sql = f'SELECT {", ".join(sel)} FROM "{self._remote()}"'
        if where:
            sql += " WHERE " + " AND ".join(where)
        if group:
            sql += f" GROUP BY {group}"
        if spec.sort:
            sql += " ORDER BY " + ", ".join(
                f"{pos + 1} {'DESC' if d else 'ASC'}" for pos, d in spec.sort)
        if spec.limit is not None:
            sql += f" LIMIT {spec.limit}"
        return sql

    def to_splits(self) -> List[object]:
        spec = self.spec
        if spec.agg is not None or spec.limit is not None:
            return [("all",)]
        n = max(int(self.config.get("federation.splits", 1) or 1), 1)
        if n <= 1:
            return [("all",)]
        return [("mod", i, n) for i in range(n)]

    def read_split(self, split) -> Iterator[VectorBatch]:
        sql = self._sql(split)
        self.handler.queries_served.append(sql)
        batch_rows = int(self.config.get("exchange.batch_rows",
                                         DEFAULT_BATCH_ROWS) or DEFAULT_BATCH_ROWS)
        names = self.output_columns()
        # hold the connection lock only around each fetch, never across a
        # yield: concurrent split readers (and writers) interleave instead
        # of serializing behind one suspended generator
        with self.handler._lock:
            cur = self.handler.conn.execute(sql)
        while True:
            with self.handler._lock:
                rows = cur.fetchmany(batch_rows)
            if not rows:
                break
            yield VectorBatch({
                n: _column([r[i] for r in rows])
                for i, n in enumerate(names)
            })


class JdbcWriter(Writer):
    def __init__(self, handler: JdbcHandler, table: TableDesc):
        self.handler = handler
        self.table = table
        self._created = False
        self._pending: List[VectorBatch] = []

    def write_batch(self, batch: VectorBatch) -> None:
        if batch.num_rows == 0:
            return
        remote = self.table.props.get("jdbc.table", self.table.name)
        h = self.handler
        with h._lock:
            if not self._created:
                exists = h.conn.execute(
                    "SELECT name FROM sqlite_master WHERE type='table'"
                    " AND name=?", (remote,)).fetchone()
                if exists is None:
                    decls = ", ".join(
                        f'"{c}" {_sqlite_type(batch.cols[c])}'
                        for c in batch.column_names)
                    h.conn.execute(f'CREATE TABLE "{remote}" ({decls})')
                self._created = True
            ph = ",".join("?" * len(batch.column_names))
            h.conn.executemany(
                f'INSERT INTO "{remote}" VALUES ({ph})',
                [tuple(_py(v) for v in r) for r in batch.to_rows()],
            )

    def commit(self) -> None:
        with self.handler._lock:
            self.handler.conn.commit()
            remote = self.table.props.get("jdbc.table", self.table.name)
            self.handler._stats_cache.pop(remote, None)

    def abort(self) -> None:
        """Roll back uncommitted batches so a failed multi-batch write
        cannot be made durable by the next unrelated commit."""
        with self.handler._lock:
            self.handler.conn.rollback()


def _expr_to_sql(e: A.Expr) -> Optional[str]:
    """Raw-column expression -> sqlite SQL; None when untranslatable."""
    if isinstance(e, A.Col):
        return f'"{e.name}"' if e.table is None else None
    if isinstance(e, A.Lit):
        if isinstance(e.value, str):
            return "'" + e.value.replace("'", "''") + "'"
        if e.value is None:
            return "NULL"
        if isinstance(e.value, bool):
            return "1" if e.value else "0"
        return repr(e.value)
    if isinstance(e, A.BinOp):
        l, r = _expr_to_sql(e.left), _expr_to_sql(e.right)
        if l is None or r is None:
            return None
        op = {"AND": "AND", "OR": "OR", "=": "=", "!=": "<>", "LIKE": "LIKE"}.get(
            e.op, e.op
        )
        return f"({l} {op} {r})"
    if isinstance(e, A.UnOp):
        v = _expr_to_sql(e.operand)
        return None if v is None else (f"(NOT {v})" if e.op == "NOT" else f"(-{v})")
    if isinstance(e, A.Between):
        v = _expr_to_sql(e.expr)
        lo = _expr_to_sql(e.low)
        hi = _expr_to_sql(e.high)
        if None in (v, lo, hi):
            return None
        neg = "NOT " if e.negated else ""
        return f"({v} {neg}BETWEEN {lo} AND {hi})"
    if isinstance(e, A.InList):
        v = _expr_to_sql(e.expr)
        vals = [_expr_to_sql(x) for x in e.values]
        if v is None or None in vals:
            return None
        neg = "NOT " if e.negated else ""
        return f"({v} {neg}IN ({', '.join(vals)}))"
    return None


def _column(vals: list) -> np.ndarray:
    """SQL NULLs -> NaN (numeric) / "" (text), keeping dtypes non-object."""
    if any(v is None for v in vals):
        if all(v is None or isinstance(v, (int, float)) for v in vals):
            return np.array([np.nan if v is None else float(v) for v in vals])
        return np.array(["" if v is None else str(v) for v in vals])
    return np.array(vals)


def _quote(name: str) -> str:
    return '"' + name.replace('"', '""') + '"'


def _sqlite_type(arr: np.ndarray) -> str:
    return {"i": "INTEGER", "u": "INTEGER", "f": "REAL", "b": "INTEGER"}.get(
        arr.dtype.kind, "TEXT"
    )


def _py(v):
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, np.str_):
        return str(v)
    return v
