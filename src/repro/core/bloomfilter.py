"""Bloom filters for index semijoins and ORC-style stripe skipping (paper §4.6).

The numpy implementation here is the *reference* / host-side path; the
TPU-side probe lives in ``repro.kernels.bloom`` (Pallas) and is validated
against this module.
"""
from __future__ import annotations

import math
from typing import Iterable

import numpy as np

# Two independent 64-bit mixers -> k hashes via double hashing (Kirsch-Mitzenmacher).
_M1 = np.uint64(0xFF51AFD7ED558CCD)
_M2 = np.uint64(0xC4CEB9FE1A85EC53)


def _mix64(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint64)
    with np.errstate(over="ignore"):
        x ^= x >> np.uint64(33)
        x *= _M1
        x ^= x >> np.uint64(33)
        x *= _M2
        x ^= x >> np.uint64(33)
    return x


def hash_values(values: np.ndarray) -> np.ndarray:
    """Hash arbitrary column values to uint64 (strings via FNV-1a per char block)."""
    if values.dtype.kind in ("U", "S", "O"):
        out = np.empty(len(values), dtype=np.uint64)
        for i, v in enumerate(values):
            h = np.uint64(14695981039346656037)
            for ch in str(v).encode("utf-8"):
                with np.errstate(over="ignore"):
                    h = np.uint64((int(h) ^ ch) * 1099511628211 & 0xFFFFFFFFFFFFFFFF)
            out[i] = h
        return _mix64(out)
    if values.dtype.kind == "f":
        values = values.astype(np.float64).view(np.uint64)
    return _mix64(values.astype(np.uint64))


class BloomFilter:
    """Standard k-hash bloom filter over a power-of-two bitset."""

    def __init__(self, num_bits: int, num_hashes: int, bits: np.ndarray | None = None):
        num_bits = 1 << int(math.ceil(math.log2(max(num_bits, 64))))
        self.num_bits = num_bits
        self.num_hashes = num_hashes
        self.bits = bits if bits is not None else np.zeros(num_bits // 64, dtype=np.uint64)

    @classmethod
    def for_expected(cls, n: int, fpp: float = 0.02) -> "BloomFilter":
        n = max(n, 1)
        num_bits = int(-n * math.log(fpp) / (math.log(2) ** 2))
        k = max(1, round(num_bits / n * math.log(2)))
        return cls(num_bits, min(k, 8))

    def _positions(self, values: np.ndarray) -> np.ndarray:
        h = hash_values(np.asarray(values))
        h1 = h & np.uint64(0xFFFFFFFF)
        h2 = h >> np.uint64(32)
        ks = np.arange(self.num_hashes, dtype=np.uint64)[:, None]
        with np.errstate(over="ignore"):
            pos = (h1[None, :] + ks * h2[None, :]) & np.uint64(self.num_bits - 1)
        return pos  # (k, n)

    def add(self, values: Iterable) -> None:
        pos = self._positions(np.asarray(list(values) if not isinstance(values, np.ndarray) else values))
        word, bit = pos >> np.uint64(6), pos & np.uint64(63)
        np.bitwise_or.at(self.bits, word.ravel(), np.uint64(1) << bit.ravel())

    def might_contain(self, values: np.ndarray) -> np.ndarray:
        pos = self._positions(values)
        word, bit = pos >> np.uint64(6), pos & np.uint64(63)
        hits = (self.bits[word] >> bit) & np.uint64(1)
        return np.all(hits.astype(bool), axis=0)

    # persistence (stored in stripe footers / shipped to scan operators)
    def to_dict(self) -> dict:
        import base64

        return {
            "num_bits": self.num_bits,
            "num_hashes": self.num_hashes,
            "bits": base64.b64encode(self.bits.tobytes()).decode(),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "BloomFilter":
        import base64

        bits = np.frombuffer(base64.b64decode(d["bits"]), dtype=np.uint64).copy()
        return cls(d["num_bits"], d["num_hashes"], bits)
