"""Compaction: merging delta stores (paper §3.2 "Compaction").

* **minor** compaction merges delta directories with other delta directories
  (and delete_deltas with delete_deltas),
* **major** compaction merges deltas into the base, applying tombstones and
  dropping aborted history ("major compaction deletes history").

Compaction is triggered automatically when thresholds are surpassed (number
of delta directories, ratio of delta rows to base rows) and never takes locks
over the table: the merge phase writes new directories, and a *separated
cleaner* removes obsolete ones only once no active reader snapshot could
still reference them.
"""
from __future__ import annotations

import os
import shutil
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from .acid import (
    AcidTable,
    PlainIO,
    StoreDir,
    T_ROWID_COL,
    T_WRITEID_COL,
    _rowkey,
    list_stores,
    select_stores,
)
from .metastore import Metastore, WriteIdList
from .runtime.vector import ROWID_COL, WRITEID_COL, VectorBatch
from .storage import write_stripe_file


@dataclass
class CompactionConfig:
    minor_delta_threshold: int = 10  # #delta dirs that triggers a minor compaction
    major_ratio_threshold: float = 0.1  # delta rows / base rows triggering major
    enabled: bool = True


def _compaction_wid_list(hms: Metastore, table: str) -> WriteIdList:
    """Only WriteIds below every open transaction may be compacted."""
    snap = hms.get_snapshot()
    min_open = hms.min_open_txn()
    if min_open is not None:
        snap_hwm = min_open - 1
        snap = type(snap)(snap_hwm, frozenset(), snap.aborted_txns)
    wid = hms.writeid_list(table, snap)
    return wid


def _read_store_rows(table: AcidTable, store: StoreDir, io: PlainIO) -> VectorBatch:
    return VectorBatch.concat(
        [io.read_file(f)[1] for f in table._store_files(store.path)]
    )


def compact_partition(
    table: AcidTable,
    location: str,
    kind: str,
    hms: Metastore,
    clean: bool = True,
) -> Optional[str]:
    """Run a minor/major compaction over one partition directory."""
    assert kind in ("minor", "major")
    io = PlainIO()
    wid_list = _compaction_wid_list(hms, table.desc.name)
    base, deltas, deletes = select_stores(location, wid_list)
    if not deltas and not deletes:
        return None

    obsolete = []
    if kind == "minor":
        # merge insert deltas (keeping records + their original row ids) and
        # delete deltas into single multi-WriteId directories
        new_dirs = []
        if deltas:
            lo = min(d.min_writeid for d in deltas)
            hi = max(d.max_writeid for d in deltas)
            merged = VectorBatch.concat([_read_store_rows(table, d, io) for d in deltas])
            mask = wid_list.valid_mask(merged.cols[WRITEID_COL])
            merged = merged.select(mask)  # drop aborted history
            out = os.path.join(location, f"delta_{lo}_{hi}")
            if len(deltas) > 1 or deltas[0].path != out:
                _write_dir(out, merged)
                obsolete += [d.path for d in deltas if d.path != out]
                new_dirs.append(out)
        if deletes:
            lo = min(d.min_writeid for d in deletes)
            hi = max(d.max_writeid for d in deletes)
            merged = VectorBatch.concat([_read_store_rows(table, d, io) for d in deletes])
            mask = wid_list.valid_mask(merged.cols[WRITEID_COL])
            merged = merged.select(mask)
            out = os.path.join(location, f"delete_delta_{lo}_{hi}")
            if len(deletes) > 1 or deletes[0].path != out:
                _write_dir(out, merged)
                obsolete += [d.path for d in deletes if d.path != out]
        result = ",".join(new_dirs) if new_dirs else None
    else:  # major: fold everything into a new base at the compaction watermark
        hwm = wid_list.hwm
        chunks = []
        tomb_keys = []
        for store in deletes:
            tb = _read_store_rows(table, store, io)
            tb = tb.select(wid_list.valid_mask(tb.cols[WRITEID_COL]))
            if tb.num_rows:
                tomb_keys.append(_rowkey(tb.cols[T_WRITEID_COL], tb.cols[T_ROWID_COL]))
        tombs = np.concatenate(tomb_keys) if tomb_keys else np.empty(0, np.int64)
        for store in ([base] if base else []) + deltas:
            tb = _read_store_rows(table, store, io)
            mask = wid_list.valid_mask(tb.cols[WRITEID_COL])
            if len(tombs):
                keys = _rowkey(tb.cols[WRITEID_COL], tb.cols[ROWID_COL])
                mask &= ~np.isin(keys, tombs)
            tb = tb.select(mask)
            if tb.num_rows:
                chunks.append(tb)
        merged = (
            VectorBatch.concat(chunks) if chunks else table._empty_batch(None)
        )
        out = os.path.join(location, f"base_{hwm}")
        _write_dir(out, merged)
        obsolete += [d.path for d in deltas + deletes if d.path != out]
        if base and base.path != out:
            obsolete.append(base.path)
        result = out

    if clean:
        run_cleaner(location, obsolete, wid_list.hwm)
    else:
        _PENDING_CLEANUPS.setdefault(location, []).extend(
            (p, wid_list.hwm) for p in obsolete
        )
    return result


_PENDING_CLEANUPS: Dict[str, list] = {}


def run_cleaner(location: str, obsolete: list, compaction_hwm: int) -> int:
    """Cleaner phase, separated from merging (paper §3.2): only delete stores
    once no active reader snapshot predates the compaction watermark."""
    leases = AcidTable.active_leases(location)
    if any(h < compaction_hwm for h in leases):
        _PENDING_CLEANUPS.setdefault(location, []).extend(
            (p, compaction_hwm) for p in obsolete
        )
        return 0
    removed = 0
    for path in obsolete:
        if os.path.isdir(path):
            shutil.rmtree(path)
            removed += 1
    return removed


def drain_pending_cleanups(location: str) -> int:
    pend = _PENDING_CLEANUPS.pop(location, [])
    removed = 0
    for path, hwm in pend:
        removed += run_cleaner(location, [path], hwm)
    return removed


def _write_dir(out_dir: str, batch: VectorBatch) -> None:
    tmp = out_dir + "._tmp"
    if os.path.isdir(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    wid = int(batch.cols[WRITEID_COL].max()) if batch.num_rows else 0
    write_stripe_file(os.path.join(tmp, "bucket_00000.tahoe"), batch, writeid=wid)
    if os.path.isdir(out_dir):
        shutil.rmtree(out_dir)
    os.replace(tmp, out_dir)


# --------------------------------------------------------------------------
# Initiator: automatic triggering on thresholds (paper §3.2)
# --------------------------------------------------------------------------
def maybe_compact(
    table: AcidTable, hms: Metastore, cfg: CompactionConfig = CompactionConfig()
) -> Dict[str, str]:
    if not cfg.enabled:
        return {}
    actions: Dict[str, str] = {}
    locations = (
        [loc for _, loc in hms.list_partitions(table.desc.name)]
        if table.desc.partition_cols
        else [table.desc.location]
    )
    io = PlainIO()
    for loc in locations:
        stores = list_stores(loc)
        deltas = [s for s in stores if s.kind != "base"]
        bases = [s for s in stores if s.kind == "base"]
        if not deltas:
            continue
        base_rows = sum(
            io.read_meta(f).num_rows
            for b in bases
            for f in table._store_files(b.path)
        )
        delta_rows = sum(
            io.read_meta(f).num_rows
            for d in deltas
            for f in table._store_files(d.path)
        )
        if base_rows and delta_rows / max(base_rows, 1) >= cfg.major_ratio_threshold:
            compact_partition(table, loc, "major", hms)
            actions[loc] = "major"
        elif len(deltas) >= cfg.minor_delta_threshold:
            compact_partition(table, loc, "minor", hms)
            actions[loc] = "minor"
    return actions
