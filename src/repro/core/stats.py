"""Column statistics for the metastore (paper §4.1 "Statistics").

Hive stores per-column statistics in HMS so that they can be combined in an
*additive* fashion: inserts and per-partition stats merge onto existing stats
without rescanning.  Range/cardinality merge trivially; the number of distinct
values uses a HyperLogLog++ sketch [Heule et al., EDBT'13], which merges
without losing approximation accuracy.
"""
from __future__ import annotations

import base64
import dataclasses
import hashlib
import math
import struct
from typing import Any, Optional

import numpy as np

__all__ = ["HyperLogLogPP", "ColumnStats", "TableStats", "compute_column_stats"]


def _hash64(value: Any) -> int:
    """Stable 64-bit hash (python hash() is salted per-process)."""
    if isinstance(value, float) and value.is_integer():
        value = int(value)  # 3.0 and 3 hash alike
    data = repr(value).encode("utf-8")
    return struct.unpack("<Q", hashlib.blake2b(data, digest_size=8).digest())[0]


class HyperLogLogPP:
    """HyperLogLog++ distinct-value sketch (dense representation).

    64-bit hashing (no large-range correction needed) with the standard bias
    correction for small cardinalities.  Registers merge by element-wise max,
    which is what makes NDV stats additive across partitions and inserts.
    """

    def __init__(self, p: int = 12, registers: Optional[np.ndarray] = None):
        if not 4 <= p <= 18:
            raise ValueError(f"HLL precision must be in [4,18], got {p}")
        self.p = p
        self.m = 1 << p
        self.registers = (
            registers.astype(np.uint8)
            if registers is not None
            else np.zeros(self.m, dtype=np.uint8)
        )

    # -- construction -------------------------------------------------------
    def add(self, value: Any) -> None:
        h = _hash64(value)
        idx = h & (self.m - 1)
        rest = h >> self.p
        # rank = leading position of first set bit in the remaining 64-p bits
        rank = (64 - self.p) - rest.bit_length() + 1 if rest else (64 - self.p) + 1
        if rank > self.registers[idx]:
            self.registers[idx] = rank

    def add_array(self, values: np.ndarray) -> None:
        for v in np.unique(values[: 1 << 20]):  # pre-unique: sketch only needs distinct
            self.add(v.item() if hasattr(v, "item") else v)

    # -- estimation ----------------------------------------------------------
    @property
    def _alpha(self) -> float:
        m = self.m
        if m == 16:
            return 0.673
        if m == 32:
            return 0.697
        if m == 64:
            return 0.709
        return 0.7213 / (1.0 + 1.079 / m)

    def cardinality(self) -> int:
        regs = self.registers.astype(np.float64)
        est = self._alpha * self.m * self.m / np.sum(np.exp2(-regs))
        if est <= 2.5 * self.m:  # small-range (linear counting) correction
            zeros = int(np.count_nonzero(self.registers == 0))
            if zeros:
                est = self.m * math.log(self.m / zeros)
        return int(round(est))

    # -- additivity ----------------------------------------------------------
    def merge(self, other: "HyperLogLogPP") -> "HyperLogLogPP":
        if self.p != other.p:
            raise ValueError("cannot merge HLL sketches of different precision")
        return HyperLogLogPP(self.p, np.maximum(self.registers, other.registers))

    # -- persistence (HMS stores the sketch bytes) ---------------------------
    def serialize(self) -> str:
        return f"{self.p}:" + base64.b64encode(self.registers.tobytes()).decode()

    @classmethod
    def deserialize(cls, s: str) -> "HyperLogLogPP":
        p_str, payload = s.split(":", 1)
        regs = np.frombuffer(base64.b64decode(payload), dtype=np.uint8).copy()
        return cls(int(p_str), regs)


@dataclasses.dataclass
class ColumnStats:
    """Additive per-column statistics (paper §4.1)."""

    count: int = 0
    null_count: int = 0
    min_value: Optional[Any] = None
    max_value: Optional[Any] = None
    hll: Optional[HyperLogLogPP] = None

    @property
    def ndv(self) -> int:
        return self.hll.cardinality() if self.hll is not None else 0

    def merge(self, other: "ColumnStats") -> "ColumnStats":
        def _mrg(a, b, fn):
            if a is None:
                return b
            if b is None:
                return a
            return fn(a, b)

        return ColumnStats(
            count=self.count + other.count,
            null_count=self.null_count + other.null_count,
            min_value=_mrg(self.min_value, other.min_value, min),
            max_value=_mrg(self.max_value, other.max_value, max),
            hll=_mrg(self.hll, other.hll, lambda a, b: a.merge(b)),
        )

    def to_dict(self) -> dict:
        return {
            "count": self.count,
            "null_count": self.null_count,
            "min": self.min_value,
            "max": self.max_value,
            "hll": self.hll.serialize() if self.hll else None,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "ColumnStats":
        return cls(
            count=d["count"],
            null_count=d["null_count"],
            min_value=d["min"],
            max_value=d["max"],
            hll=HyperLogLogPP.deserialize(d["hll"]) if d.get("hll") else None,
        )


@dataclasses.dataclass
class TableStats:
    row_count: int = 0
    columns: dict = dataclasses.field(default_factory=dict)  # name -> ColumnStats

    def merge(self, other: "TableStats") -> "TableStats":
        cols = dict(self.columns)
        for name, cs in other.columns.items():
            cols[name] = cols[name].merge(cs) if name in cols else cs
        return TableStats(self.row_count + other.row_count, cols)


def compute_column_stats(values: np.ndarray, hll_p: int = 12) -> ColumnStats:
    """Build stats for one column vector (invoked at write time)."""
    n = len(values)
    if values.dtype.kind == "f":
        nulls = int(np.count_nonzero(np.isnan(values)))
        valid = values[~np.isnan(values)]
    elif values.dtype.kind in ("U", "S", "O"):
        mask = values == None  # noqa: E711  (object-array null compare)
        nulls = int(np.count_nonzero(mask))
        valid = values[~mask]
    else:
        nulls, valid = 0, values
    hll = HyperLogLogPP(hll_p)
    hll.add_array(valid)
    mn = mx = None
    if len(valid):
        if valid.dtype.kind in ("U", "S", "O"):
            s = np.sort(valid.astype(str))
            mn, mx = str(s[0]), str(s[-1])
        else:
            mn, mx = valid.min().item(), valid.max().item()
    return ColumnStats(count=n, null_count=nulls, min_value=mn, max_value=mx, hll=hll)
