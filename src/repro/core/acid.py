"""ACID table storage: base/delta layout + snapshot merge-on-read (paper §3.2).

Directory scheme inside each table (or partition) directory::

    base_<w>/              all valid records up to WriteId w   (from compaction)
    delta_<w1>_<w2>/       inserted records for WriteIds [w1, w2]
    delete_delta_<w1>_<w2>/ tombstones written by WriteIds [w1, w2]

Every record carries hidden columns (__writeid__, __rowid__); the pair
uniquely identifies a row for the lifetime of the table (it survives
compaction), which is what lets delete tombstones — themselves just inserted
records pointing at a (writeid, rowid) — be applied by an anti-join at read
time.  Updates are split into delete + insert (paper §3.2).

Readers bind a per-table WriteIdList (projection of the snapshot) to each
scan: whole stores are discarded when their WriteId range is invisible, and
row-level masks handle open/aborted writers below the high watermark.
"""
from __future__ import annotations

import os
import re
import threading
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .bloomfilter import BloomFilter
from ..analysis.lockdep import make_lock
from .metastore import Metastore, TableDesc, WriteIdList
from .runtime.vector import ROWID_COL, WRITEID_COL, VectorBatch
from .stats import TableStats, compute_column_stats
from .storage import (
    FileMeta,
    SargPredicate,
    read_file_meta,
    read_stripe_column,
    stripe_may_match,
    write_stripe_file,
)

_BASE_RE = re.compile(r"^base_(\d+)$")
_DELTA_RE = re.compile(r"^delta_(\d+)_(\d+)$")
_DELETE_RE = re.compile(r"^delete_delta_(\d+)_(\d+)$")

# Tombstone target pointers (the record being deleted).
T_WRITEID_COL = "__t_writeid__"
T_ROWID_COL = "__t_rowid__"


# --------------------------------------------------------------------------
# Pluggable I/O: the plain reader here; LLAP's caching I/O elevator implements
# the same surface in core/runtime/llap.py.
# --------------------------------------------------------------------------
class PlainIO:
    """Cold reads straight off the file system (the "container" path)."""

    def read_file_chunks(
        self,
        path: str,
        columns: Optional[Sequence[str]] = None,
        sarg_preds: Sequence[SargPredicate] = (),
        runtime_blooms: Optional[Dict[str, BloomFilter]] = None,
    ) -> Iterator[VectorBatch]:
        """Stream one decoded ``VectorBatch`` per surviving stripe, so scans
        pipeline morsels instead of materializing whole files."""
        meta = self.read_meta(path)
        cols = list(columns) if columns is not None else meta.columns
        for si, smeta in enumerate(meta.stripes):
            if sarg_preds and not stripe_may_match(smeta, sarg_preds):
                continue  # row-group skip via min/max + file blooms (§5.1)
            stripe_cols = {c: read_stripe_column(path, si, c) for c in cols}
            yield _bloom_masked(stripe_cols, cols, runtime_blooms)

    def read_file(
        self,
        path: str,
        columns: Optional[Sequence[str]] = None,
        sarg_preds: Sequence[SargPredicate] = (),
        runtime_blooms: Optional[Dict[str, BloomFilter]] = None,
    ) -> Tuple[FileMeta, VectorBatch]:
        meta = self.read_meta(path)
        cols = list(columns) if columns is not None else meta.columns
        chunks = list(self.read_file_chunks(path, columns, sarg_preds,
                                            runtime_blooms))
        return meta, _concat_file_chunks(chunks, cols, meta)

    def read_meta(self, path: str) -> FileMeta:
        return read_file_meta(path)


def _bloom_masked(
    stripe_cols: Dict[str, np.ndarray],
    cols: Sequence[str],
    runtime_blooms: Optional[Dict[str, BloomFilter]],
) -> VectorBatch:
    """Apply runtime-filter bloom probes to one decoded stripe (§4.6)."""
    mask = None
    if runtime_blooms:
        for col, bf in runtime_blooms.items():
            if col in stripe_cols:
                m = bf.might_contain(stripe_cols[col])
                mask = m if mask is None else (mask & m)
    if mask is None:
        return VectorBatch({c: stripe_cols[c] for c in cols})
    return VectorBatch({c: stripe_cols[c][mask] for c in cols})


def _concat_file_chunks(chunks, cols, meta: FileMeta) -> VectorBatch:
    if chunks:
        return VectorBatch.concat(chunks)
    return VectorBatch({
        c: np.empty(0, dtype=meta.dtypes.get(c, "f8")) for c in cols
    })


@dataclass
class StoreDir:
    path: str
    kind: str  # base | delta | delete_delta
    min_writeid: int
    max_writeid: int


def list_stores(directory: str) -> List[StoreDir]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in sorted(os.listdir(directory)):
        full = os.path.join(directory, name)
        if not os.path.isdir(full):
            continue
        if m := _BASE_RE.match(name):
            w = int(m.group(1))
            out.append(StoreDir(full, "base", 0, w))
        elif m := _DELTA_RE.match(name):
            out.append(StoreDir(full, "delta", int(m.group(1)), int(m.group(2))))
        elif m := _DELETE_RE.match(name):
            out.append(StoreDir(full, "delete_delta", int(m.group(1)), int(m.group(2))))
    return out


def select_stores(
    directory: str, wid_list: WriteIdList
) -> Tuple[Optional[StoreDir], List[StoreDir], List[StoreDir]]:
    """Pick the newest visible base and the deltas above it (paper §3.2)."""
    stores = list_stores(directory)
    bases = [s for s in stores if s.kind == "base" and s.max_writeid <= wid_list.hwm]
    base = max(bases, key=lambda s: s.max_writeid, default=None)
    floor = base.max_writeid if base else 0
    deltas = [
        s
        for s in stores
        if s.kind == "delta" and s.max_writeid > floor and s.min_writeid <= wid_list.hwm
    ]
    deletes = [
        s
        for s in stores
        if s.kind == "delete_delta" and s.min_writeid <= wid_list.hwm
    ]
    return base, deltas, deletes


def _rowkey(writeids: np.ndarray, rowids: np.ndarray) -> np.ndarray:
    return writeids.astype(np.int64) * np.int64(1 << 32) + rowids.astype(np.int64)


class AcidTable:
    """Transactional read/write facade over one table's directory tree."""

    # registry of active reader snapshots per table-location, consulted by the
    # compaction cleaner so in-flight queries finish before files vanish (§3.2)
    _reader_leases: Dict[str, List[int]] = {}
    _lease_lock = make_lock("acid.lease")

    def __init__(self, desc: TableDesc, hms: Metastore):
        self.desc = desc
        self.hms = hms

    # ---------------------------------------------------------------- writes
    def _partition_dirs(self, batch: VectorBatch) -> Iterator[Tuple[tuple, str, VectorBatch]]:
        pcols = self.desc.partition_cols
        if not pcols:
            yield (), self.desc.location, batch
            return
        keys = [batch.cols[c] for c in pcols]
        rec = np.rec.fromarrays(keys)
        for uniq in np.unique(rec):
            vals = tuple(np.atleast_1d(uniq[c]).item() for c in rec.dtype.names)
            mask = rec == uniq
            sub = batch.select(mask).drop(pcols)
            loc = self.hms.add_partition(self.desc.name, vals)
            yield vals, loc, sub

    def insert(
        self,
        txn_id: int,
        batch: VectorBatch,
        *,
        bloom_columns: Sequence[str] = (),
        update_stats: bool = True,
    ) -> int:
        """INSERT rows under txn; allocates the table WriteId on first use."""
        wid = self.hms.allocate_write_id(txn_id, self.desc.name)
        for pvals, loc, sub in self._partition_dirs(batch):
            self.hms.acquire_lock(
                txn_id, self.desc.name, pvals if pvals else None, "shared"
            )
            self.hms.record_write_set(txn_id, self.desc.name, pvals, "insert")
            self._write_store(loc, f"delta_{wid}_{wid}", sub, wid, bloom_columns)
            if update_stats:
                stats = TableStats(
                    row_count=sub.num_rows,
                    columns={
                        c: compute_column_stats(sub.cols[c])
                        for c in sub.column_names
                        if not c.startswith("__")
                    },
                )
                for c in self.desc.partition_cols:
                    pass  # partition cols are directory-encoded, no file stats
                self.hms.merge_stats(self.desc.name, pvals, stats)
        return wid

    def delete(
        self, txn_id: int, targets_by_partition: Dict[tuple, np.ndarray]
    ) -> int:
        """DELETE: write tombstones pointing at (writeid, rowid) pairs.

        ``targets_by_partition`` maps partition values -> (n, 2) int64 array of
        [orig_writeid, orig_rowid].
        """
        wid = self.hms.allocate_write_id(txn_id, self.desc.name)
        for pvals, targets in targets_by_partition.items():
            if len(targets) == 0:
                continue
            loc = (
                self.hms.add_partition(self.desc.name, pvals)
                if self.desc.partition_cols
                else self.desc.location
            )
            self.hms.acquire_lock(
                txn_id, self.desc.name, pvals if pvals else None, "shared"
            )
            self.hms.record_write_set(txn_id, self.desc.name, pvals, "delete")
            tomb = VectorBatch(
                {
                    T_WRITEID_COL: targets[:, 0].astype(np.int64),
                    T_ROWID_COL: targets[:, 1].astype(np.int64),
                }
            )
            self._write_store(loc, f"delete_delta_{wid}_{wid}", tomb, wid, ())
        return wid

    def _write_store(
        self,
        location: str,
        store_name: str,
        batch: VectorBatch,
        wid: int,
        bloom_columns: Sequence[str],
    ) -> None:
        store_dir = os.path.join(location, store_name)
        os.makedirs(store_dir, exist_ok=True)
        existing = [f for f in os.listdir(store_dir) if f.endswith(".tahoe")]
        rowid_base = 0
        for f in existing:  # rowids stay unique within a WriteId across files
            rowid_base += read_file_meta(os.path.join(store_dir, f)).num_rows
        n = batch.num_rows
        full = batch.with_column(
            WRITEID_COL, np.full(n, wid, dtype=np.int64)
        ).with_column(ROWID_COL, np.arange(rowid_base, rowid_base + n, dtype=np.int64))
        path = os.path.join(store_dir, f"bucket_{len(existing):05d}.tahoe")
        write_stripe_file(path, full, writeid=wid, bloom_columns=bloom_columns)

    # ---------------------------------------------------------------- reads
    def _partition_tombstones(self, deletes, wid_list: WriteIdList,
                              io) -> np.ndarray:
        # Deletes are usually small: load tombstones fully in memory (§3.2)
        tomb_keys = []
        for store in deletes:
            for f in self._store_files(store.path):
                meta, tb = io.read_file(
                    f, [T_WRITEID_COL, T_ROWID_COL, WRITEID_COL]
                )
                valid = wid_list.valid_mask(tb.cols[WRITEID_COL])
                tb = tb.select(valid)
                if tb.num_rows:
                    tomb_keys.append(
                        _rowkey(tb.cols[T_WRITEID_COL], tb.cols[T_ROWID_COL])
                    )
        return np.concatenate(tomb_keys) if tomb_keys else np.empty(0, np.int64)

    def iter_partition_chunks(
        self,
        location: str,
        part_values: tuple,
        wid_list: WriteIdList,
        columns: Optional[Sequence[str]] = None,
        sarg_preds: Sequence[SargPredicate] = (),
        runtime_blooms: Optional[Dict[str, BloomFilter]] = None,
        io=None,
        keep_acid_cols: bool = False,
    ) -> Iterator[VectorBatch]:
        """Stream one partition's visible rows stripe-by-stripe.

        The merge-on-read pipeline (WriteId visibility mask + tombstone
        anti-join + partition-column injection) applies per decoded stripe
        chunk, so a scan never materializes a whole partition."""
        io = io or PlainIO()
        base, deltas, deletes = select_stores(location, wid_list)
        tombs = self._partition_tombstones(deletes, wid_list, io)

        data_cols = None
        if columns is not None:
            pcols = set(self.desc.partition_cols)
            data_cols = [c for c in columns if c not in pcols]
            for c in (WRITEID_COL, ROWID_COL):
                if c not in data_cols:
                    data_cols = data_cols + [c]

        def finish(tb: VectorBatch) -> VectorBatch:
            # inject directory-encoded partition columns (§3.1 / Figure 3)
            for col, val in zip(self.desc.partition_cols, part_values):
                if columns is None or col in columns:
                    dtype = _np_dtype(self.desc.dtype_of(col))
                    tb = tb.with_column(
                        col, np.full(tb.num_rows, val, dtype=dtype))
            return tb if keep_acid_cols else tb.drop_acid_cols()

        stores = ([base] if base else []) + deltas
        for store in stores:
            for f in self._store_files(store.path):
                for tb in io.read_file_chunks(f, data_cols, sarg_preds,
                                              runtime_blooms):
                    mask = wid_list.valid_mask(tb.cols[WRITEID_COL])
                    if len(tombs):  # anti-join against delete tombstones
                        keys = _rowkey(tb.cols[WRITEID_COL],
                                       tb.cols[ROWID_COL])
                        mask &= ~np.isin(keys, tombs)
                    tb = tb.select(mask)
                    if tb.num_rows:
                        yield finish(tb)

    def scan_partition(
        self,
        location: str,
        part_values: tuple,
        wid_list: WriteIdList,
        columns: Optional[Sequence[str]] = None,
        sarg_preds: Sequence[SargPredicate] = (),
        runtime_blooms: Optional[Dict[str, BloomFilter]] = None,
        io=None,
        keep_acid_cols: bool = False,
    ) -> VectorBatch:
        chunks = list(self.iter_partition_chunks(
            location, part_values, wid_list, columns, sarg_preds,
            runtime_blooms, io, keep_acid_cols,
        ))
        if chunks:
            return VectorBatch.concat(chunks)
        data_cols = None
        if columns is not None:
            pcols = set(self.desc.partition_cols)
            data_cols = [c for c in columns if c not in pcols]
            for c in (WRITEID_COL, ROWID_COL):
                if c not in data_cols:
                    data_cols = data_cols + [c]
        out = self._empty_batch(data_cols)
        for col, val in zip(self.desc.partition_cols, part_values):
            if columns is None or col in columns:
                dtype = _np_dtype(self.desc.dtype_of(col))
                out = out.with_column(col, np.full(0, val, dtype=dtype))
        return out if keep_acid_cols else out.drop_acid_cols()

    def scan(
        self,
        wid_list: WriteIdList,
        columns: Optional[Sequence[str]] = None,
        sarg_preds: Sequence[SargPredicate] = (),
        runtime_blooms: Optional[Dict[str, BloomFilter]] = None,
        partition_filter=None,  # callable(part_values_tuple) -> bool
        io=None,
        keep_acid_cols: bool = False,
    ) -> Iterator[Tuple[tuple, VectorBatch]]:
        self._register_lease(wid_list.hwm)
        try:
            if self.desc.partition_cols:
                for pvals, loc in self.hms.list_partitions(self.desc.name):
                    if partition_filter is not None and not partition_filter(pvals):
                        continue  # static or dynamic partition pruning (§4.6)
                    yield pvals, self.scan_partition(
                        loc, pvals, wid_list, columns, sarg_preds,
                        runtime_blooms, io, keep_acid_cols,
                    )
            else:
                yield (), self.scan_partition(
                    self.desc.location, (), wid_list, columns, sarg_preds,
                    runtime_blooms, io, keep_acid_cols,
                )
        finally:
            self._release_lease(wid_list.hwm)

    def scan_chunks(
        self,
        wid_list: WriteIdList,
        columns: Optional[Sequence[str]] = None,
        sarg_preds: Sequence[SargPredicate] = (),
        runtime_blooms: Optional[Dict[str, BloomFilter]] = None,
        partition_filter=None,  # callable(part_values_tuple) -> bool
        io=None,
        keep_acid_cols: bool = False,
    ) -> Iterator[Tuple[tuple, VectorBatch]]:
        """Streaming variant of :meth:`scan`: yields ``(part_values, chunk)``
        per decoded stripe chunk instead of one batch per partition."""
        self._register_lease(wid_list.hwm)
        try:
            if self.desc.partition_cols:
                targets = [
                    (pvals, loc)
                    for pvals, loc in self.hms.list_partitions(self.desc.name)
                    if partition_filter is None or partition_filter(pvals)
                ]
            else:
                targets = [((), self.desc.location)]
            for pvals, loc in targets:
                for chunk in self.iter_partition_chunks(
                    loc, pvals, wid_list, columns, sarg_preds,
                    runtime_blooms, io, keep_acid_cols,
                ):
                    yield pvals, chunk
        finally:
            self._release_lease(wid_list.hwm)

    def read_all(self, wid_list: WriteIdList, **kw) -> VectorBatch:
        return VectorBatch.concat([b for _, b in self.scan(wid_list, **kw)])

    # ---------------------------------------------------------------- helpers
    def _store_files(self, store_dir: str) -> List[str]:
        return [
            os.path.join(store_dir, f)
            for f in sorted(os.listdir(store_dir))
            if f.endswith(".tahoe")
        ]

    def _empty_batch(self, columns: Optional[Sequence[str]]) -> VectorBatch:
        pcols = set(self.desc.partition_cols)
        names = columns or (
            [c for c, _ in self.desc.schema if c not in pcols]
            + [WRITEID_COL, ROWID_COL]
        )
        cols = {}
        for c in names:
            if c in (WRITEID_COL, ROWID_COL, T_WRITEID_COL, T_ROWID_COL):
                cols[c] = np.empty(0, dtype=np.int64)
            elif c not in pcols:
                cols[c] = np.empty(0, dtype=_np_dtype(self.desc.dtype_of(c)))
        return VectorBatch(cols)

    def _register_lease(self, hwm: int) -> None:
        with AcidTable._lease_lock:
            AcidTable._reader_leases.setdefault(self.desc.location, []).append(hwm)

    def _release_lease(self, hwm: int) -> None:
        with AcidTable._lease_lock:
            leases = AcidTable._reader_leases.get(self.desc.location, [])
            if hwm in leases:
                leases.remove(hwm)

    @classmethod
    def active_leases(cls, location: str) -> List[int]:
        with cls._lease_lock:
            return list(cls._reader_leases.get(location, []))


def _np_dtype(sql_type: str) -> np.dtype:
    t = sql_type.upper()
    if t.startswith(("INT", "BIGINT", "SMALLINT", "TINYINT")):
        return np.dtype(np.int64)
    if t.startswith("FLOAT"):
        return np.dtype(np.float32)  # Hive FLOAT is single-precision
    if t.startswith(("DECIMAL", "DOUBLE", "REAL")):
        return np.dtype(np.float64)
    if t.startswith(("VARCHAR", "CHAR", "STRING", "TEXT", "TIMESTAMP", "DATE")):
        return np.dtype("U64")
    if t.startswith("BOOL"):
        return np.dtype(np.bool_)
    raise ValueError(f"unsupported SQL type {sql_type}")
