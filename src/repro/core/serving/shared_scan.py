"""Shared scans: attach concurrent queries to an in-flight scan's exchange.

When two queries race over the same table, the second one normally re-reads
every stripe through LLAP.  The registry instead lets the first query's scan
vertex *publish* its output :class:`~..runtime.exchange.Exchange`; a later
query whose DAG contains an identical scan vertex (same fused
scan/filter/project subtree, same parameters, same per-table write-ID
state) attaches a second replaying reader to that exchange and never
touches storage.

Retention is refcounted: publishing forces ``retain = True`` on the
exchange, and the producer query's teardown *retires* the entry instead of
discarding the exchange outright — the last attached consumer to release
performs the actual ``discard()`` (and any deferred scratch-dir cleanup).
Attachment is race-safe against completion: ``attach`` fails once the entry
is retired, and the caller falls back to a fresh scan.  A snapshot or
write-ID difference changes the key itself, so stale data can never be
served.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, Iterator, List, Optional, Tuple

from ...analysis.lockdep import make_lock
from ..obs.metrics import MetricsRegistry

_STAT_NAMES = ("published", "attached", "attach_misses", "fallbacks",
               "invalidated")


class _Entry:
    __slots__ = ("key", "table", "exchange", "refcount", "retired",
                 "on_final")

    def __init__(self, key, table: str, exchange):
        self.key = key
        self.table = table
        self.exchange = exchange
        self.refcount = 0
        self.retired = False
        # callbacks to run after the exchange is discarded (deferred
        # scratch-dir cleanup for the producer query)
        self.on_final: List[Callable[[], None]] = []


class SharedScanHandle:
    """One attached consumer's claim on a published scan exchange."""

    def __init__(self, registry: "SharedScanRegistry", entry: _Entry):
        self._registry = registry
        self._entry = entry
        self._released = False

    def reader(self) -> Iterator:
        return self._entry.exchange.reader()

    def release(self) -> None:
        if not self._released:
            self._released = True
            self._registry._release(self._entry)


class SharedScanRegistry:
    """Warehouse-wide map of live scan-vertex exchanges keyed by identity.

    The key is built by the DAG scheduler from the vertex plan's ``key()``
    (which covers table, columns, pushed/partition filters and min
    write-ID), the query parameters, and the table's ``(hwm, invalid)``
    write-ID state — so only transactionally identical scans ever share.
    """

    def __init__(self, metrics: Optional[MetricsRegistry] = None):
        self._lock = make_lock("serving.shared_scans")
        self._entries: Dict[object, _Entry] = {}
        # counters live in the warehouse MetricsRegistry (PR 10): the
        # legacy ``stats`` dict shape is *derived* from it (see property)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._c = {name: self.metrics.counter(f"serving.shared_scans.{name}")
                   for name in _STAT_NAMES}

    @property
    def stats(self) -> Dict[str, int]:
        return {name: c.value for name, c in self._c.items()}

    # ------------------------------------------------------------- producer
    def publish(self, key, table: str, exchange) -> bool:
        """Register ``exchange`` as the live producer for ``key``.

        Returns False when another producer already holds the key (the
        caller keeps its exchange private and runs normally)."""
        with self._lock:
            if key in self._entries:
                return False
            self._entries[key] = _Entry(key, table, exchange)
            self._c["published"].inc()
            return True

    def retire(self, key, exchange,
               on_final: Optional[Callable[[], None]] = None) -> bool:
        """Producer teardown: drop the entry once no consumer needs it.

        Returns True when the exchange was fully released — the registry
        discarded it (or it was never published) and the caller runs its
        own cleanup.  Returns False when attached consumers are still
        replaying: the registry then owns the discard and runs ``on_final``
        after the last consumer releases."""
        with self._lock:
            entry = self._entries.get(key)
            if entry is None or entry.exchange is not exchange:
                return True  # never published, or already torn down
            if entry.refcount > 0:
                entry.retired = True
                if on_final is not None:
                    entry.on_final.append(on_final)
                return False
            del self._entries[key]
        exchange.discard()
        return True

    # ------------------------------------------------------------- consumer
    def attach(self, key) -> Optional[SharedScanHandle]:
        """Attach a replaying reader to a live entry; None => fresh scan."""
        with self._lock:
            entry = self._entries.get(key)
            if entry is None or entry.retired:
                self._c["attach_misses"].inc()
                return None
            entry.refcount += 1
            self._c["attached"].inc()
            return SharedScanHandle(self, entry)

    def note_fallback(self) -> None:
        with self._lock:
            self._c["fallbacks"].inc()

    def _release(self, entry: _Entry) -> None:
        with self._lock:
            entry.refcount -= 1
            last = entry.retired and entry.refcount == 0
            if last:
                self._entries.pop(entry.key, None)
                callbacks = list(entry.on_final)
        if last:
            entry.exchange.discard()
            for cb in callbacks:
                cb()

    # ------------------------------------------------------------ invalidate
    def invalidate_table(self, table: str) -> None:
        """DDL invalidation (DROP/rename): stop NEW attachments to scans of
        ``table``.  Consumers already attached keep replaying exchange
        chunks — those live in exchange memory/scratch, not table files —
        so a concurrent purge cannot corrupt them."""
        with self._lock:
            for key in [k for k, e in self._entries.items()
                        if e.table == table]:
                self._entries[key].retired = True
                self._c["invalidated"].inc()

    def invalidate_all(self) -> None:
        with self._lock:
            for e in self._entries.values():
                e.retired = True
                self._c["invalidated"].inc()

    # ------------------------------------------------------------ stats
    def stats_snapshot(self) -> Dict[str, int]:
        with self._lock:
            out = dict(self.stats)
            out["live_entries"] = len(self._entries)
            return out
