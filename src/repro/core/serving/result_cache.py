"""Byte-bounded serving result cache (paper §4.3 + §5 serving story).

A drop-in replacement for :class:`~..optimizer.result_cache.QueryResultCache`
(the warehouse wires it in as ``Warehouse.result_cache``) with the bounds a
serving tier needs: entries are charged by result bytes against a fixed
budget and evicted with the same LRFU policy LLAP's chunk cache uses
(``core/runtime/lrfu.py``), instead of a flat entry-count cap.  Validity is
unchanged — per-table write-ID snapshots, checked at lookup — so a hit is
always transactionally current, and the scheduler can serve it without
admission or execution.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Optional, Tuple

from ...analysis.lockdep import make_lock
from ..metastore import Metastore
from ..obs.metrics import MetricsRegistry
from ..optimizer.result_cache import CacheEntry
from ..runtime.exchange import batch_nbytes
from ..runtime.lrfu import LRFUPolicy
from ..runtime.vector import VectorBatch

DEFAULT_CACHE_BYTES = 64 << 20

_STAT_NAMES = ("hits", "misses", "pending_waits", "evictions", "fills",
               "invalidations")


class ResultCacheServer:
    def __init__(self, max_bytes: int = DEFAULT_CACHE_BYTES,
                 ttl_seconds: float = 3600.0, lrfu_lambda: float = 0.01,
                 metrics: Optional[MetricsRegistry] = None):
        self.max_bytes = int(max_bytes)
        self.ttl = ttl_seconds
        self._lock = make_lock("serving.result_cache")
        self._entries: Dict[str, CacheEntry] = {}
        self._sizes: Dict[str, int] = {}
        self._used = 0
        self._policy = LRFUPolicy(lrfu_lambda)
        # counters live in the warehouse MetricsRegistry (PR 10): the
        # legacy ``stats`` dict shape is *derived* from it (see property),
        # so server_stats()/metrics() can never drift apart
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._c = {name: self.metrics.counter(f"serving.result_cache.{name}")
                   for name in _STAT_NAMES}

    @property
    def stats(self) -> Dict[str, int]:
        return {name: c.value for name, c in self._c.items()}

    # -- snapshot helpers -----------------------------------------------------
    @staticmethod
    def _current_state(hms: Metastore, tables) -> Dict[str, Tuple[int, frozenset]]:
        snap = hms.get_snapshot()
        return {
            t: (wl.hwm, wl.invalid)
            for t in tables
            for wl in [hms.writeid_list(t, snap)]
        }

    # -- probe ----------------------------------------------------------------
    def lookup(self, key: str, hms: Metastore, tables) -> Optional[VectorBatch]:
        """Return cached results if still valid; may block on a pending
        entry (thundering-herd serialization, §4.3)."""
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self._c["misses"].inc()
                return None
            pending = entry.pending
        if pending is not None:
            self._c["pending_waits"].inc()
            pending.wait(timeout=60)
            with self._lock:
                entry = self._entries.get(key)
                if entry is None or entry.pending is not None:
                    self._c["misses"].inc()
                    return None
        if time.time() - entry.created_at > self.ttl:
            self._drop(key)
            self._c["misses"].inc()
            return None
        # transactional validity: tables must not contain new/modified data
        if self._current_state(hms, entry.snapshot.keys()) != entry.snapshot:
            self._drop(key)
            self._c["misses"].inc()
            return None
        with self._lock:
            entry.hits += 1
            self._c["hits"].inc()
            self._policy.on_access(key)
        return entry.result

    def begin_pending(self, key: str, hms: Metastore, tables) -> bool:
        """Install a pending entry; True if we are the filling query."""
        with self._lock:
            if key in self._entries:
                return False
            self._entries[key] = CacheEntry(
                result=None,
                snapshot=self._current_state(hms, tables),
                pending=threading.Event(),
            )
            return True

    # -- fill / cancel --------------------------------------------------------
    def fill(self, key: str, result: VectorBatch) -> None:
        nbytes = batch_nbytes(result)
        ev = None
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                return
            if nbytes > self.max_bytes:
                # oversized result: release waiters, don't cache
                self._entries.pop(key, None)
                ev = entry.pending
            else:
                while (self._used + nbytes > self.max_bytes and self._sizes):
                    victim = self._policy.victim()
                    if victim is None:
                        break
                    self._evict(victim)
                entry.result = result
                entry.created_at = time.time()
                ev, entry.pending = entry.pending, None
                self._sizes[key] = nbytes
                self._used += nbytes
                self._policy.on_access(key)
                self._c["fills"].inc()
        if ev is not None:
            ev.set()

    def cancel_pending(self, key: str) -> None:
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                return
            if entry.pending is None:
                return  # already filled — keep the valid entry
            self._entries.pop(key, None)
            ev = entry.pending
        ev.set()

    # -- eviction / invalidation ----------------------------------------------
    def _evict(self, key: str) -> None:
        """Caller holds the lock.  Pending entries are never in the policy,
        so a victim is always a filled entry."""
        entry = self._entries.pop(key, None)
        if entry is not None:
            self._used -= self._sizes.pop(key, 0)
            self._c["evictions"].inc()
        self._policy.on_remove(key)

    def _drop(self, key: str) -> None:
        with self._lock:
            entry = self._entries.pop(key, None)
            if entry is not None:
                self._used -= self._sizes.pop(key, 0)
                self._policy.on_remove(key)

    def invalidate_all(self) -> None:
        with self._lock:
            pendings = [e.pending for e in self._entries.values()
                        if e.pending is not None]
            self._entries.clear()
            self._sizes.clear()
            self._used = 0
            self._policy = LRFUPolicy(self._policy.lam)
            self._c["invalidations"].inc()
        for ev in pendings:
            ev.set()

    # -- stats ----------------------------------------------------------------
    def stats_snapshot(self) -> Dict[str, int]:
        with self._lock:
            out = dict(self.stats)
            out["entries"] = len(self._entries)
            out["bytes_used"] = self._used
            out["bytes_budget"] = self.max_bytes
            return out
