"""Serving tier for high-concurrency workloads (ROADMAP open item 3).

The paper's LLAP layer (§5) exists so that many concurrent queries share
IO, cache and daemon capacity instead of each re-reading the warehouse.
This package holds the warehouse-wide pieces of that story:

  * :class:`SharedScanRegistry` — in-flight scan vertices publish their
    output exchange; a concurrent query whose DAG contains the same scan
    (same plan subtree, same write-ID snapshot) *attaches* as a second
    consumer instead of re-reading through LLAP.
  * :class:`ResultCacheServer` — byte-bounded, LRFU-evicted, write-ID
    invalidated full-result cache, so repeated dashboard queries are
    served without admission or execution.

Sharded WLM admission (lock striping per pool) lives in
``core/runtime/wlm.py``; the session config knobs are
``serving.shared_scans`` and ``serving.result_cache``.
"""
from .result_cache import ResultCacheServer
from .shared_scan import SharedScanHandle, SharedScanRegistry

__all__ = ["ResultCacheServer", "SharedScanHandle", "SharedScanRegistry"]
