"""Central session-config key registry (the REP001 invariant).

Every key a session config may carry is declared here, exactly once, with
its default, its expected type(s), and whether it participates in plan
shaping (the plan-cache key).  ``session.DEFAULT_CONFIG`` and
``pipeline._PLANNING_KEYS`` are both *derived* from this table, so the two
can no longer drift apart — a drifted ``_PLANNING_KEYS`` silently shares
optimized plans across sessions whose configs should have produced
different plans.

The invariant lint (``python -m repro.analysis``, checker REP001) enforces
the other direction: every ``config.get("...")`` call site in the warehouse
code must name a key declared here.  Before this registry existed a typo'd
key fell back to its default silently; now it is a lint failure at the call
site and a :class:`SessionConfig` warning at session creation.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class ConfigKey:
    """One declared session-config knob."""

    name: str
    default: object
    types: Tuple[type, ...]          # accepted value types (None always ok)
    planning: bool = False           # part of the plan-cache key?
    doc: str = ""


def _k(name, default, types, planning=False, doc=""):
    if not isinstance(types, tuple):
        types = (types,)
    return ConfigKey(name, default, types, planning, doc)


_KEYS = (
    # ----------------------------------------------------------- optimizer (§4)
    _k("cbo", True, bool, planning=True, doc="cost-based optimization"),
    _k("pushdown", True, bool, planning=True, doc="filter/project pushdown"),
    _k("join_reorder", True, bool, planning=True, doc="cost-based join order"),
    _k("transitive_inference", True, bool, planning=True,
       doc="predicate transit across join keys"),
    _k("partition_pruning", True, bool, planning=True),
    _k("prune_columns", True, bool, planning=True),
    _k("broadcast_threshold_rows", 200_000.0, (int, float), planning=True,
       doc="build sides below this broadcast instead of shuffling"),
    _k("mv_rewriting", True, bool, planning=True,
       doc="materialized-view rewrite (§4.4)"),
    _k("semijoin_reduction", True, bool, planning=True,
       doc="dynamic semijoin reducers (§4.6)"),
    _k("shared_work", True, bool, doc="shared-subplan detection (§4.5)"),
    _k("result_cache", True, bool, doc="query result cache (§4.3)"),
    _k("reopt_mode", "reoptimize", str,
       doc="off | overlay | reoptimize (§4.2)"),
    _k("overlay", {"broadcast_threshold_rows": 0.0}, dict,
       doc="config overrides applied on §4.2 overlay re-execution"),
    # ------------------------------------------------------------- runtime (§5)
    _k("llap", True, bool, doc="run vertices on the persistent LLAP pool"),
    _k("speculative_execution", False, bool),
    _k("mapjoin_max_rows", 50_000_000, int,
       doc="broadcast build-side row budget"),
    _k("num_containers", 4, int),
    # ---------------------------------------------------------------- ACID (§3)
    _k("compaction_enabled", True, bool),
    _k("compaction_minor_threshold", 10, int),
    _k("compaction_major_ratio", 0.2, (int, float)),
    # ------------------------------------------------------------------ kernels
    _k("engine", "auto", str, doc="kernel backend: auto | pallas | ref"),
    # -------------------------------------------------------------- WLM (§5.2)
    _k("user", None, str, doc="identity for resource-plan mappings"),
    _k("application", None, str),
    # ------------------------------------------------------------ async handles
    _k("stream_batch_rows", 4096, int,
       doc="rows per batch handed to QueryHandle.fetch_stream()"),
    # -------------------------------------------- pipelined exchanges (PR 3, §5)
    _k("exchange.pipeline", True, bool,
       doc="stream vertices concurrently through exchanges"),
    _k("exchange.batch_rows", 1024, int, doc="operator morsel size"),
    _k("exchange.buffer_rows", 65536, int, doc="per-edge in-memory row budget"),
    _k("exchange.buffer_bytes", 64 << 20, int),
    _k("exchange.spill", True, bool,
       doc="spill overflow to scratch (off: MemoryPressureError -> §4.2)"),
    _k("exchange.spill_dir", None, str),
    # ------------------------------------------------ shuffle service (PR 5, §4)
    _k("shuffle.partitions", "auto", (int, str), planning=True,
       doc='lane count per SHUFFLE edge; "auto" derives from CBO rows'),
    _k("shuffle.lane_batch_rows", 8192, int,
       doc="rows the ShuffleWriter coalesces per lane morsel"),
    _k("shuffle.auto_rows_per_partition", 32_768, int, planning=True,
       doc="auto mode: one lane per this many estimated input rows for "
           "consumers that already sit behind a SHUFFLE edge"),
    _k("shuffle.auto_scan_fed_rows_per_partition", 262_144, int,
       planning=True,
       doc="auto mode lane-payoff threshold for scan-fed consumers, where "
           "fan-out adds an exchange hop the single-lane plan fuses away "
           "(the BENCH_PR5 partitioned-DISTINCT regression)"),
    # --------------------------------------------- adaptive execution (PR 8)
    _k("adaptive.enabled", True, bool,
       doc="replan a running DAG from live lane telemetry (hot-lane "
           "split, payoff-gated fan-out collapse)"),
    _k("adaptive.skew_ratio", 4.0, (int, float),
       doc="split a shuffle lane whose observed rows exceed this ratio "
           "over the live lane median"),
    _k("adaptive.split_min_rows", 65_536, int,
       doc="never split a lane before it has at least this many rows"),
    _k("adaptive.split_ways", 0, int,
       doc="sub-lanes a hot lane splits into (0 = derive from cores)"),
    _k("adaptive.elide_copartition", True, bool, planning=True,
       doc="compile-time: reuse a shuffle join's lanes for a downstream "
           "grouped aggregate whose keys cover the join keys, eliding "
           "the second shuffle hop"),
    _k("adaptive.speculation", False, bool,
       doc="clone straggler lane consumers under the pipelined scheduler "
           "and swap consumers to the first finisher (forces lane "
           "retention while on)"),
    _k("adaptive.straggler_factor", 4.0, (int, float),
       doc="speculate a lane consumer running this many times longer "
           "than the median of its finished siblings"),
    _k("adaptive.straggler_min_s", 0.2, (int, float),
       doc="never speculate before a vertex has run this long"),
    # ---------------------------------------------------------- federation (§6)
    _k("federation.push_filters", True, bool, planning=True),
    _k("federation.push_projection", True, bool, planning=True),
    _k("federation.push_aggregate", True, bool, planning=True),
    _k("federation.push_limit", True, bool, planning=True),
    _k("federation.splits", 4, int, doc="split fan-out for external reads"),
    # -------------------------------------------------- serving tier (PR 6)
    _k("serving.shared_scans", True, bool,
       doc="attach concurrent queries to in-flight identical scans"),
    _k("serving.result_cache", True, bool,
       doc="serve repeated queries from the byte-bounded cache pre-admission"),
    # ------------------------------------------------- observability (PR 10)
    _k("obs.tracing", False, bool,
       doc="per-query structured tracing: spans for every pipeline stage, "
           "WLM admission wait, DAG vertex (compute vs exchange-wait vs "
           "spill-I/O), shuffle lane, federated split read, kernel "
           "dispatch, serving and adaptive event; export Chrome trace "
           "JSON via QueryHandle.trace() / Connection.export_trace(). "
           "Off by default — hot paths then pay one attribute test and "
           "allocate no span objects (also enabled process-wide by the "
           "REPRO_OBS_TRACING env var)"),
    _k("obs.query_log_size", 128, int,
       doc="capacity of the warehouse's always-on completed-query ring "
           "buffer (Connection.query_log()); read once at warehouse "
           "creation from this declared default"),
    _k("obs.trace_store_size", 32, int,
       doc="how many completed traced queries the warehouse retains for "
           "Connection.export_trace(query_id, path); oldest evict first; "
           "read once at warehouse creation from this declared default"),
    # -------------------------------------------------------- internal/debug
    _k("keep_acid_cols", False, bool,
       doc="internal: scans keep __rowid__/__writeid__ columns (DML reads)"),
    _k("debug_vertex_delay_s", 0.0, (int, float),
       doc="test hook: sleep per DAG vertex to make concurrency observable"),
    _k("debug.validate_plans", False, bool,
       doc="run the structural DAG validator on every compiled plan "
           "(also enabled process-wide by the REPRO_VALIDATE_PLANS env var)"),
    _k("debug.check_batches", False, bool,
       doc="runtime schema sanitizer: Exchange.put asserts every morsel "
           "conforms to the edge's declared schema (also enabled "
           "process-wide by the REPRO_CHECK_BATCHES env var)"),
)

CONFIG_KEYS: Dict[str, ConfigKey] = {k.name: k for k in _KEYS}

# the dict Session/Connection defaults are built from (former
# session.DEFAULT_CONFIG literal — session re-exports this one)
DEFAULT_CONFIG: Dict[str, object] = {k.name: k.default for k in _KEYS}

# config keys that change the shape of the optimized plan; part of the
# plan-cache key so sessions with different planning configs never share
# plans (former pipeline._PLANNING_KEYS literal)
PLANNING_KEYS: Tuple[str, ...] = tuple(k.name for k in _KEYS if k.planning)


def is_declared(name: str) -> bool:
    return name in CONFIG_KEYS


def check_value(name: str, value: object) -> Optional[str]:
    """Type-check one setting; returns a complaint string or None."""
    import numbers

    key = CONFIG_KEYS.get(name)
    if key is None:
        return f"unknown session config key {name!r}"
    if value is None or key.default is None:
        return None  # nullable keys; None always accepted
    complaint = (f"config key {name!r} expects "
                 f"{'/'.join(t.__name__ for t in key.types)}, "
                 f"got {type(value).__name__}")
    # bool is an int subclass — only accept it where bool is declared
    if isinstance(value, bool):
        return None if bool in key.types else complaint
    if isinstance(value, key.types):
        return None
    # numeric knobs accept any real number (numpy scalars included)
    if (int in key.types or float in key.types) \
            and isinstance(value, numbers.Real):
        return None
    return complaint


class UnknownConfigKeyWarning(UserWarning):
    """A session was created with a key the registry does not declare."""


class SessionConfig(dict):
    """A session's resolved config: defaults overlaid with user settings.

    Unknown keys *warn* instead of raising — the synchronous ``session()``
    path historically accepted any dict, and a hard error here would turn a
    silent-typo class into a breaking change for embedders; the strict path
    (``repro.api.connect``) still rejects unknown keys outright.  The
    warning names the key and the call is otherwise honored.
    """

    def __init__(self, *overlays: dict):
        merged: Dict[str, object] = {}
        for o in overlays:
            merged.update(o)
        super().__init__(merged)
        for name in merged:
            if not is_declared(name):
                warnings.warn(
                    f"unknown session config key {name!r} (typo?); declared "
                    f"keys live in repro.core.config_keys",
                    UnknownConfigKeyWarning,
                    stacklevel=3,
                )


def validate_config(config: dict, type_check: bool = False) -> list:
    """Complaints for unknown (and optionally mistyped) keys in ``config``."""
    out = []
    for name, value in config.items():
        if not is_declared(name):
            out.append(f"unknown session config key {name!r}")
        elif type_check:
            c = check_value(name, value)
            if c is not None:
                out.append(c)
    return out
