"""Typed schema contract for the vectorized runtime (paper §5).

``VectorBatch`` is an untyped dict of numpy arrays; this module is the
contract that says what those dicts *must* look like at every plan edge:

  * :class:`ColumnType` — a numpy dtype family plus nullability.  Types are
    compared by canonical token (``int64``/``float64``/``float32``/``bool``/
    ``str``/``any``); string columns compare by kind so ``U8`` vs ``U64``
    itemsize differences never count as drift.
  * :class:`Schema` — an ordered ``name -> ColumnType`` map with the
    relational-algebra operations the planner needs (project, concat with
    join-collision renaming, positional rename, union promotion).
  * :func:`infer_expr` — mirrors ``runtime/exec.py``'s ``eval_expr`` dtype
    semantics (``/`` is always float64, comparisons are bool, ``||`` is
    string concat, CAST FLOAT is float32, ...).
  * :func:`infer_node` / :func:`annotate_plan` — per-node inference rules
    (Scan/FederatedScan from catalog metadata, then Project/Filter/Join/
    Aggregate/WindowOp/Sort/Limit/Union/ShuffleRead/Values) that the binder
    and pipeline attach to every ``PlanNode`` as ``node.schema``; compile
    propagates them onto DAG vertices and exchange edge declarations.

The static flow checker (``repro.analysis.schema_check``) and the runtime
batch sanitizer (``Exchange.put`` under ``REPRO_CHECK_BATCHES=1``) both
consume these types; inference failures here raise
:class:`SchemaInferenceError` subclasses that the checker maps to SCHnnn
rule codes.

Unknowns degrade to the ``any`` type, which conforms to everything — the
checker only flags *definite* contradictions (an unresolvable column, a
string key hashed against a numeric one), never incomplete knowledge.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .sql import ast as A


class SchemaMismatchError(Exception):
    """Two schemas that must agree don't.  ``context`` names the plan edge
    or exchange tag where the disagreement was observed."""

    def __init__(self, message: str, context: Optional[str] = None):
        self.context = context
        super().__init__(f"{message}" + (f" [at {context}]" if context else ""))


class SchemaInferenceError(SchemaMismatchError):
    """Static inference hit a contradiction (not merely an unknown)."""


class UnresolvedColumnError(SchemaInferenceError):
    """A column reference does not resolve against its input schema."""

    def __init__(self, name: str, available: Sequence[str],
                 context: Optional[str] = None):
        self.name = name
        self.available = list(available)
        super().__init__(
            f"column {name!r} does not resolve against {self.available[:12]}",
            context)


# ---------------------------------------------------------------------------
# ColumnType
# ---------------------------------------------------------------------------
_STR_KINDS = ("U", "S")


def _token_of_dtype(dt: np.dtype) -> str:
    dt = np.dtype(dt)
    if dt.kind in _STR_KINDS:
        return "str"
    if dt.kind == "b":
        return "bool"
    return dt.name  # int64, float64, float32, ...


class ColumnType:
    """A column's dtype family + nullability.

    ``token`` is a canonical name: a numpy numeric dtype name, ``bool``,
    ``str`` (any unicode/bytes itemsize), or ``any`` (statically unknown —
    conforms to everything).  ``nullable`` is informational: NULLs travel as
    NaN in float columns and as the empty string in string columns, so an
    int64 column that *may* hold NULL is physically float64 at runtime;
    :meth:`accepts` knows that representation.
    """

    __slots__ = ("token", "nullable")

    def __init__(self, token, nullable: bool = False):
        if not isinstance(token, str):
            token = _token_of_dtype(token)
        self.token = token
        self.nullable = bool(nullable)

    # -- constructors -------------------------------------------------------
    @classmethod
    def of_array(cls, arr: np.ndarray, nullable: bool = False) -> "ColumnType":
        return cls(_token_of_dtype(arr.dtype), nullable)

    @classmethod
    def of_sql(cls, sql_type: str, nullable: bool = False) -> "ColumnType":
        from .acid import _np_dtype

        try:
            return cls(_token_of_dtype(_np_dtype(sql_type)), nullable)
        except ValueError:
            return ANY

    # -- predicates ---------------------------------------------------------
    @property
    def family(self) -> str:
        if self.token == "any":
            return "any"
        if self.token == "str":
            return "str"
        if self.token == "bool":
            return "bool"
        return "numeric"

    def np_dtype(self) -> np.dtype:
        if self.token == "str":
            return np.dtype("U64")
        if self.token == "any":
            return np.dtype(np.float64)
        return np.dtype(self.token)

    def promote(self, other: "ColumnType",
                context: Optional[str] = None) -> "ColumnType":
        """UNION-branch promotion; raises when no common type exists."""
        nullable = self.nullable or other.nullable
        if self.token == "any" or other.token == "any":
            t = other.token if self.token == "any" else self.token
            return ColumnType(t, nullable)
        if self.token == other.token:
            return ColumnType(self.token, nullable)
        fams = {self.family, other.family}
        if fams <= {"numeric", "bool"}:
            promoted = np.promote_types(
                np.dtype(self.token) if self.token != "bool" else np.bool_,
                np.dtype(other.token) if other.token != "bool" else np.bool_)
            return ColumnType(_token_of_dtype(promoted), nullable)
        raise SchemaInferenceError(
            f"no common type for {self.render()} and {other.render()}",
            context)

    def accepts(self, actual: np.dtype) -> bool:
        """Runtime conformance: may an array of ``actual`` dtype flow through
        an edge declared with this type?"""
        actual = np.dtype(actual)
        if self.token == "any":
            return True
        if self.token == "str":
            return actual.kind in _STR_KINDS
        if actual.name == self.token:
            return True
        # NULLs have no integer/bool representation: a nullable int64/bool
        # column is physically float64 (NaN-null) the moment a NULL appears
        # (outer-join padding, empty-group aggregates), and COALESCE-style
        # merges may round-trip through float64 either way.
        if self.token in ("int64", "bool") and actual.name == "float64":
            return True
        return False

    def render(self) -> str:
        return self.token + ("?" if self.nullable else "")

    def __eq__(self, other):
        return (isinstance(other, ColumnType) and self.token == other.token
                and self.nullable == other.nullable)

    def __hash__(self):
        return hash((self.token, self.nullable))

    def __repr__(self):
        return f"ColumnType({self.render()})"


ANY = ColumnType("any")
BOOL = ColumnType("bool")
INT64 = ColumnType("int64")
FLOAT64 = ColumnType("float64")
STR = ColumnType("str")


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------
class Schema:
    """Ordered ``column name -> ColumnType`` map for one plan edge."""

    __slots__ = ("cols",)

    def __init__(self, cols: Iterable[Tuple[str, ColumnType]]):
        self.cols: Dict[str, ColumnType] = dict(cols)

    # -- constructors -------------------------------------------------------
    @classmethod
    def of_batch(cls, batch) -> "Schema":
        return cls((name, ColumnType.of_array(arr))
                   for name, arr in batch.cols.items())

    @classmethod
    def of_table(cls, table, alias: Optional[str] = None,
                 columns: Optional[Sequence[str]] = None) -> "Schema":
        """From catalog metadata (a ``TableDesc``)."""
        want = list(columns) if columns is not None \
            else [c for c, _ in table.schema]
        prefix = f"{alias}." if alias else ""
        return cls((prefix + c, ColumnType.of_sql(table.dtype_of(c)))
                   for c in want)

    @classmethod
    def any_of(cls, names: Sequence[str]) -> "Schema":
        return cls((n, ANY) for n in names)

    # -- basic access -------------------------------------------------------
    def names(self) -> List[str]:
        return list(self.cols)

    def get(self, name: str) -> Optional[ColumnType]:
        return self.cols.get(name)

    def __len__(self):
        return len(self.cols)

    def __contains__(self, name):
        return name in self.cols

    def __iter__(self):
        return iter(self.cols.items())

    def __eq__(self, other):
        return isinstance(other, Schema) and self.cols == other.cols

    def resolve(self, name: str, table: Optional[str] = None) -> ColumnType:
        """Resolve a (possibly qualified) column reference the way
        ``exec._lookup`` does: exact key first, then unique suffix for
        unqualified names.  Raises :class:`UnresolvedColumnError`."""
        key = f"{table}.{name}" if table else name
        if key in self.cols:
            return self.cols[key]
        if table is None:
            hits = [k for k in self.cols
                    if k == name or k.endswith("." + name)]
            if hits:
                # ambiguity is an execution-time concern; statically, agree
                # when every candidate agrees and degrade to ANY otherwise
                tys = {self.cols[h].token for h in hits}
                return self.cols[hits[0]] if len(tys) == 1 else ANY
        raise UnresolvedColumnError(key, self.names())

    # -- relational operations ---------------------------------------------
    def project(self, names: Sequence[str]) -> "Schema":
        out = []
        for n in names:
            ty = self.cols.get(n)
            if ty is None:
                raise UnresolvedColumnError(n, self.names())
            out.append((n, ty))
        return Schema(out)

    def rename_to(self, names: Sequence[str],
                  context: Optional[str] = None) -> "Schema":
        """Positional rename (UNION branches, federated output naming)."""
        if len(names) != len(self.cols):
            raise SchemaMismatchError(
                f"arity mismatch: {len(names)} names for "
                f"{len(self.cols)} columns", context)
        return Schema(zip(names, self.cols.values()))

    def concat(self, other: "Schema") -> "Schema":
        """Join-output concatenation; collisions on the right side get the
        ``__r`` suffix exactly like ``exec._concat_sides``."""
        cols = dict(self.cols)
        for k, v in other.cols.items():
            if k in cols:
                k = k + "__r"
            cols[k] = v
        return Schema(cols.items())

    def promote(self, other: "Schema",
                context: Optional[str] = None) -> "Schema":
        """Positional UNION promotion: same arity, pairwise common types,
        left side's names win."""
        if len(self.cols) != len(other.cols):
            raise SchemaMismatchError(
                f"union branch arity mismatch: {self.names()} vs "
                f"{other.names()}", context)
        out = []
        for (ln, lt), (_, rt) in zip(self.cols.items(), other.cols.items()):
            out.append((ln, lt.promote(rt, context)))
        return Schema(out)

    def nullable(self) -> "Schema":
        """All columns marked nullable (outer-join padding side)."""
        return Schema((n, ColumnType(t.token, True)) for n, t in self)

    def to_pairs(self) -> List[Tuple[str, np.dtype]]:
        """(name, numpy dtype) pairs — feeds ``VectorBatch.empty``."""
        return [(n, t.np_dtype()) for n, t in self]

    def describe(self) -> str:
        return ", ".join(f"{n}:{t.render()}" for n, t in self)

    def __repr__(self):
        return f"Schema({self.describe()})"

    # -- runtime conformance ------------------------------------------------
    def check_batch(self, batch, context: Optional[str] = None) -> None:
        """Assert a morsel conforms: declared names all present with
        conforming dtypes, no undeclared columns (hidden ``__``-prefixed
        bookkeeping columns like ACID's rowid travel freely)."""
        for name, ty in self.cols.items():
            arr = batch.cols.get(name)
            if arr is None:
                raise SchemaMismatchError(
                    f"declared column {name!r} missing from batch "
                    f"{list(batch.cols)[:12]}", context)
            if not ty.accepts(arr.dtype):
                raise SchemaMismatchError(
                    f"column {name!r} declared {ty.render()} but batch "
                    f"carries {arr.dtype.name}", context)
        for name in batch.cols:
            if name not in self.cols and not name.startswith("__"):
                raise SchemaMismatchError(
                    f"undeclared column {name!r} in batch (declared: "
                    f"{self.names()[:12]})", context)


# ---------------------------------------------------------------------------
# expression type inference — mirrors exec.eval_expr dtype semantics
# ---------------------------------------------------------------------------
_BOOL_OPS = {"AND", "OR", "=", "!=", "<", "<=", ">", ">=", "LIKE"}
_STR_FUNCS = {"lower", "upper", "substr"}
_INT_FUNCS = {"length", "extract", "year"}


def _lit_type(value) -> ColumnType:
    # mirrors exec._broadcast
    if value is None:
        return ColumnType("float64", True)
    if isinstance(value, bool):
        return BOOL
    if isinstance(value, int):
        return INT64
    if isinstance(value, float):
        return FLOAT64
    return STR


def infer_expr(e: A.Expr, schema: Schema) -> ColumnType:
    """Static dtype of ``eval_expr(e, batch)`` for a batch of ``schema``."""
    if isinstance(e, A.Col):
        return schema.resolve(e.name, e.table)
    if isinstance(e, A.Lit):
        return _lit_type(e.value)
    if isinstance(e, A.Param):
        return ANY  # bound at execute(); value type unknown statically
    if isinstance(e, A.BinOp):
        lt = infer_expr(e.left, schema)
        rt = infer_expr(e.right, schema)
        if e.op in _BOOL_OPS:
            return BOOL
        if e.op == "||":
            return STR
        if e.op == "/":
            return FLOAT64  # eval_expr divides in float64 unconditionally
        if lt.family == "str" or rt.family == "str":
            return STR  # arithmetic coerces to str when either side is
        return lt.promote(rt)
    if isinstance(e, A.UnOp):
        if e.op.upper() == "NOT":
            return BOOL
        return infer_expr(e.operand, schema)  # unary minus keeps dtype
    if isinstance(e, (A.InList, A.Between, A.IsNull)):
        return BOOL
    if isinstance(e, A.Cast):
        infer_expr(e.expr, schema)  # still verify the operand resolves
        t = e.to_type.upper()
        if t.startswith(("INT", "BIGINT")):
            return INT64
        if t.startswith("FLOAT"):
            return ColumnType("float32")
        if t.startswith(("DOUBLE", "DECIMAL", "REAL")):
            return FLOAT64
        return STR
    if isinstance(e, A.Case):
        out: Optional[ColumnType] = None
        for when, then in e.whens:
            infer_expr(when, schema)
            ty = infer_expr(then, schema)
            out = ty if out is None else out.promote(ty)
        if e.otherwise is not None:
            ty = infer_expr(e.otherwise, schema)
            out = ty if out is None else out.promote(ty)
        else:
            out = ColumnType(out.token, True) if out is not None else ANY
        return out or ANY
    if isinstance(e, A.Func):
        for a in e.args:
            infer_expr(a, schema)
        name = e.name.lower()
        if name in _STR_FUNCS:
            return STR
        if name in _INT_FUNCS:
            return INT64
        if name == "abs":
            return infer_expr(e.args[0], schema)
        if name in ("floor", "ceil"):
            # np.floor/ceil promote ints to float64, keep float32
            ty = infer_expr(e.args[0], schema)
            return ty if ty.token in ("float32", "float64") else FLOAT64
        if name == "round":
            ty = infer_expr(e.args[0], schema)
            return ty if ty.family == "numeric" else ty
        if name == "coalesce":
            out = infer_expr(e.args[0], schema)
            for a in e.args[1:]:
                out = out.promote(infer_expr(a, schema))
            return out
        return ANY  # unknown scalar — let execution decide
    if isinstance(e, A.SubqueryExpr):
        return BOOL if e.kind in ("in", "exists") else ANY
    return ANY  # Star / WindowFunc / anything new


def agg_result_type(fn: str, arg_type: ColumnType) -> ColumnType:
    """Output type of one aggregate spec — mirrors ``exec._agg_column``:
    COUNT is int64; SUM/MIN/MAX of int stay int64 (physically float64-NaN
    when a group comes up empty, which ``accepts`` allows); float32 MIN/MAX
    preserve float32; SUM widens float32 to float64 accumulation."""
    fn = fn.lower()
    if fn == "count":
        return INT64
    if fn == "avg":
        return ColumnType("float64", True)
    if arg_type.token == "any":
        return ANY
    if fn == "sum":
        if arg_type.family == "str":
            raise SchemaInferenceError(f"sum() over string column")
        if arg_type.token in ("int64", "bool"):
            return ColumnType("int64", True)
        return ColumnType("float64", True)
    if fn in ("min", "max"):
        if arg_type.family == "str":
            return ColumnType("str", True)
        if arg_type.token == "float32":
            return ColumnType("float32", True)
        if arg_type.token in ("int64", "bool"):
            return ColumnType("int64", True)
        return ColumnType("float64", True)
    return ANY


def _window_type(wf: A.WindowFunc, schema: Schema) -> ColumnType:
    fn = wf.func.name.lower()
    if fn in ("row_number", "rank", "dense_rank", "count"):
        return INT64
    if fn in ("lag", "lead"):
        # exec seeds lag/lead output from _null_like(arg): numeric -> float64
        ty = infer_expr(wf.func.args[0], schema) if wf.func.args else ANY
        if ty.family == "str":
            return ColumnType("str", True)
        if ty.token == "any":
            return ANY
        return ColumnType("float64", True)
    if fn in ("sum", "min", "max", "avg"):
        arg = infer_expr(wf.func.args[0], schema) if wf.func.args else ANY
        return agg_result_type(fn, arg)
    return ANY


# ---------------------------------------------------------------------------
# plan-node schema inference
# ---------------------------------------------------------------------------
def infer_node(node, input_schemas: List[Schema]) -> Schema:
    """Output schema of one plan node given its inputs' schemas.

    Raises :class:`SchemaInferenceError` (or subclasses) on definite
    contradictions; unknowable types come back as ``any``.
    """
    from .optimizer import plan as P
    from .runtime.dag import MaterializedNode

    if isinstance(node, P.Scan):
        return Schema.of_table(node.table, node.alias, node.columns)
    if isinstance(node, P.FederatedScan):
        return _federated_schema(node)
    if isinstance(node, MaterializedNode):
        if getattr(node, "schema", None) is not None:
            return node.schema
        return Schema.any_of(node.names)
    if isinstance(node, (P.Filter, P.Sort, P.Limit)):
        src = input_schemas[0]
        if isinstance(node, P.Filter):
            infer_expr(node.predicate, src)
        if isinstance(node, P.Sort):
            for k, _ in node.keys:
                src.resolve(k)
        return src
    if isinstance(node, P.Project):
        src = input_schemas[0]
        return Schema((name, infer_expr(expr, src))
                      for expr, name in node.exprs)
    if isinstance(node, P.Join):
        left, right = input_schemas
        for lk, rk in zip(node.left_keys, node.right_keys):
            lt, rt = left.resolve(lk), right.resolve(rk)
            if "any" not in (lt.family, rt.family) and lt.family != rt.family:
                raise SchemaInferenceError(
                    f"join key dtype family mismatch: {lk}:{lt.render()} vs "
                    f"{rk}:{rt.render()} (bitcast hash partitions them "
                    f"differently)")
        if node.kind in ("semi", "anti"):
            return left
        if node.kind == "left":
            right = _null_extended(right)
        elif node.kind == "full":
            left, right = _null_extended(left), _null_extended(right)
        out = left.concat(right)
        if node.residual is not None:
            infer_expr(node.residual, out)
        return out
    if isinstance(node, P.Aggregate):
        src = input_schemas[0]
        out: List[Tuple[str, ColumnType]] = []
        for k in node.group_keys:
            out.append((k, src.resolve(k)))
        for spec in node.aggs:
            arg = infer_expr(spec.arg, src) if spec.arg is not None else ANY
            out.append((spec.out_name, agg_result_type(spec.fn, arg)))
        if node.grouping_sets is not None:
            # keys absent from a grouping set are NULL-padded in its rows
            out = [(n, ColumnType(t.token, True) if n in node.group_keys
                    else t) for n, t in out]
        return Schema(out)
    if isinstance(node, P.WindowOp):
        src = input_schemas[0]
        cols = list(src)
        for wf, name in node.funcs:
            cols.append((name, _window_type(wf, src)))
        return Schema(cols)
    if isinstance(node, P.Union):
        out = input_schemas[0]
        names = out.names()
        for i, branch in enumerate(input_schemas[1:], start=1):
            out = out.promote(branch.rename_to(names, f"union branch {i}"),
                              f"union branch {i}")
        return out
    if isinstance(node, P.ShuffleRead):
        src = input_schemas[0]
        for k in node.keys:
            src.resolve(k)
        return src
    if isinstance(node, P.ValuesNode):
        # cells are expressions evaluated against a dummy one-row batch
        empty = Schema(())
        cols: List[Tuple[str, ColumnType]] = []
        for i, name in enumerate(node.names):
            ty: Optional[ColumnType] = None
            for row in node.rows:
                try:
                    vt = infer_expr(row[i], empty) if i < len(row) else ANY
                except SchemaMismatchError:
                    vt = ANY
                ty = vt if ty is None else ty.promote(vt)
            cols.append((name, ty or ANY))
        return Schema(cols)
    # unknown node kind — stay permissive
    return Schema.any_of(node.output_names())


def _null_extended(schema: Schema) -> Schema:
    """The padded side of an outer join: every column becomes nullable, and
    numeric columns widen to float64 (``_null_like`` pads with NaN)."""
    out = []
    for n, t in schema:
        if t.family == "numeric":
            out.append((n, ColumnType("float64", True)))
        elif t.family == "bool":
            out.append((n, ColumnType("float64", True)))
        else:
            out.append((n, ColumnType(t.token, True)))
    return Schema(out)


def _federated_schema(node) -> Schema:
    """FederatedScan output: ``output_names()`` order, typed from catalog
    metadata through the negotiated spec (projection narrows, pushed
    aggregates type as group keys + agg results)."""
    table = node.table
    spec = node.spec

    def raw_type(col: Optional[str]) -> ColumnType:
        if col is None:
            return ANY
        try:
            return ColumnType.of_sql(table.dtype_of(col))
        except (KeyError, ValueError):
            return ANY

    if spec is not None and spec.agg is not None:
        raw = [(k, raw_type(k)) for k in spec.agg.group_keys]
        raw += [(out, agg_result_type(fn, raw_type(arg)))
                for fn, arg, out in spec.agg.aggs]
    elif spec is not None and spec.projection is not None:
        raw = [(c, raw_type(c)) for c in spec.projection]
    else:
        raw = [(c, raw_type(c)) for c, _ in table.schema]
    names = node.output_names()
    if len(names) != len(raw):
        # connector/plan disagreement is SCH005 territory; stay permissive
        # here and let the checker compare against output_columns()
        return Schema.any_of(names)
    return Schema((n, t) for n, (_, t) in zip(names, raw))


def infer_plan(node, memo: Optional[Dict[int, Schema]] = None) -> Schema:
    """Recursive inference over a plan tree (raises on contradiction)."""
    if memo is None:
        memo = {}
    got = memo.get(id(node))
    if got is not None:
        return got
    ins = [infer_plan(i, memo) for i in node.inputs]
    out = infer_node(node, ins)
    memo[id(node)] = out
    return out


def annotate_plan(node, memo: Optional[Dict[int, Optional[Schema]]] = None):
    """Attach ``node.schema`` bottom-up, tolerantly: a subtree whose schema
    cannot be inferred gets ``schema = None`` (EXPLAIN omits the line, the
    runtime sanitizer skips the edge) instead of failing the query — the
    strict path is the checker, not annotation."""
    if memo is None:
        memo = {}
    if id(node) in memo:
        return memo[id(node)]
    ins = [annotate_plan(i, memo) for i in node.inputs]
    try:
        if any(s is None for s in ins):
            out: Optional[Schema] = None
        else:
            out = infer_node(node, ins)
    except SchemaMismatchError:
        out = None
    node.schema = out
    memo[id(node)] = out
    return out
