"""SQL lexer + recursive-descent parser (paper §3.1 SQL surface).

Covers the warehouse subset exercised in the paper: SELECT with joins,
correlated/uncorrelated subqueries (IN / EXISTS / scalar), window functions,
grouping sets, set operations, DML (INSERT/UPDATE/DELETE/MERGE), DDL with
``PARTITIONED BY`` and ``STORED BY`` (storage handlers), materialized views,
and the workload-management DDL of §5.2.
"""
from __future__ import annotations

import re
from typing import List, Optional, Tuple

from . import ast as A

_TOKEN_RE = re.compile(
    r"""
    (?P<ws>\s+|--[^\n]*)
  | (?P<num>\d+\.\d+|\d+|\.\d+)
  | (?P<str>'(?:[^']|'')*')
  | (?P<ident>[A-Za-z_][A-Za-z_0-9]*)
  | (?P<op><>|!=|>=|<=|=|<|>|\|\||[+\-*/%(),.;?])
    """,
    re.VERBOSE,
)

KEYWORDS = {
    "select", "from", "where", "group", "by", "having", "order", "limit",
    "as", "and", "or", "not", "in", "exists", "between", "like", "is",
    "null", "case", "when", "then", "else", "end", "cast", "distinct",
    "join", "inner", "left", "right", "full", "outer", "cross", "on",
    "union", "intersect", "except", "all", "asc", "desc", "insert", "into",
    "values", "update", "set", "delete", "merge", "using", "matched",
    "create", "table", "external", "partitioned", "stored", "tblproperties",
    "materialized", "view", "drop", "if", "rebuild", "alter", "explain",
    "analyze", "primary", "key", "unique", "foreign", "references", "over",
    "partition", "rows", "grouping", "sets", "resource", "plan", "pool",
    "with", "rule", "move", "kill", "add", "to", "mapping", "application",
    "user", "default", "enable", "activate", "true", "false", "by",
    "catalog",
}


class Token:
    __slots__ = ("kind", "value", "pos")

    def __init__(self, kind: str, value: str, pos: int):
        self.kind = kind  # num | str | ident | kw | op | eof
        self.value = value
        self.pos = pos

    def __repr__(self):
        return f"Token({self.kind},{self.value!r})"


def tokenize(sql: str) -> List[Token]:
    out, pos = [], 0
    while pos < len(sql):
        m = _TOKEN_RE.match(sql, pos)
        if not m:
            raise SyntaxError(f"cannot tokenize at {sql[pos:pos+20]!r}")
        pos = m.end()
        if m.lastgroup == "ws":
            continue
        text = m.group()
        if m.lastgroup == "ident":
            low = text.lower()
            out.append(Token("kw" if low in KEYWORDS else "ident", low if low in KEYWORDS else text, m.start()))
        elif m.lastgroup == "str":
            out.append(Token("str", text[1:-1].replace("''", "'"), m.start()))
        else:
            out.append(Token(m.lastgroup, text, m.start()))
    out.append(Token("eof", "", len(sql)))
    return out


class Parser:
    def __init__(self, sql: str):
        self.toks = tokenize(sql)
        self.i = 0
        self._next_param = 0  # ordinal for '?' placeholders (qmark style)

    # -- token helpers --------------------------------------------------------
    def peek(self, offset: int = 0) -> Token:
        return self.toks[min(self.i + offset, len(self.toks) - 1)]

    def next(self) -> Token:
        t = self.toks[self.i]
        self.i += 1
        return t

    def at_kw(self, *kws: str) -> bool:
        t = self.peek()
        return t.kind == "kw" and t.value in kws

    def accept_kw(self, *kws: str) -> bool:
        if self.at_kw(*kws):
            self.next()
            return True
        return False

    def expect_kw(self, kw: str) -> None:
        if not self.accept_kw(kw):
            raise SyntaxError(f"expected {kw.upper()} at {self.peek()!r}")

    def accept_op(self, op: str) -> bool:
        t = self.peek()
        if t.kind == "op" and t.value == op:
            self.next()
            return True
        return False

    def expect_op(self, op: str) -> None:
        if not self.accept_op(op):
            raise SyntaxError(f"expected {op!r} at {self.peek()!r}")

    def ident(self) -> str:
        t = self.next()
        if t.kind not in ("ident", "kw"):
            raise SyntaxError(f"expected identifier at {t!r}")
        return t.value

    # ==========================================================================
    # statements
    # ==========================================================================
    def parse(self) -> A.Statement:
        stmt = self._statement()
        self.accept_op(";")
        if self.peek().kind != "eof":
            raise SyntaxError(f"trailing tokens at {self.peek()!r}")
        return stmt

    def _statement(self) -> A.Statement:
        if self.at_kw("explain"):
            self.next()
            analyze = self.accept_kw("analyze")
            return A.Explain(self._statement(), analyze)
        if self.at_kw("select") or (self.peek().kind == "op" and self.peek().value == "("):
            return self._select_with_setops()
        if self.at_kw("insert"):
            return self._insert()
        if self.at_kw("update"):
            return self._update()
        if self.at_kw("delete"):
            return self._delete()
        if self.at_kw("merge"):
            return self._merge()
        if self.at_kw("create"):
            return self._create()
        if self.at_kw("drop"):
            self.next()
            if self.accept_kw("catalog"):
                if_exists = False
                if self.accept_kw("if"):
                    self.expect_kw("exists")
                    if_exists = True
                return A.DropCatalog(self.ident(), if_exists)
            self.expect_kw("table")
            if_exists = False
            if self.accept_kw("if"):
                self.expect_kw("exists")
                if_exists = True
            return A.DropTable(self.ident(), if_exists)
        if self.at_kw("alter"):
            return self._alter()
        if self.at_kw("add"):
            self.next()
            self.expect_kw("rule")
            rule = self.ident()
            self.expect_kw("to")
            pool = self.ident()
            return A.AddWMRuleToPool(plan=None, rule=rule, pool=pool)
        raise SyntaxError(f"unsupported statement start {self.peek()!r}")

    # -- SELECT / set ops -----------------------------------------------------
    def _select_with_setops(self):
        left = self._select_core()
        while self.at_kw("union", "intersect", "except"):
            kind = self.next().value
            all_ = self.accept_kw("all")
            right = self._select_core()
            left = A.SetOp(kind, all_, left, right)
        # trailing ORDER BY / LIMIT bind to the set-op result
        if isinstance(left, A.SetOp):
            if self.accept_kw("order"):
                self.expect_kw("by")
                left.order_by = self._order_list()
            if self.accept_kw("limit"):
                left.limit = int(self.next().value)
        return left

    def _select_core(self) -> A.Select:
        if self.accept_op("("):
            s = self._select_with_setops()
            self.expect_op(")")
            return s
        self.expect_kw("select")
        distinct = self.accept_kw("distinct")
        projections = []
        while True:
            e = self._expr()
            alias = None
            if self.accept_kw("as"):
                alias = self.ident()
            elif self.peek().kind == "ident":
                alias = self.ident()
            projections.append((e, alias))
            if not self.accept_op(","):
                break
        sel = A.Select(projections=projections, distinct=distinct)
        if self.accept_kw("from"):
            sel.from_ = self._from_clause()
        if self.accept_kw("where"):
            sel.where = self._expr()
        if self.accept_kw("group"):
            self.expect_kw("by")
            if self.accept_kw("grouping"):
                self.expect_kw("sets")
                self.expect_op("(")
                sets = []
                while True:
                    self.expect_op("(")
                    exprs = []
                    if not self.accept_op(")"):
                        while True:
                            exprs.append(self._expr())
                            if not self.accept_op(","):
                                break
                        self.expect_op(")")
                    sets.append(exprs)
                    if not self.accept_op(","):
                        break
                self.expect_op(")")
                sel.grouping_sets = sets
                keys, seen = [], set()
                for s in sets:
                    for e in s:
                        if e.key() not in seen:
                            seen.add(e.key())
                            keys.append(e)
                sel.group_by = keys
            else:
                while True:
                    sel.group_by.append(self._expr())
                    if not self.accept_op(","):
                        break
        if self.accept_kw("having"):
            sel.having = self._expr()
        if self.accept_kw("order"):
            self.expect_kw("by")
            sel.order_by = self._order_list()
        if self.accept_kw("limit"):
            sel.limit = int(self.next().value)
        return sel

    def _order_list(self) -> List[Tuple[A.Expr, bool]]:
        out = []
        while True:
            e = self._expr()
            desc = False
            if self.accept_kw("desc"):
                desc = True
            else:
                self.accept_kw("asc")
            out.append((e, desc))
            if not self.accept_op(","):
                break
        return out

    def _from_clause(self):
        left = self._table_factor()
        while True:
            if self.accept_op(","):
                right = self._table_factor()
                left = A.JoinRef(left, right, "cross", None)
            elif self.at_kw("join", "inner", "left", "right", "full", "cross"):
                kind = "inner"
                if self.accept_kw("inner"):
                    pass
                elif self.accept_kw("left"):
                    kind = "left"
                    self.accept_kw("outer")
                elif self.accept_kw("right"):
                    kind = "right"
                    self.accept_kw("outer")
                elif self.accept_kw("full"):
                    kind = "full"
                    self.accept_kw("outer")
                elif self.accept_kw("cross"):
                    kind = "cross"
                self.expect_kw("join")
                right = self._table_factor()
                cond = None
                if kind != "cross":
                    self.expect_kw("on")
                    cond = self._expr()
                left = A.JoinRef(left, right, kind, cond)
            else:
                return left

    def _table_factor(self):
        if self.accept_op("("):
            q = self._select_with_setops()
            self.expect_op(")")
            self.accept_kw("as")
            alias = self.ident()
            return A.SubqueryRef(q, alias)
        # one-, two-, or three-part names: table | catalog.table |
        # catalog.schema.table (federated catalogs, paper §6)
        parts = [self.ident()]
        while len(parts) < 3 and self.peek().kind == "op" \
                and self.peek().value == ".":
            self.next()
            parts.append(self.ident())
        alias = None
        if self.accept_kw("as"):
            alias = self.ident()
        elif self.peek().kind == "ident":
            alias = self.ident()
        if len(parts) == 1:
            return A.TableRef(parts[0], alias)
        if len(parts) == 2:
            return A.TableRef(parts[1], alias, catalog=parts[0])
        return A.TableRef(parts[2], alias, catalog=parts[0], schema=parts[1])

    # -- DML --------------------------------------------------------------
    def _insert(self) -> A.Insert:
        self.expect_kw("insert")
        self.expect_kw("into")
        table = self.ident()
        columns = None
        if self.peek().kind == "op" and self.peek().value == "(" and (
            self.peek(1).kind in ("ident",) or
            (self.peek(1).kind == "kw" and self.peek(2).kind == "op")
        ):
            self.expect_op("(")
            columns = []
            while True:
                columns.append(self.ident())
                if not self.accept_op(","):
                    break
            self.expect_op(")")
        if self.accept_kw("values"):
            rows = []
            while True:
                self.expect_op("(")
                row = []
                while True:
                    row.append(self._expr())
                    if not self.accept_op(","):
                        break
                self.expect_op(")")
                rows.append(row)
                if not self.accept_op(","):
                    break
            return A.Insert(table, columns, A.Values(rows))
        return A.Insert(table, columns, self._select_with_setops())

    def _update(self) -> A.Update:
        self.expect_kw("update")
        table = self.ident()
        self.expect_kw("set")
        assigns = []
        while True:
            col = self.ident()
            self.expect_op("=")
            assigns.append((col, self._expr()))
            if not self.accept_op(","):
                break
        where = self._expr() if self.accept_kw("where") else None
        return A.Update(table, assigns, where)

    def _delete(self) -> A.Delete:
        self.expect_kw("delete")
        self.expect_kw("from")
        table = self.ident()
        where = self._expr() if self.accept_kw("where") else None
        return A.Delete(table, where)

    def _merge(self) -> A.Merge:
        self.expect_kw("merge")
        self.expect_kw("into")
        target = self._table_factor()
        self.expect_kw("using")
        source = self._table_factor()
        self.expect_kw("on")
        on = self._expr()
        matched, not_matched = [], []
        while self.at_kw("when"):
            self.next()
            negated = self.accept_kw("not")
            self.expect_kw("matched")
            cond = self._expr() if self.accept_kw("and") else None
            self.expect_kw("then")
            if self.accept_kw("update"):
                self.expect_kw("set")
                assigns = []
                while True:
                    col = self.ident()
                    self.expect_op("=")
                    assigns.append((col, self._expr()))
                    if not self.accept_op(","):
                        break
                matched.append(A.MergeAction("update", assignments=assigns, condition=cond))
            elif self.accept_kw("delete"):
                matched.append(A.MergeAction("delete", condition=cond))
            elif self.accept_kw("insert"):
                cols = None
                if self.accept_op("("):
                    cols = []
                    while True:
                        cols.append(self.ident())
                        if not self.accept_op(","):
                            break
                    self.expect_op(")")
                self.expect_kw("values")
                self.expect_op("(")
                vals = []
                while True:
                    vals.append(self._expr())
                    if not self.accept_op(","):
                        break
                self.expect_op(")")
                (not_matched if negated else matched).append(
                    A.MergeAction("insert", columns=cols, values=vals, condition=cond)
                )
        assert isinstance(target, A.TableRef)
        return A.Merge(target, source, on, matched, not_matched)

    # -- DDL ---------------------------------------------------------------
    def _create(self):
        self.expect_kw("create")
        if self.accept_kw("catalog"):
            # CREATE CATALOG name USING connector [WITH (k = v, ...)]
            name = self.ident()
            self.expect_kw("using")
            connector = self.next().value  # ident or quoted string
            props = {}
            if self.accept_kw("with"):
                props = self._props()
            return A.CreateCatalog(name, connector, props)
        if self.accept_kw("materialized"):
            self.expect_kw("view")
            name = self.ident()
            props, stored_by = {}, None
            while True:
                if self.accept_kw("stored"):
                    self.expect_kw("by")
                    stored_by = self.next().value
                elif self.accept_kw("tblproperties"):
                    props.update(self._props())
                else:
                    break
            self.expect_kw("as")
            q = self._select_with_setops()
            return A.CreateMaterializedView(name, q, props, stored_by)
        if self.accept_kw("resource"):
            self.expect_kw("plan")
            return A.CreateResourcePlan(self.ident())
        if self.accept_kw("pool"):
            plan = self.ident()
            self.expect_op(".")
            pool = self.ident()
            self.expect_kw("with")
            kv = {}
            while True:
                k = self.ident()
                self.expect_op("=")
                kv[k] = float(self.next().value)
                if not self.accept_op(","):
                    break
            return A.CreatePool(plan, pool, kv.get("alloc_fraction", 1.0),
                                int(kv.get("query_parallelism", 1)))
        if self.accept_kw("rule"):
            rule = self.ident()
            self.expect_kw("in")
            plan = self.ident()
            self.expect_kw("when")
            metric = self.ident()
            op = self.next().value  # > / >= etc
            threshold = float(self.next().value)
            self.expect_kw("then")
            if self.accept_kw("move"):
                return A.CreateWMRule(plan, rule, metric, threshold, "move", self.ident())
            self.expect_kw("kill")
            return A.CreateWMRule(plan, rule, metric, threshold, "kill")
        if self.accept_kw("application") or self.accept_kw("user"):
            kind = self.toks[self.i - 1].value
            self.expect_kw("mapping")
            entity = self.next().value  # ident or string
            self.expect_kw("in")
            plan = self.ident()
            self.expect_kw("to")
            return A.CreateWMMapping(plan, kind, entity, self.ident())
        external = self.accept_kw("external")
        self.expect_kw("table")
        name = self.ident()
        columns, fks = [], []
        if self.accept_op("("):
            while True:
                col = self.ident()
                ctype = self._type_name()
                cons = []
                while True:
                    if self.accept_kw("primary"):
                        self.expect_kw("key")
                        cons.append("primary key")
                    elif self.accept_kw("not"):
                        self.expect_kw("null")
                        cons.append("not null")
                    elif self.accept_kw("unique"):
                        cons.append("unique")
                    elif self.accept_kw("references"):
                        ref_t = self.ident()
                        self.expect_op("(")
                        ref_c = self.ident()
                        self.expect_op(")")
                        fks.append((col, ref_t, ref_c))
                    else:
                        break
                columns.append(A.ColumnDef(col, ctype, cons))
                if not self.accept_op(","):
                    break
            self.expect_op(")")
        part, props, stored_by = [], {}, None
        while True:
            if self.accept_kw("partitioned"):
                self.expect_kw("by")
                self.expect_op("(")
                while True:
                    pc = self.ident()
                    pt = self._type_name()
                    part.append(A.ColumnDef(pc, pt))
                    if not self.accept_op(","):
                        break
                self.expect_op(")")
            elif self.accept_kw("stored"):
                self.expect_kw("by")
                stored_by = self.next().value
            elif self.accept_kw("tblproperties"):
                props.update(self._props())
            else:
                break
        return A.CreateTable(name, columns, part, props, stored_by, external, fks)

    def _alter(self):
        self.expect_kw("alter")
        if self.accept_kw("materialized"):
            self.expect_kw("view")
            name = self.ident()
            self.expect_kw("rebuild")
            return A.RebuildMaterializedView(name)
        if self.accept_kw("resource"):
            self.expect_kw("plan")
            plan = self.ident()
            self.expect_kw("enable")
            self.expect_kw("activate")
            return A.AlterResourcePlan(plan, enable_activate=True)
        self.expect_kw("plan")
        plan = self.ident()
        self.expect_kw("set")
        self.expect_kw("default")
        self.expect_kw("pool")
        self.expect_op("=")
        return A.AlterResourcePlan(plan, default_pool=self.ident())

    def _props(self) -> dict:
        self.expect_op("(")
        out = {}
        while True:
            k = self.next().value
            self.expect_op("=")
            out[k] = self.next().value
            if not self.accept_op(","):
                break
        self.expect_op(")")
        return out

    def _type_name(self) -> str:
        base = self.ident().upper()
        if self.accept_op("("):
            args = [self.next().value]
            while self.accept_op(","):
                args.append(self.next().value)
            self.expect_op(")")
            base += f"({','.join(args)})"
        return base

    # ==========================================================================
    # expressions (precedence climbing)
    # ==========================================================================
    def _expr(self) -> A.Expr:
        return self._or_expr()

    def _or_expr(self) -> A.Expr:
        left = self._and_expr()
        while self.accept_kw("or"):
            left = A.BinOp("OR", left, self._and_expr())
        return left

    def _and_expr(self) -> A.Expr:
        left = self._not_expr()
        while self.accept_kw("and"):
            left = A.BinOp("AND", left, self._not_expr())
        return left

    def _not_expr(self) -> A.Expr:
        if self.accept_kw("not"):
            return A.UnOp("NOT", self._not_expr())
        return self._comparison()

    def _comparison(self) -> A.Expr:
        if self.at_kw("exists"):
            self.next()
            self.expect_op("(")
            q = self._select_with_setops()
            self.expect_op(")")
            return A.SubqueryExpr(q, "exists")
        left = self._additive()
        while True:
            negated = False
            save = self.i
            if self.accept_kw("not"):
                negated = True
            if self.accept_kw("in"):
                self.expect_op("(")
                if self.at_kw("select"):
                    q = self._select_with_setops()
                    self.expect_op(")")
                    left = A.SubqueryExpr(q, "in", expr=left, negated=negated)
                else:
                    vals = []
                    while True:
                        vals.append(self._expr())
                        if not self.accept_op(","):
                            break
                    self.expect_op(")")
                    left = A.InList(left, tuple(vals), negated)
                continue
            if self.accept_kw("between"):
                low = self._additive()
                self.expect_kw("and")
                high = self._additive()
                left = A.Between(left, low, high, negated)
                continue
            if self.accept_kw("like"):
                left = A.BinOp("LIKE", left, self._additive())
                if negated:
                    left = A.UnOp("NOT", left)
                continue
            if negated:
                self.i = save  # NOT belongs to a boolean factor, rewind
                break
            if self.accept_kw("is"):
                neg = self.accept_kw("not")
                self.expect_kw("null")
                left = A.IsNull(left, neg)
                continue
            t = self.peek()
            if t.kind == "op" and t.value in ("=", "!=", "<>", "<", "<=", ">", ">="):
                self.next()
                op = "!=" if t.value == "<>" else t.value
                right = self._additive()
                left = A.BinOp(op, left, right)
                continue
            break
        return left

    def _additive(self) -> A.Expr:
        left = self._multiplicative()
        while True:
            t = self.peek()
            if t.kind == "op" and t.value in ("+", "-", "||"):
                self.next()
                left = A.BinOp(t.value, left, self._multiplicative())
            else:
                return left

    def _multiplicative(self) -> A.Expr:
        left = self._unary()
        while True:
            t = self.peek()
            if t.kind == "op" and t.value in ("*", "/", "%"):
                self.next()
                left = A.BinOp(t.value, left, self._unary())
            else:
                return left

    def _unary(self) -> A.Expr:
        if self.accept_op("-"):
            return A.UnOp("-", self._unary())
        self.accept_op("+")
        return self._primary()

    def _primary(self) -> A.Expr:
        t = self.peek()
        if t.kind == "num":
            self.next()
            return A.Lit(float(t.value) if "." in t.value else int(t.value))
        if t.kind == "str":
            self.next()
            return A.Lit(t.value)
        if self.at_kw("true"):
            self.next()
            return A.Lit(True)
        if self.at_kw("false"):
            self.next()
            return A.Lit(False)
        if self.at_kw("null"):
            self.next()
            return A.Lit(None)
        if self.at_kw("case"):
            return self._case()
        if self.at_kw("cast"):
            self.next()
            self.expect_op("(")
            e = self._expr()
            self.expect_kw("as")
            ty = self._type_name()
            self.expect_op(")")
            return A.Cast(e, ty)
        if t.kind == "op" and t.value == "(":
            self.next()
            if self.at_kw("select"):
                q = self._select_with_setops()
                self.expect_op(")")
                return A.SubqueryExpr(q, "scalar")
            e = self._expr()
            self.expect_op(")")
            return e
        if t.kind == "op" and t.value == "*":
            self.next()
            return A.Star()
        if t.kind == "op" and t.value == "?":
            self.next()
            p = A.Param(self._next_param)
            self._next_param += 1
            return p
        # identifier: column, qualified column, star, or function call
        name = self.ident()
        if self.accept_op("("):
            distinct = self.accept_kw("distinct")
            args: List[A.Expr] = []
            if not self.accept_op(")"):
                if self.accept_op("*"):
                    args = [A.Star()]
                else:
                    while True:
                        args.append(self._expr())
                        if not self.accept_op(","):
                            break
                self.expect_op(")")
            func = A.Func(name.lower(), tuple(args), distinct)
            if self.accept_kw("over"):
                self.expect_op("(")
                pby: List[A.Expr] = []
                oby: List[Tuple[A.Expr, bool]] = []
                if self.accept_kw("partition"):
                    self.expect_kw("by")
                    while True:
                        pby.append(self._expr())
                        if not self.accept_op(","):
                            break
                if self.accept_kw("order"):
                    self.expect_kw("by")
                    oby = self._order_list()
                self.expect_op(")")
                return A.WindowFunc(func, tuple(pby), tuple(oby))
            return func
        if self.accept_op("."):
            if self.accept_op("*"):
                return A.Star(table=name)
            return A.Col(self.ident(), table=name)
        return A.Col(name)

    def _case(self) -> A.Expr:
        self.expect_kw("case")
        whens = []
        operand = None
        if not self.at_kw("when"):
            operand = self._expr()
        while self.accept_kw("when"):
            cond = self._expr()
            if operand is not None:
                cond = A.BinOp("=", operand, cond)
            self.expect_kw("then")
            whens.append((cond, self._expr()))
        otherwise = self._expr() if self.accept_kw("else") else None
        self.expect_kw("end")
        return A.Case(tuple(whens), otherwise)


def parse(sql: str) -> A.Statement:
    return Parser(sql).parse()


def parse_many(sql: str) -> List[A.Statement]:
    """Split on top-level semicolons and parse each statement."""
    stmts, depth, start, in_str = [], 0, 0, False
    i = 0
    while i < len(sql):
        ch = sql[i]
        if in_str:
            if ch == "'":
                in_str = False
        elif ch == "'":
            in_str = True
        elif ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        elif ch == ";" and depth == 0:
            text = sql[start:i].strip()
            if text:
                stmts.append(parse(text))
            start = i + 1
        i += 1
    tail = sql[start:].strip()
    if tail:
        stmts.append(parse(tail))
    return stmts
