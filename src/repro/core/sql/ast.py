"""SQL abstract syntax tree (paper §3.1).

Expression nodes double as *bound* expression nodes in logical plans: after
binding, every ``Col`` carries a fully qualified name (``alias.column``) that
uniquely identifies a column in its input batch.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple, Union

# ---------------------------------------------------------------------------
# Expressions
# ---------------------------------------------------------------------------


class Expr:
    def children(self) -> Sequence["Expr"]:
        out = []
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, Expr):
                out.append(v)
            elif isinstance(v, (list, tuple)):
                for x in v:
                    if isinstance(x, Expr):
                        out.append(x)
                    elif isinstance(x, tuple):  # Case.whens / WindowFunc.order_by
                        out.extend(y for y in x if isinstance(y, Expr))
        return out

    def key(self) -> str:
        """Canonical string form — used for cache keys, CSE, MV matching."""
        raise NotImplementedError


@dataclass(frozen=True)
class Col(Expr):
    name: str
    table: Optional[str] = None  # alias qualifier; filled by the binder

    @property
    def qualified(self) -> str:
        return f"{self.table}.{self.name}" if self.table else self.name

    def key(self) -> str:
        return self.qualified


@dataclass(frozen=True)
class Lit(Expr):
    value: object

    def key(self) -> str:
        return repr(self.value)


@dataclass(frozen=True)
class BinOp(Expr):
    op: str  # + - * / % = != < <= > >= AND OR LIKE
    left: Expr
    right: Expr

    def key(self) -> str:
        l, r = self.left.key(), self.right.key()
        if self.op in ("+", "*", "=", "!=", "AND", "OR") and r < l:
            l, r = r, l  # commutative normalization
        return f"({l} {self.op} {r})"


@dataclass(frozen=True)
class UnOp(Expr):
    op: str  # NOT, -
    operand: Expr

    def key(self) -> str:
        return f"({self.op} {self.operand.key()})"


@dataclass(frozen=True)
class Func(Expr):
    name: str  # scalar or aggregate function name, lowercase
    args: Tuple[Expr, ...] = ()
    distinct: bool = False

    def key(self) -> str:
        d = "DISTINCT " if self.distinct else ""
        return f"{self.name}({d}{', '.join(a.key() for a in self.args)})"


@dataclass(frozen=True)
class Case(Expr):
    whens: Tuple[Tuple[Expr, Expr], ...]
    otherwise: Optional[Expr] = None

    def key(self) -> str:
        ws = " ".join(f"WHEN {c.key()} THEN {v.key()}" for c, v in self.whens)
        e = f" ELSE {self.otherwise.key()}" if self.otherwise else ""
        return f"CASE {ws}{e} END"


@dataclass(frozen=True)
class InList(Expr):
    expr: Expr
    values: Tuple[Expr, ...]
    negated: bool = False

    def key(self) -> str:
        n = "NOT " if self.negated else ""
        return f"({self.expr.key()} {n}IN ({', '.join(v.key() for v in self.values)}))"


@dataclass(frozen=True)
class Between(Expr):
    expr: Expr
    low: Expr
    high: Expr
    negated: bool = False

    def key(self) -> str:
        n = "NOT " if self.negated else ""
        return f"({self.expr.key()} {n}BETWEEN {self.low.key()} AND {self.high.key()})"


@dataclass(frozen=True)
class IsNull(Expr):
    expr: Expr
    negated: bool = False

    def key(self) -> str:
        n = "NOT " if self.negated else ""
        return f"({self.expr.key()} IS {n}NULL)"


@dataclass(frozen=True)
class Cast(Expr):
    expr: Expr
    to_type: str

    def key(self) -> str:
        return f"CAST({self.expr.key()} AS {self.to_type})"


@dataclass(frozen=True)
class Param(Expr):
    """``?`` placeholder (DB-API qmark style), bound at execution time.

    ``key()`` is the ordinal, not the value, so a prepared statement's plan
    is parameter-independent and can be cached across executions.
    """

    index: int  # 0-based ordinal of the placeholder in the statement

    def key(self) -> str:
        return f"?{self.index}"


@dataclass(frozen=True)
class Star(Expr):
    table: Optional[str] = None

    def key(self) -> str:
        return f"{self.table or ''}.*"


@dataclass(frozen=True)
class SubqueryExpr(Expr):
    """IN / EXISTS / scalar subquery; decorrelated by the optimizer (§3.1)."""

    query: "Select"
    kind: str  # 'scalar' | 'in' | 'exists'
    expr: Optional[Expr] = None  # the LHS for IN
    negated: bool = False

    def key(self) -> str:
        return f"({self.kind} {id(self.query)})"


@dataclass(frozen=True)
class WindowFunc(Expr):
    """OLAP window function (paper §3.1 'advanced OLAP operations')."""

    func: Func
    partition_by: Tuple[Expr, ...] = ()
    order_by: Tuple[Tuple[Expr, bool], ...] = ()  # (expr, descending)

    def key(self) -> str:
        p = ", ".join(e.key() for e in self.partition_by)
        o = ", ".join(f"{e.key()} {'DESC' if d else 'ASC'}" for e, d in self.order_by)
        return f"{self.func.key()} OVER (PARTITION BY {p} ORDER BY {o})"


AGG_FUNCS = {"sum", "count", "min", "max", "avg"}
WINDOW_ONLY_FUNCS = {"row_number", "rank", "dense_rank", "lag", "lead"}
NON_DETERMINISTIC_FUNCS = {"rand", "random", "uuid"}
RUNTIME_CONSTANT_FUNCS = {"current_date", "current_timestamp", "now"}


def walk(expr: Expr):
    yield expr
    for c in expr.children():
        yield from walk(c)


def contains_aggregate(expr: Expr) -> bool:
    return any(
        isinstance(e, Func) and e.name in AGG_FUNCS and not isinstance(e, WindowFunc)
        for e in walk(expr)
    )


# ---------------------------------------------------------------------------
# Relations / statements
# ---------------------------------------------------------------------------


@dataclass
class TableRef:
    name: str
    alias: Optional[str] = None
    catalog: Optional[str] = None  # federated catalog qualifier (paper §6)
    schema: Optional[str] = None   # schema within the catalog


@dataclass
class SubqueryRef:
    query: "Select"
    alias: str


@dataclass
class JoinRef:
    left: object  # TableRef | SubqueryRef | JoinRef
    right: object
    kind: str  # inner | left | right | full | cross
    condition: Optional[Expr] = None


@dataclass
class Select:
    projections: List[Tuple[Expr, Optional[str]]]  # (expr, alias)
    from_: object = None  # TableRef | SubqueryRef | JoinRef | None
    where: Optional[Expr] = None
    group_by: List[Expr] = field(default_factory=list)
    grouping_sets: Optional[List[List[Expr]]] = None
    having: Optional[Expr] = None
    order_by: List[Tuple[Expr, bool]] = field(default_factory=list)
    limit: Optional[int] = None
    distinct: bool = False


@dataclass
class SetOp:
    kind: str  # union | intersect | except
    all: bool
    left: object  # Select | SetOp
    right: object
    order_by: List[Tuple[Expr, bool]] = field(default_factory=list)
    limit: Optional[int] = None


@dataclass
class Values:
    rows: List[List[Expr]]


@dataclass
class Insert:
    table: str
    columns: Optional[List[str]]
    source: object  # Select | Values
    # Hive multi-insert: several (table, columns) targets share one source.
    extra_targets: List[Tuple[str, Optional[List[str]]]] = field(default_factory=list)


@dataclass
class Update:
    table: str
    assignments: List[Tuple[str, Expr]]
    where: Optional[Expr] = None


@dataclass
class Delete:
    table: str
    where: Optional[Expr] = None


@dataclass
class MergeAction:
    kind: str  # update | delete | insert
    assignments: List[Tuple[str, Expr]] = field(default_factory=list)
    columns: Optional[List[str]] = None
    values: List[Expr] = field(default_factory=list)
    condition: Optional[Expr] = None


@dataclass
class Merge:
    target: TableRef
    source: object  # TableRef | SubqueryRef
    on: Expr
    matched: List[MergeAction] = field(default_factory=list)
    not_matched: List[MergeAction] = field(default_factory=list)


@dataclass
class ColumnDef:
    name: str
    type: str
    constraints: List[str] = field(default_factory=list)  # PRIMARY KEY / NOT NULL / UNIQUE


@dataclass
class CreateTable:
    name: str
    columns: List[ColumnDef]
    partition_by: List[ColumnDef] = field(default_factory=list)
    props: dict = field(default_factory=dict)
    stored_by: Optional[str] = None  # storage-handler class (§6.1)
    external: bool = False
    foreign_keys: List[Tuple[str, str, str]] = field(default_factory=list)


@dataclass
class CreateMaterializedView:
    name: str
    query: Select
    props: dict = field(default_factory=dict)
    stored_by: Optional[str] = None


@dataclass
class DropTable:
    name: str
    if_exists: bool = False


# federated catalogs (paper §6): mount a whole external system at once
@dataclass
class CreateCatalog:
    name: str
    connector: str  # registered connector name (jdbc | druid | memtable | ...)
    props: dict = field(default_factory=dict)


@dataclass
class DropCatalog:
    name: str
    if_exists: bool = False


@dataclass
class RebuildMaterializedView:
    name: str


@dataclass
class Explain:
    stmt: object
    analyze: bool = False


# workload management DDL (paper §5.2)
@dataclass
class CreateResourcePlan:
    name: str


@dataclass
class CreatePool:
    plan: str
    pool: str
    alloc_fraction: float
    query_parallelism: int


@dataclass
class CreateWMRule:
    plan: str
    rule: str
    metric: str
    threshold: float
    action: str  # MOVE <pool> | KILL
    target_pool: Optional[str] = None


@dataclass
class AddWMRuleToPool:
    plan: str
    rule: str
    pool: str


@dataclass
class CreateWMMapping:
    plan: str
    kind: str  # application | user | group
    entity: str
    pool: str


@dataclass
class AlterResourcePlan:
    plan: str
    default_pool: Optional[str] = None
    enable_activate: bool = False


Statement = Union[
    Select, SetOp, Insert, Update, Delete, Merge, CreateTable,
    CreateMaterializedView, DropTable, RebuildMaterializedView, Explain,
    CreateResourcePlan, CreatePool, CreateWMRule, AddWMRuleToPool,
    CreateWMMapping, AlterResourcePlan, CreateCatalog, DropCatalog,
]


# ---------------------------------------------------------------------------
# parameter binding helpers (DB-API qmark placeholders)
# ---------------------------------------------------------------------------
def _walk_any(obj):
    """Yield every Expr reachable from an AST node / statement dataclass."""
    if isinstance(obj, Expr):
        for e in walk(obj):
            yield e
            if isinstance(e, SubqueryExpr):
                yield from _walk_any(e.query)
        return
    if dataclasses.is_dataclass(obj):
        for f in dataclasses.fields(obj):
            yield from _walk_any(getattr(obj, f.name))
        return
    if isinstance(obj, (list, tuple)):
        for x in obj:
            yield from _walk_any(x)


def count_params(stmt) -> int:
    """Number of distinct ``?`` placeholders in a statement."""
    return len({e.index for e in _walk_any(stmt) if isinstance(e, Param)})


def substitute_params(obj, params: Sequence[object]):
    """Return a copy of the statement with every ``Param`` replaced by a
    ``Lit`` of the corresponding value (used by the DML execution path)."""
    if isinstance(obj, Param):
        if obj.index >= len(params):
            raise ValueError(
                f"statement references parameter ?{obj.index} but only "
                f"{len(params)} parameter(s) were supplied"
            )
        return Lit(params[obj.index])
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return type(obj)(**{
            f.name: substitute_params(getattr(obj, f.name), params)
            for f in dataclasses.fields(obj)
        })
    if isinstance(obj, list):
        return [substitute_params(x, params) for x in obj]
    if isinstance(obj, tuple):
        return tuple(substitute_params(x, params) for x in obj)
    if isinstance(obj, dict):
        return {k: substitute_params(v, params) for k, v in obj.items()}
    return obj
