"""Semantic analysis: AST -> bound logical plan (paper §2, Fig. 2 "logical plan").

Responsibilities:
  * name resolution against the metastore catalog (incl. scope chains for
    correlated subqueries),
  * subquery unnesting: IN / EXISTS / scalar subqueries — correlated or not —
    become semi/anti/left joins (Calcite's subquery-remove rules; paper §3.1
    counts correlated subqueries among the SQL features added to Hive),
  * aggregate extraction (incl. AVG -> SUM/COUNT decomposition, which also
    enables materialized-view rewrites over AVG),
  * window functions, grouping sets, set operations, DISTINCT.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from ..metastore import Metastore
from ..optimizer import plan as P
from . import ast as A


class BindError(Exception):
    pass


class Scope:
    def __init__(self, tables: Dict[str, List[str]], parent: Optional["Scope"] = None):
        # alias -> list of raw column names
        self.tables = tables
        self.parent = parent
        self.correlated_uses: List[str] = []  # qualified outer columns we touched

    def resolve(self, col: A.Col) -> Tuple[str, int]:
        """Return (qualified_name, level); level 0 = local, 1+ = outer."""
        if col.table is not None:
            level = 0
            scope = self
            while scope is not None:
                if col.table in scope.tables:
                    if col.name in scope.tables[col.table]:
                        return f"{col.table}.{col.name}", level
                    raise BindError(f"column {col.name} not in {col.table}")
                scope, level = scope.parent, level + 1
            raise BindError(f"unknown table alias {col.table}")
        level = 0
        scope = self
        while scope is not None:
            hits = [t for t, cols in scope.tables.items() if col.name in cols]
            if len(hits) > 1:
                raise BindError(f"ambiguous column {col.name} ({hits})")
            if hits:
                return f"{hits[0]}.{col.name}", level
            scope, level = scope.parent, level + 1
        raise BindError(f"unknown column {col.name}")

    def all_columns(self, alias: Optional[str] = None) -> List[str]:
        out = []
        for t, cols in self.tables.items():
            if alias is None or t == alias:
                out.extend(f"{t}.{c}" for c in cols)
        return out


def split_conjuncts(e: Optional[A.Expr]) -> List[A.Expr]:
    if e is None:
        return []
    if isinstance(e, A.BinOp) and e.op == "AND":
        return split_conjuncts(e.left) + split_conjuncts(e.right)
    return [e]


def conjoin(es: Sequence[A.Expr]) -> Optional[A.Expr]:
    es = list(es)
    if not es:
        return None
    out = es[0]
    for e in es[1:]:
        out = A.BinOp("AND", out, e)
    return out


class Binder:
    def __init__(self, hms: Metastore, catalogs=None):
        self.hms = hms
        self.catalogs = catalogs  # CatalogRegistry (three-part names, §6)
        self._counter = itertools.count()

    def _fresh(self, prefix: str) -> str:
        return f"{prefix}_{next(self._counter)}"

    # ======================================================================
    # statements
    # ======================================================================
    def bind(self, stmt) -> P.PlanNode:
        if isinstance(stmt, A.Select):
            plan, _ = self.bind_select(stmt, None)
        elif isinstance(stmt, A.SetOp):
            plan, _ = self.bind_setop(stmt, None)
        else:
            raise BindError(f"cannot bind {type(stmt).__name__} as a query")
        # attach the typed schema contract (tolerant: EXPLAIN/compile
        # re-annotate after rewrites; the strict check is schema_check)
        from ..schema import annotate_plan

        annotate_plan(plan)
        return plan

    def bind_setop(self, s: A.SetOp, outer: Optional[Scope]):
        lplan, lnames = self._bind_query(s.left, outer)
        rplan, rnames = self._bind_query(s.right, outer)
        if len(lnames) != len(rnames):
            raise BindError("set operands have different arity")
        # align right column names to left
        if lplan.output_names() != rplan.output_names():
            rplan = P.Project(
                rplan,
                [(A.Col(_base(rn), _qual(rn)), ln)
                 for rn, ln in zip(rplan.output_names(), lplan.output_names())],
            )
        if s.kind == "union":
            plan = P.Union([lplan, rplan], all=s.all)
            if not s.all:
                plan = self._distinct(plan)
        elif s.kind == "intersect":
            plan = P.Join(
                self._distinct(lplan), self._distinct(rplan), "semi",
                lplan.output_names(), lplan.output_names(),
            )
        elif s.kind == "except":
            plan = P.Join(
                self._distinct(lplan), self._distinct(rplan), "anti",
                lplan.output_names(), lplan.output_names(),
            )
        else:
            raise BindError(f"unknown set op {s.kind}")
        if s.order_by:
            keys = []
            for e, desc in s.order_by:
                if isinstance(e, A.Lit) and isinstance(e.value, int):
                    keys.append((plan.output_names()[e.value - 1], desc))
                else:
                    raise BindError("set-op ORDER BY supports positional keys")
            plan = P.Sort(plan, keys)
        if s.limit is not None:
            plan = P.Limit(plan, s.limit)
        return plan, plan.output_names()

    def _bind_query(self, q, outer):
        if isinstance(q, A.Select):
            return self.bind_select(q, outer)
        return self.bind_setop(q, outer)

    def _distinct(self, plan: P.PlanNode) -> P.PlanNode:
        return P.Aggregate(plan, plan.output_names(), [])

    # ======================================================================
    # SELECT
    # ======================================================================
    def bind_select(self, sel: A.Select, outer: Optional[Scope]):
        if sel.from_ is None:
            # SELECT <consts>
            names, row = [], []
            for i, (e, alias) in enumerate(sel.projections):
                names.append(alias or f"_c{i}")
                row.append(e)
            return P.ValuesNode(names, [row]), names

        plan, scope = self._bind_from(sel.from_, outer)

        # ---- WHERE (with subquery unnesting) --------------------------------
        if sel.where is not None:
            plan, residual = self._apply_predicate(plan, scope, sel.where)
            if residual is not None:
                plan = P.Filter(plan, residual)

        # ---- star expansion --------------------------------------------------
        projections: List[Tuple[A.Expr, Optional[str]]] = []
        for e, alias in sel.projections:
            if isinstance(e, A.Star):
                for q in scope.all_columns(e.table):
                    projections.append((A.Col(_base(q), _qual(q)), _base(q)))
            else:
                projections.append((e, alias))

        # bind all output expressions (also unnests scalar subqueries in them)
        bound_projs: List[Tuple[A.Expr, str]] = []
        for i, (e, alias) in enumerate(projections):
            plan, be = self._bind_expr_unnesting(plan, scope, e)
            bound_projs.append((be, alias or _derive_name(be, i)))

        having = None
        if sel.having is not None:
            plan, having = self._bind_expr_unnesting(plan, scope, sel.having)

        order_bound: List[Tuple[A.Expr, bool]] = []
        for e, desc in sel.order_by:
            if isinstance(e, A.Lit) and isinstance(e.value, int):
                order_bound.append((bound_projs[e.value - 1][0], desc))
            else:
                # ORDER BY may reference projection aliases (§7.1: "order by
                # unselected columns" is also allowed -> falls through to expr)
                matched = None
                if isinstance(e, A.Col) and e.table is None:
                    for be, name in bound_projs:
                        if name == e.name:
                            matched = be
                            break
                if matched is None:
                    plan, matched = self._bind_expr_unnesting(plan, scope, e)
                order_bound.append((matched, desc))

        group_bound: List[A.Expr] = []
        for e in sel.group_by:
            if isinstance(e, A.Lit) and isinstance(e.value, int):
                group_bound.append(bound_projs[e.value - 1][0])
            else:
                plan, be = self._bind_expr_unnesting(plan, scope, e)
                group_bound.append(be)

        # ---- aggregation ------------------------------------------------------
        need_agg = bool(group_bound) or any(
            A.contains_aggregate(be) for be, _ in bound_projs
        ) or (having is not None and A.contains_aggregate(having))

        if need_agg:
            plan, rewrite = self._build_aggregate(
                plan, group_bound, bound_projs, having, order_bound,
                sel.grouping_sets, scope,
            )
            bound_projs = [(rewrite(be), n) for be, n in bound_projs]
            having = rewrite(having) if having is not None else None
            order_bound = [(rewrite(be), d) for be, d in order_bound]

        if having is not None:
            plan = P.Filter(plan, having)

        # ---- window functions -------------------------------------------------
        win_map: Dict[str, str] = {}
        win_funcs: List[Tuple[A.WindowFunc, str]] = []
        for be, _ in bound_projs + [(e, None) for e, _ in order_bound]:
            for node in A.walk(be):
                if isinstance(node, A.WindowFunc) and node.key() not in win_map:
                    name = self._fresh("w")
                    win_map[node.key()] = name
                    win_funcs.append((node, name))
        if win_funcs:
            plan = P.WindowOp(plan, win_funcs)
            repl = lambda e: _replace_by_key(e, win_map)
            bound_projs = [(repl(be), n) for be, n in bound_projs]
            order_bound = [(repl(be), d) for be, d in order_bound]

        # ---- final projection / distinct / order / limit -----------------------
        out_names = _uniquify([n for _, n in bound_projs])
        proj_exprs = [(be, n) for (be, _), n in zip(bound_projs, out_names)]

        # sort keys that aren't plain output columns ride along as hidden cols
        sort_keys: List[Tuple[str, bool]] = []
        hidden: List[Tuple[A.Expr, str]] = []
        for be, desc in order_bound:
            name = None
            for e2, n2 in proj_exprs:
                if e2.key() == be.key():
                    name = n2
                    break
            if name is None:
                name = self._fresh("sk")
                hidden.append((be, name))
            sort_keys.append((name, desc))

        plan = P.Project(plan, proj_exprs + hidden)
        if sel.distinct:
            if hidden:
                raise BindError("DISTINCT with non-projected ORDER BY keys")
            plan = self._distinct(plan)
        if sort_keys:
            plan = P.Sort(plan, sort_keys)
        if sel.limit is not None:
            plan = P.Limit(plan, sel.limit)
        if hidden:
            plan = P.Project(
                plan, [(A.Col(_base(n), _qual(n)) if "." in n else A.Col(n), n)
                       for n in out_names]
            )
        return plan, out_names

    # ======================================================================
    # FROM clause
    # ======================================================================
    def _bind_from(self, node, outer: Optional[Scope]):
        if isinstance(node, A.TableRef):
            if node.catalog is not None:
                # catalog.schema.table: resolve through the mounted catalog's
                # connector with lazy remote-schema discovery (paper §6)
                if self.catalogs is None:
                    raise BindError(
                        f"no catalog registry to resolve "
                        f"{node.catalog}.{node.name}"
                    )
                cat = self.catalogs.get(node.catalog)
                if cat is None:
                    raise BindError(f"unknown catalog {node.catalog!r}")
                try:
                    desc = cat.table_desc(node.schema, node.name)
                except KeyError as exc:
                    raise BindError(str(exc)) from exc
                alias = node.alias or node.name
                cols = [c for c, _ in desc.schema]
                return (P.FederatedScan(desc, alias, cols),
                        Scope({alias: cols}, outer))
            desc = self.hms.get_table(node.name)
            alias = node.alias or node.name
            if desc.is_mv and desc.mv_sql is None:
                raise BindError(f"materialized view {node.name} has no definition")
            cols = [c for c, _ in desc.schema]
            scan: P.PlanNode
            if desc.handler:
                scan = P.FederatedScan(desc, alias, cols)
            else:
                scan = P.Scan(desc, alias, cols)
            return scan, Scope({alias: cols}, outer)
        if isinstance(node, A.SubqueryRef):
            subplan, names = self._bind_query(node.query, outer)
            base_names = [_base(n) for n in names]
            proj = P.Project(
                subplan,
                [(A.Col(_base(n), _qual(n)) if "." in n else A.Col(n),
                  f"{node.alias}.{b}") for n, b in zip(names, base_names)],
            )
            return proj, Scope({node.alias: base_names}, outer)
        if isinstance(node, A.JoinRef):
            lplan, lscope = self._bind_from(node.left, outer)
            rplan, rscope = self._bind_from(node.right, outer)
            merged = Scope({**lscope.tables, **rscope.tables}, outer)
            if node.condition is None:
                return (
                    P.Join(lplan, rplan, "cross" if node.kind == "cross" else "inner",
                           [], []),
                    merged,
                )
            cond = self._bind_expr(node.condition, merged)
            lnames, rnames = set(lplan.output_names()), set(rplan.output_names())
            keys_l, keys_r, residual = _classify_join_condition(cond, lnames, rnames)
            kind = node.kind
            if kind == "right":  # normalize RIGHT to LEFT by swapping inputs
                lplan, rplan = rplan, lplan
                keys_l, keys_r = keys_r, keys_l
                kind = "left"
            return P.Join(lplan, rplan, kind, keys_l, keys_r, residual), merged
        raise BindError(f"unsupported FROM element {type(node).__name__}")

    # ======================================================================
    # predicates & subquery unnesting
    # ======================================================================
    def _apply_predicate(self, plan, scope, where):
        conjuncts = split_conjuncts(where)
        plain: List[A.Expr] = []
        for c in conjuncts:
            sub = _find_subquery(c)
            if sub is None:
                plain.append(self._bind_expr(c, scope))
            else:
                plan = self._unnest_predicate_subquery(plan, scope, c, sub)
        return plan, conjoin(plain)

    def _unnest_predicate_subquery(self, plan, scope, conjunct, sub: A.SubqueryExpr):
        subscope_parent = scope
        subplan, subnames = self._bind_query(sub.query, subscope_parent)
        # correlation: equality conjuncts referencing outer columns were bound
        # inside subplan Filters; extract them into join keys.
        subplan, corr_pairs = _extract_correlation(subplan, scope)

        if sub.kind in ("in", "exists"):
            lkeys, rkeys = [c[0] for c in corr_pairs], [c[1] for c in corr_pairs]
            if sub.kind == "in":
                lhs = self._bind_expr(sub.expr, scope)
                if not isinstance(lhs, A.Col):
                    raise BindError("IN subquery LHS must be a column")
                lkeys = [lhs.qualified] + lkeys
                rkeys = [subnames[0]] + rkeys
            kind = "anti" if sub.negated else "semi"
            if conjunct is not sub and not (
                isinstance(conjunct, A.SubqueryExpr)
                or (isinstance(conjunct, A.UnOp) and conjunct.op == "NOT")
            ):
                raise BindError("subquery must be a top-level conjunct")
            if isinstance(conjunct, A.UnOp) and conjunct.op == "NOT":
                kind = "semi" if kind == "anti" else "anti"
            build = self._distinct(P.Project(
                subplan,
                [(A.Col(_base(n), _qual(n)), n) for n in rkeys],
            )) if rkeys else subplan
            return P.Join(plan, build, kind, lkeys, rkeys)

        if sub.kind == "scalar":
            # comparison against a (possibly correlated) scalar subquery
            return self._join_scalar_subquery(
                plan, scope, conjunct, sub, subplan, subnames, corr_pairs,
                as_filter=True,
            )
        raise BindError(f"unsupported subquery kind {sub.kind}")

    def _join_scalar_subquery(self, plan, scope, expr, sub, subplan, subnames,
                              corr_pairs, as_filter: bool):
        val_col = subnames[0]
        out_name = self._fresh("sq")
        if corr_pairs:
            keys_inner = [p[1] for p in corr_pairs]
            gk = keys_inner
            sub_agg = P.Project(
                subplan,
                [(A.Col(_base(n), _qual(n)), n) for n in gk + [val_col]],
            )
            # subquery must be scalar per group; binder trusts aggregate shape
            joined = P.Join(plan, sub_agg, "left",
                            [p[0] for p in corr_pairs], keys_inner)
        else:
            joined = P.Join(plan, subplan, "cross", [], [])
        rename = P.Project(
            joined,
            [(A.Col(_base(n), _qual(n)) if "." in n else A.Col(n), n)
             for n in plan.output_names()]
            + [(A.Col(_base(val_col), _qual(val_col)) if "." in val_col
                else A.Col(val_col), out_name)],
        )
        if as_filter:
            pred = _replace_subquery(expr, sub, A.Col(out_name))
            pred = self._bind_expr(pred, _scope_of(rename))
            return P.Filter(rename, pred)
        return rename, A.Col(out_name)

    def _bind_expr_unnesting(self, plan, scope, e):
        sub = _find_subquery(e)
        if sub is None:
            return plan, self._bind_expr(e, scope)
        if sub.kind != "scalar":
            raise BindError("only scalar subqueries allowed in this context")
        subplan, subnames = self._bind_query(sub.query, scope)
        subplan, corr = _extract_correlation(subplan, scope)
        plan2, ref = self._join_scalar_subquery(
            plan, scope, e, sub, subplan, subnames, corr, as_filter=False
        )
        new_e = _replace_subquery(e, sub, ref)
        # rebind remaining structure (ref resolves via plan outputs)
        return plan2, self._bind_expr_loose(new_e, plan2, scope)

    def _bind_expr_loose(self, e, plan, scope):
        """Bind against scope but let already-qualified synthetic cols pass."""
        outputs = set(plan.output_names())

        def rec(x):
            if isinstance(x, A.Col):
                if x.qualified in outputs or (x.table is None and x.name in outputs):
                    return A.Col(x.name, x.table)
                q, _ = scope.resolve(x)
                return A.Col(_base(q), _qual(q))
            return _rebuild(x, [rec(c) for c in x.children()])

        return rec(e)

    # ---- plain expression binding -------------------------------------------
    def _bind_expr(self, e: A.Expr, scope: Scope) -> A.Expr:
        if isinstance(e, A.Col):
            q, level = scope.resolve(e)
            if level > 0:
                scope.correlated_uses.append(q)
                return A.Col(_base(q), _qual(q))  # outer ref, same shape
            return A.Col(_base(q), _qual(q))
        if isinstance(e, A.SubqueryExpr):
            return e  # handled by unnesting paths
        if isinstance(e, A.Param):
            return e  # bound at execution time from ExecContext.params
        return _rebuild(e, [self._bind_expr(c, scope) for c in e.children()])

    # ======================================================================
    # aggregation builder
    # ======================================================================
    def _build_aggregate(self, plan, group_bound, bound_projs, having,
                         order_bound, grouping_sets, scope):
        # collect aggregate calls from every post-agg expression
        agg_calls: Dict[str, A.Func] = {}

        def collect(e):
            if e is None:
                return
            for node in A.walk(e):
                if isinstance(node, A.WindowFunc):
                    continue
                if isinstance(node, A.Func) and node.name in A.AGG_FUNCS:
                    agg_calls.setdefault(node.key(), node)

        for be, _ in bound_projs:
            collect(be)
        collect(having)
        for be, _ in order_bound:
            collect(be)

        # AVG -> SUM/COUNT so rollups & MV rewrites stay additive
        decomposed: Dict[str, A.Expr] = {}
        final_calls: Dict[str, A.Func] = {}
        for k, f in agg_calls.items():
            if f.name == "avg":
                s = A.Func("sum", f.args, f.distinct)
                c = A.Func("count", f.args, f.distinct)
                decomposed[k] = A.BinOp("/", s, c)
                final_calls.setdefault(s.key(), s)
                final_calls.setdefault(c.key(), c)
            else:
                final_calls.setdefault(k, f)

        # pre-aggregation projection: group keys + aggregate arguments
        pre_exprs: List[Tuple[A.Expr, str]] = []
        group_names: List[str] = []
        group_map: Dict[str, str] = {}
        for g in group_bound:
            if isinstance(g, A.Col):
                name = g.qualified
            else:
                name = self._fresh("gk")
            group_map[g.key()] = name
            group_names.append(name)
            pre_exprs.append((g, name))

        specs: List[P.AggSpec] = []
        agg_out: Dict[str, str] = {}
        for k, f in final_calls.items():
            arg = None
            if f.args and not isinstance(f.args[0], A.Star):
                arg = f.args[0]
            out = self._fresh("agg")
            agg_out[k] = out
            if arg is not None:
                arg_name = arg.qualified if isinstance(arg, A.Col) else self._fresh("aa")
                if arg_name not in [n for _, n in pre_exprs]:
                    pre_exprs.append((arg, arg_name))
                specs.append(P.AggSpec(f.name, A.Col(_base(arg_name), _qual(arg_name)),
                                       f.distinct, out))
            else:
                specs.append(P.AggSpec(f.name, None, f.distinct, out))

        pre = P.Project(plan, pre_exprs) if pre_exprs else plan
        gsets = None
        if grouping_sets is not None:
            gsets = []
            for s in grouping_sets:
                names = []
                for e in s:
                    be = self._bind_expr(e, scope)
                    names.append(group_map[be.key()])
                gsets.append(names)
        agg = P.Aggregate(pre, group_names, specs, gsets)

        replace_map = dict(group_map)

        def rewrite(e):
            if e is None:
                return None
            if e.key() in replace_map:
                n = replace_map[e.key()]
                return A.Col(_base(n), _qual(n))
            if isinstance(e, A.Func) and e.name in A.AGG_FUNCS:
                if e.key() in decomposed:
                    return rewrite(decomposed[e.key()])
                n = agg_out[e.key()]
                return A.Col(_base(n), _qual(n))
            if isinstance(e, A.WindowFunc):
                return A.WindowFunc(
                    rewrite(e.func), tuple(rewrite(x) for x in e.partition_by),
                    tuple((rewrite(x), d) for x, d in e.order_by),
                )
            return _rebuild(e, [rewrite(c) for c in e.children()])

        return agg, rewrite


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _base(qualified: str) -> str:
    return qualified.split(".", 1)[1] if "." in qualified else qualified


def _qual(qualified: str) -> Optional[str]:
    return qualified.split(".", 1)[0] if "." in qualified else None


def _uniquify(names: List[str]) -> List[str]:
    seen: Dict[str, int] = {}
    out = []
    for n in names:
        if n in seen:
            seen[n] += 1
            out.append(f"{n}_{seen[n]}")
        else:
            seen[n] = 0
            out.append(n)
    return out


def _derive_name(e: A.Expr, i: int) -> str:
    if isinstance(e, A.Col):
        return e.name
    if isinstance(e, A.Func):
        return f"{e.name}_{i}"
    return f"_c{i}"


def _rebuild(e: A.Expr, new_children: List[A.Expr]) -> A.Expr:
    """Reconstruct a frozen expr dataclass with replaced Expr children."""
    import dataclasses as dc

    it = iter(new_children)
    kwargs = {}
    for f in dc.fields(e):
        v = getattr(e, f.name)
        if isinstance(v, A.Expr):
            kwargs[f.name] = next(it)
        elif isinstance(v, tuple) and v and all(isinstance(x, A.Expr) for x in v):
            kwargs[f.name] = tuple(next(it) for _ in v)
        elif (
            isinstance(v, tuple) and v
            and all(isinstance(x, tuple) and len(x) == 2 for x in v)
            and all(isinstance(x[0], A.Expr) for x in v)
        ):
            if all(isinstance(x[1], A.Expr) for x in v):  # Case.whens
                kwargs[f.name] = tuple((next(it), next(it)) for _ in v)
            else:  # WindowFunc.order_by: (expr, bool)
                kwargs[f.name] = tuple((next(it), x[1]) for x in v)
        else:
            kwargs[f.name] = v
    return type(e)(**kwargs)


def _find_subquery(e: A.Expr) -> Optional[A.SubqueryExpr]:
    for node in A.walk(e):
        if isinstance(node, A.SubqueryExpr):
            return node
    return None


def _replace_subquery(e: A.Expr, target: A.SubqueryExpr, repl: A.Expr) -> A.Expr:
    if e is target:
        return repl
    if isinstance(e, A.SubqueryExpr):
        return e
    kids = [_replace_subquery(c, target, repl) for c in e.children()]
    return _rebuild(e, kids)


def _classify_join_condition(cond, lnames, rnames):
    keys_l, keys_r, residual = [], [], []
    for c in split_conjuncts(cond):
        if (
            isinstance(c, A.BinOp) and c.op == "="
            and isinstance(c.left, A.Col) and isinstance(c.right, A.Col)
        ):
            lq, rq = c.left.qualified, c.right.qualified
            if lq in lnames and rq in rnames:
                keys_l.append(lq)
                keys_r.append(rq)
                continue
            if rq in lnames and lq in rnames:
                keys_l.append(rq)
                keys_r.append(lq)
                continue
        residual.append(c)
    return keys_l, keys_r, conjoin(residual)


def _extract_correlation(subplan: P.PlanNode, outer_scope: Scope):
    """Pull equality conjuncts that reference outer columns out of the
    subquery plan's filters; return (new_plan, [(outer_q, inner_q), ...])."""
    outer_cols = set()
    scope = outer_scope
    while scope is not None:
        outer_cols.update(scope.all_columns())
        scope = scope.parent

    pairs: List[Tuple[str, str]] = []

    def visit(node: P.PlanNode) -> P.PlanNode:
        for i, child in enumerate(node.inputs):
            node.inputs[i] = visit(child)
        if isinstance(node, P.Filter):
            inner_names = set(node.input.output_names())
            keep = []
            for c in split_conjuncts(node.predicate):
                if (
                    isinstance(c, A.BinOp) and c.op == "="
                    and isinstance(c.left, A.Col) and isinstance(c.right, A.Col)
                ):
                    lq, rq = c.left.qualified, c.right.qualified
                    if lq in outer_cols and rq in inner_names and lq not in inner_names:
                        pairs.append((lq, rq))
                        continue
                    if rq in outer_cols and lq in inner_names and rq not in inner_names:
                        pairs.append((rq, lq))
                        continue
                keep.append(c)
            if not keep:
                return node.input
            node.predicate = conjoin(keep)
        return node

    newplan = visit(subplan)

    # Correlated aggregates: if the subquery aggregates globally but we pulled
    # correlation keys out, re-group by the inner correlation keys so the join
    # preserves per-outer-row semantics.
    if pairs:
        inner_keys = [p[1] for p in pairs]

        def fix_agg(node):
            for i, child in enumerate(node.inputs):
                node.inputs[i] = fix_agg(child)
            if isinstance(node, P.Aggregate) and not node.group_keys:
                avail = set(node.input.output_names())
                missing = [k for k in inner_keys if k not in avail]
                if missing and isinstance(node.input, P.Project):
                    src = node.input
                    src_avail = set(src.input.output_names())
                    if all(k in src_avail for k in missing):
                        src.exprs = src.exprs + [
                            (A.Col(_base(k), _qual(k)), k) for k in missing
                        ]
                        avail = set(src.output_names())
                if all(k in avail for k in inner_keys):
                    node.group_keys = list(inner_keys)
            if isinstance(node, P.Project):
                # ensure correlation keys survive the projection above the agg
                have = {n for _, n in node.exprs}
                child_names = set(node.input.output_names())
                for k in inner_keys:
                    if k not in have and k in child_names:
                        node.exprs = node.exprs + [(A.Col(_base(k), _qual(k)), k)]
            return node

        newplan = fix_agg(newplan)
    return newplan, pairs


def _replace_by_key(e: A.Expr, mapping: Dict[str, str]) -> A.Expr:
    if e is None:
        return None
    if e.key() in mapping:
        return A.Col(mapping[e.key()])
    return _rebuild(e, [_replace_by_key(c, mapping) for c in e.children()])


def _scope_of(plan: P.PlanNode) -> Scope:
    tables: Dict[str, List[str]] = {}
    loose = []
    for n in plan.output_names():
        if "." in n:
            t, c = n.split(".", 1)
            tables.setdefault(t, []).append(c)
        else:
            loose.append(n)
    if loose:
        tables[""] = loose

    class _LooseScope(Scope):
        def resolve(self, col: A.Col):
            try:
                return super().resolve(col)
            except BindError:
                if col.table is None and "" in self.tables and col.name in self.tables[""]:
                    return col.name, 0
                raise

    return _LooseScope(tables)
