"""ORC-like columnar stripe files (paper §2 "Data storage", §5.1 I/O elevator).

Each data file is a zip of column arrays organized in *stripes* (row groups)
plus a JSON footer with per-stripe, per-column min/max statistics and optional
bloom filters.  This gives the scan path the two structures the paper's I/O
elevator pushes down: sargable predicates (min/max seek) and bloom filters
(paper §4.6, §5.1).

Files are immutable once written (HDFS/object-store semantics).  Every file
carries a content-derived ``file_id`` which plays the role of the HDFS unique
file id / S3 ETag that LLAP uses for cache validity (paper §5.1).
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import zipfile
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .bloomfilter import BloomFilter
from .runtime.vector import VectorBatch

DEFAULT_STRIPE_ROWS = 8192
_META_KEY = "_tahoe_meta.json"


@dataclass
class StripeMeta:
    rows: int
    # col -> {"min": x, "max": x} (present when the column is orderable)
    ranges: Dict[str, dict] = field(default_factory=dict)
    blooms: Dict[str, dict] = field(default_factory=dict)  # col -> BloomFilter dict


@dataclass
class FileMeta:
    file_id: str
    num_rows: int
    columns: List[str]
    dtypes: Dict[str, str]
    stripes: List[StripeMeta]
    writeid: int = 0

    def to_json(self) -> str:
        return json.dumps(
            {
                "file_id": self.file_id,
                "num_rows": self.num_rows,
                "columns": self.columns,
                "dtypes": self.dtypes,
                "writeid": self.writeid,
                "stripes": [
                    {"rows": s.rows, "ranges": s.ranges, "blooms": s.blooms}
                    for s in self.stripes
                ],
            }
        )

    @classmethod
    def from_json(cls, s: str) -> "FileMeta":
        d = json.loads(s)
        return cls(
            file_id=d["file_id"],
            num_rows=d["num_rows"],
            columns=d["columns"],
            dtypes=d["dtypes"],
            writeid=d.get("writeid", 0),
            stripes=[
                StripeMeta(x["rows"], x.get("ranges", {}), x.get("blooms", {}))
                for x in d["stripes"]
            ],
        )


def _col_range(values: np.ndarray) -> Optional[dict]:
    if len(values) == 0:
        return None
    if values.dtype.kind in ("i", "u", "f"):
        if values.dtype.kind == "f":
            valid = values[~np.isnan(values)]
            if len(valid) == 0:
                return None
            return {"min": float(valid.min()), "max": float(valid.max())}
        return {"min": int(values.min()), "max": int(values.max())}
    if values.dtype.kind in ("U", "S"):
        s = np.sort(values)  # np.min lacks a unicode ufunc loop
        return {"min": str(s[0]), "max": str(s[-1])}
    return None


def write_stripe_file(
    path: str,
    batch: VectorBatch,
    *,
    writeid: int = 0,
    stripe_rows: int = DEFAULT_STRIPE_ROWS,
    bloom_columns: Sequence[str] = (),
) -> FileMeta:
    """Write a batch as an immutable stripe file; returns its metadata."""
    columns = batch.column_names
    n = batch.num_rows
    stripes: List[StripeMeta] = []
    hasher = hashlib.blake2b(digest_size=10)

    buf = io.BytesIO()
    with zipfile.ZipFile(buf, "w", compression=zipfile.ZIP_DEFLATED) as zf:
        for si, start in enumerate(range(0, max(n, 1), stripe_rows)):
            chunk = batch.slice(start, min(start + stripe_rows, n))
            if chunk.num_rows == 0 and n > 0:
                break
            meta = StripeMeta(rows=chunk.num_rows)
            for col in columns:
                values = chunk.cols[col]
                arr_buf = io.BytesIO()
                np.save(arr_buf, values, allow_pickle=False)
                payload = arr_buf.getvalue()
                hasher.update(payload)
                zf.writestr(f"s{si}/{col}.npy", payload)
                rng = _col_range(values)
                if rng is not None:
                    meta.ranges[col] = rng
                if col in bloom_columns and len(values):
                    bf = BloomFilter.for_expected(len(values))
                    bf.add(values)
                    meta.blooms[col] = bf.to_dict()
            stripes.append(meta)
            if n == 0:
                break
        fmeta = FileMeta(
            file_id=hasher.hexdigest(),
            num_rows=n,
            columns=columns,
            dtypes={c: str(batch.cols[c].dtype) for c in columns},
            stripes=stripes,
            writeid=writeid,
        )
        zf.writestr(_META_KEY, fmeta.to_json())

    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(buf.getvalue())
    os.replace(tmp, path)  # atomic publish, mimicking HDFS rename semantics
    return fmeta


def read_file_meta(path: str) -> FileMeta:
    """Footer-only read — this is what LLAP's bulk metadata cache loads."""
    with zipfile.ZipFile(path) as zf:
        return FileMeta.from_json(zf.read(_META_KEY).decode())


def read_stripe_column(path: str, stripe: int, column: str) -> np.ndarray:
    with zipfile.ZipFile(path) as zf:
        with zf.open(f"s{stripe}/{column}.npy") as f:
            return np.load(io.BytesIO(f.read()), allow_pickle=False)


# --------------------------------------------------------------------------
# Sargable predicates: (column, op, literal) triples the I/O elevator can use
# against stripe min/max ranges and bloom filters to skip row groups.
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class SargPredicate:
    column: str
    op: str  # one of <, <=, >, >=, =, in
    value: object


def stripe_may_match(meta: StripeMeta, preds: Sequence[SargPredicate]) -> bool:
    for p in preds:
        rng = meta.ranges.get(p.column)
        if rng is not None:
            lo, hi = rng["min"], rng["max"]
            if p.op == "=" and not (lo <= p.value <= hi):
                return False
            if p.op == "<" and not (lo < p.value):
                return False
            if p.op == "<=" and not (lo <= p.value):
                return False
            if p.op == ">" and not (hi > p.value):
                return False
            if p.op == ">=" and not (hi >= p.value):
                return False
            if p.op == "in" and not any(lo <= v <= hi for v in p.value):
                return False
        bloom_d = meta.blooms.get(p.column)
        if bloom_d is not None and p.op == "=":
            bf = BloomFilter.from_dict(bloom_d)
            if not bool(bf.might_contain(np.asarray([p.value]))[0]):
                return False
    return True
