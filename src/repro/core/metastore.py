"""Hive Metastore (HMS) analogue (paper §2 "Data catalog", §3.2, §5.2).

HMS is "a catalog for all data queryable by Hive", persisted in an RDBMS.  We
persist in sqlite3 (the stdlib RDBMS) — playing the role DataNucleus-managed
MySQL/Postgres plays for Hive — and expose a typed in-process API standing in
for the Thrift service.  Like HMS, this one component owns:

  * the table/partition catalog and column statistics (additive, HLL++ NDV),
  * the transaction manager state: TxnIds, per-table WriteIds, locks,
    write-sets for first-commit-wins conflict detection (paper §3.2),
  * the materialized-view registry incl. build-time snapshots (paper §4.4),
  * workload-management resource plans (paper §5.2),
  * a notification log consumed by storage-handler metastore hooks (paper §6.1).
"""
from __future__ import annotations

import dataclasses
import json
import os
import sqlite3
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

from ..analysis.lockdep import make_rlock
from .stats import ColumnStats, TableStats

# --------------------------------------------------------------------------
# Public dataclasses
# --------------------------------------------------------------------------


@dataclasses.dataclass
class TableDesc:
    name: str
    schema: List[Tuple[str, str]]  # (column, dtype-string)
    partition_cols: List[str]
    location: str
    props: Dict[str, str]
    handler: Optional[str] = None  # storage-handler name for federated tables
    is_mv: bool = False
    mv_sql: Optional[str] = None
    table_id: int = 0

    @property
    def column_names(self) -> List[str]:
        return [c for c, _ in self.schema]

    def dtype_of(self, col: str) -> str:
        for c, d in self.schema:
            if c == col:
                return d
        raise KeyError(col)


@dataclasses.dataclass(frozen=True)
class Snapshot:
    """Global transaction list: high watermark + open/aborted sets (§3.2)."""

    hwm: int
    open_txns: frozenset
    aborted_txns: frozenset

    def txn_visible(self, txn_id: int) -> bool:
        return (
            txn_id <= self.hwm
            and txn_id not in self.open_txns
            and txn_id not in self.aborted_txns
        )


@dataclasses.dataclass(frozen=True)
class WriteIdList:
    """Per-table projection of a Snapshot (§3.2).

    Readers keep per-table state that is much smaller than the global
    transaction list — the paper notes this is critical when many
    transactions are open.
    """

    table: str
    hwm: int  # highest writeid whose txn is at-or-below the snapshot hwm
    invalid: frozenset  # writeids from open or aborted txns

    def is_valid(self, writeid) -> bool:
        return writeid <= self.hwm and writeid not in self.invalid

    def valid_mask(self, writeids):
        import numpy as np

        mask = writeids <= self.hwm
        if self.invalid:
            mask &= ~np.isin(writeids, np.fromiter(self.invalid, dtype=writeids.dtype))
        return mask


class LockConflict(Exception):
    pass


class WriteConflict(Exception):
    """Raised at commit when first-commit-wins resolution loses (§3.2)."""


class TxnAborted(Exception):
    pass


_SCHEMA = """
CREATE TABLE IF NOT EXISTS tbls(
  table_id INTEGER PRIMARY KEY AUTOINCREMENT, name TEXT UNIQUE, schema_json TEXT,
  partition_cols TEXT, location TEXT, props TEXT, handler TEXT,
  is_mv INTEGER DEFAULT 0, mv_sql TEXT);
CREATE TABLE IF NOT EXISTS partitions(
  table_id INTEGER, part_values TEXT, location TEXT,
  PRIMARY KEY(table_id, part_values));
CREATE TABLE IF NOT EXISTS col_stats(
  table_id INTEGER, part_values TEXT, column_name TEXT, stats_json TEXT,
  PRIMARY KEY(table_id, part_values, column_name));
CREATE TABLE IF NOT EXISTS row_counts(
  table_id INTEGER, part_values TEXT, row_count INTEGER,
  PRIMARY KEY(table_id, part_values));
CREATE TABLE IF NOT EXISTS txns(
  txn_id INTEGER PRIMARY KEY AUTOINCREMENT, state TEXT, started_at REAL,
  begin_seq INTEGER, commit_seq INTEGER);
CREATE TABLE IF NOT EXISTS write_ids(
  table_id INTEGER, txn_id INTEGER, write_id INTEGER,
  PRIMARY KEY(table_id, txn_id));
CREATE TABLE IF NOT EXISTS next_write_id(
  table_id INTEGER PRIMARY KEY, next INTEGER);
CREATE TABLE IF NOT EXISTS write_sets(
  txn_id INTEGER, table_id INTEGER, part_values TEXT, kind TEXT,
  commit_seq INTEGER);
CREATE TABLE IF NOT EXISTS locks(
  lock_id INTEGER PRIMARY KEY AUTOINCREMENT, txn_id INTEGER, table_id INTEGER,
  part_values TEXT, mode TEXT);
CREATE TABLE IF NOT EXISTS mv_registry(
  name TEXT PRIMARY KEY, sql_text TEXT, source_tables TEXT,
  build_snapshot TEXT, rebuild_seconds REAL, staleness_window REAL,
  last_rebuild_at REAL);
CREATE TABLE IF NOT EXISTS resource_plans(
  name TEXT PRIMARY KEY, plan_json TEXT, is_active INTEGER DEFAULT 0);
CREATE TABLE IF NOT EXISTS notifications(
  event_id INTEGER PRIMARY KEY AUTOINCREMENT, event_type TEXT, payload TEXT,
  at REAL);
CREATE TABLE IF NOT EXISTS runtime_stats(
  query_fingerprint TEXT, op_id TEXT, est_rows REAL, actual_rows REAL,
  at REAL);
CREATE TABLE IF NOT EXISTS catalogs(
  name TEXT PRIMARY KEY, connector TEXT, props TEXT);
"""


class Metastore:
    def __init__(self, warehouse_dir: str, db_path: Optional[str] = None):
        self.warehouse_dir = warehouse_dir
        os.makedirs(warehouse_dir, exist_ok=True)
        self.db_path = db_path or os.path.join(warehouse_dir, "metastore.db")
        self._conn = sqlite3.connect(self.db_path, check_same_thread=False)
        self._conn.execute("PRAGMA journal_mode=MEMORY")
        self._conn.execute("PRAGMA synchronous=OFF")
        self._lock = make_rlock("metastore")
        with self._lock:
            self._conn.executescript(_SCHEMA)
        self._commit_seq = self._q1("SELECT COALESCE(MAX(commit_seq),0) FROM txns") or 0
        self._hooks = []  # metastore hooks registered by storage handlers (§6.1)

    # -- tiny query helpers ---------------------------------------------------
    def _exec(self, sql: str, args: tuple = ()):
        with self._lock:
            cur = self._conn.execute(sql, args)
            self._conn.commit()
            return cur

    def _q(self, sql: str, args: tuple = ()) -> list:
        with self._lock:
            return self._conn.execute(sql, args).fetchall()

    def _q1(self, sql: str, args: tuple = ()):
        rows = self._q(sql, args)
        return rows[0][0] if rows else None

    # ======================================================================
    # Catalog
    # ======================================================================
    def create_table(
        self,
        name: str,
        schema: Sequence[Tuple[str, str]],
        partition_cols: Sequence[str] = (),
        props: Optional[Dict[str, str]] = None,
        handler: Optional[str] = None,
        is_mv: bool = False,
        mv_sql: Optional[str] = None,
        location: Optional[str] = None,
    ) -> TableDesc:
        if self.table_exists(name):
            raise ValueError(f"table {name!r} already exists")
        loc = location or os.path.join(self.warehouse_dir, name)
        self._exec(
            "INSERT INTO tbls(name, schema_json, partition_cols, location, props,"
            " handler, is_mv, mv_sql) VALUES (?,?,?,?,?,?,?,?)",
            (
                name,
                json.dumps(list(map(list, schema))),
                json.dumps(list(partition_cols)),
                loc,
                json.dumps(props or {}),
                handler,
                int(is_mv),
                mv_sql,
            ),
        )
        self._notify("CREATE_TABLE", {"table": name, "handler": handler})
        return self.get_table(name)

    def table_exists(self, name: str) -> bool:
        return self._q1("SELECT COUNT(*) FROM tbls WHERE name=?", (name,)) > 0

    def get_table(self, name: str) -> TableDesc:
        rows = self._q(
            "SELECT table_id, name, schema_json, partition_cols, location, props,"
            " handler, is_mv, mv_sql FROM tbls WHERE name=?",
            (name,),
        )
        if not rows:
            raise KeyError(f"no such table: {name}")
        (tid, nm, schema_json, pcols, loc, props, handler, is_mv, mv_sql) = rows[0]
        return TableDesc(
            name=nm,
            schema=[tuple(x) for x in json.loads(schema_json)],
            partition_cols=json.loads(pcols),
            location=loc,
            props=json.loads(props),
            handler=handler,
            is_mv=bool(is_mv),
            mv_sql=mv_sql,
            table_id=tid,
        )

    def drop_table(self, name: str) -> None:
        t = self.get_table(name)
        for tbl in ("partitions", "col_stats", "row_counts", "write_ids",
                    "next_write_id", "write_sets", "locks"):
            self._exec(f"DELETE FROM {tbl} WHERE table_id=?", (t.table_id,))
        self._exec("DELETE FROM tbls WHERE table_id=?", (t.table_id,))
        self._exec("DELETE FROM mv_registry WHERE name=?", (name,))
        self._notify("DROP_TABLE", {"table": name})

    def list_tables(self) -> List[str]:
        return [r[0] for r in self._q("SELECT name FROM tbls ORDER BY name")]

    def add_partition(self, table: str, part_values: Sequence) -> str:
        t = self.get_table(table)
        key = json.dumps(list(part_values))
        sub = "/".join(f"{c}={v}" for c, v in zip(t.partition_cols, part_values))
        loc = os.path.join(t.location, sub)
        self._exec(
            "INSERT OR IGNORE INTO partitions(table_id, part_values, location)"
            " VALUES (?,?,?)",
            (t.table_id, key, loc),
        )
        return loc

    def list_partitions(self, table: str) -> List[Tuple[tuple, str]]:
        t = self.get_table(table)
        rows = self._q(
            "SELECT part_values, location FROM partitions WHERE table_id=?",
            (t.table_id,),
        )
        return [(tuple(json.loads(pv)), loc) for pv, loc in rows]

    # ======================================================================
    # Catalogs (paper §6: whole external systems mounted at once)
    # ======================================================================
    def create_catalog(self, name: str, connector: str,
                       props: Optional[Dict[str, str]] = None) -> None:
        if self._q1("SELECT COUNT(*) FROM catalogs WHERE name=?", (name,)):
            raise ValueError(f"catalog {name!r} already exists")
        self._exec(
            "INSERT INTO catalogs(name, connector, props) VALUES (?,?,?)",
            (name, connector, json.dumps(props or {})),
        )
        self._notify("CREATE_CATALOG", {"catalog": name, "connector": connector})

    def drop_catalog(self, name: str) -> None:
        self._exec("DELETE FROM catalogs WHERE name=?", (name,))
        self._notify("DROP_CATALOG", {"catalog": name})

    def list_catalogs(self) -> List[Tuple[str, str, Dict[str, str]]]:
        return [
            (n, c, json.loads(p)) for n, c, p in
            self._q("SELECT name, connector, props FROM catalogs ORDER BY name")
        ]

    # ======================================================================
    # Statistics (additive merge, §4.1)
    # ======================================================================
    def merge_stats(self, table: str, part_values, stats: TableStats) -> None:
        t = self.get_table(table)
        key = json.dumps(list(part_values)) if part_values else "[]"
        for col, cs in stats.columns.items():
            prev = self._q(
                "SELECT stats_json FROM col_stats WHERE table_id=? AND part_values=?"
                " AND column_name=?",
                (t.table_id, key, col),
            )
            if prev:
                cs = ColumnStats.from_dict(json.loads(prev[0][0])).merge(cs)
            self._exec(
                "INSERT OR REPLACE INTO col_stats VALUES (?,?,?,?)",
                (t.table_id, key, col, json.dumps(cs.to_dict())),
            )
        prev_rc = self._q1(
            "SELECT row_count FROM row_counts WHERE table_id=? AND part_values=?",
            (t.table_id, key),
        )
        self._exec(
            "INSERT OR REPLACE INTO row_counts VALUES (?,?,?)",
            (t.table_id, key, (prev_rc or 0) + stats.row_count),
        )

    def get_stats(self, table: str) -> TableStats:
        """Stats merged across all partitions (what the optimizer consumes)."""
        t = self.get_table(table)
        out = TableStats()
        for (pv,) in self._q(
            "SELECT DISTINCT part_values FROM row_counts WHERE table_id=?",
            (t.table_id,),
        ):
            cols = {
                col: ColumnStats.from_dict(json.loads(js))
                for col, js in self._q(
                    "SELECT column_name, stats_json FROM col_stats WHERE table_id=?"
                    " AND part_values=?",
                    (t.table_id, pv),
                )
            }
            rc = self._q1(
                "SELECT row_count FROM row_counts WHERE table_id=? AND part_values=?",
                (t.table_id, pv),
            )
            out = out.merge(TableStats(rc or 0, cols))
        return out

    # ======================================================================
    # Transactions (§3.2)
    # ======================================================================
    def open_txn(self) -> int:
        with self._lock:
            cur = self._exec(
                "INSERT INTO txns(state, started_at, begin_seq, commit_seq)"
                " VALUES ('open', ?, ?, NULL)",
                (time.time(), self._commit_seq),
            )
            return cur.lastrowid

    def txn_state(self, txn_id: int) -> str:
        st = self._q1("SELECT state FROM txns WHERE txn_id=?", (txn_id,))
        if st is None:
            raise KeyError(f"unknown txn {txn_id}")
        return st

    def allocate_write_id(self, txn_id: int, table: str) -> int:
        """Monotonic per-table WriteId; one per (txn, table) (§3.2)."""
        if self.txn_state(txn_id) != "open":
            raise TxnAborted(f"txn {txn_id} not open")
        t = self.get_table(table)
        with self._lock:
            existing = self._q1(
                "SELECT write_id FROM write_ids WHERE table_id=? AND txn_id=?",
                (t.table_id, txn_id),
            )
            if existing is not None:
                return existing
            nxt = self._q1(
                "SELECT next FROM next_write_id WHERE table_id=?", (t.table_id,)
            )
            wid = nxt or 1
            self._exec(
                "INSERT OR REPLACE INTO next_write_id VALUES (?,?)",
                (t.table_id, wid + 1),
            )
            self._exec(
                "INSERT INTO write_ids VALUES (?,?,?)", (t.table_id, txn_id, wid)
            )
            return wid

    def record_write_set(self, txn_id: int, table: str, part_values, kind: str):
        """Track update/delete write-sets for optimistic conflict resolution."""
        t = self.get_table(table)
        key = json.dumps(list(part_values)) if part_values else "[]"
        self._exec(
            "INSERT INTO write_sets(txn_id, table_id, part_values, kind, commit_seq)"
            " VALUES (?,?,?,?,NULL)",
            (txn_id, t.table_id, key, kind),
        )

    def commit_txn(self, txn_id: int) -> None:
        with self._lock:
            if self.txn_state(txn_id) != "open":
                raise TxnAborted(f"txn {txn_id} not open")
            # First-commit-wins (§3.2): abort if an overlapping update/delete
            # write-set committed after this transaction began.
            begin_seq = self._q1(
                "SELECT begin_seq FROM txns WHERE txn_id=?", (txn_id,)
            )
            mine = self._q(
                "SELECT table_id, part_values FROM write_sets WHERE txn_id=?"
                " AND kind IN ('update','delete')",
                (txn_id,),
            )
            for table_id, part_values in mine:
                conflict = self._q(
                    "SELECT w.txn_id FROM write_sets w JOIN txns t ON w.txn_id=t.txn_id"
                    " WHERE w.table_id=? AND w.part_values=? AND w.txn_id != ?"
                    " AND w.kind IN ('update','delete') AND t.state='committed'"
                    " AND t.commit_seq > ?",
                    (table_id, part_values, txn_id, begin_seq),
                )
                if conflict:
                    self.abort_txn(txn_id)
                    raise WriteConflict(
                        f"txn {txn_id} lost first-commit-wins to txn {conflict[0][0]}"
                    )
            self._commit_seq += 1
            self._exec(
                "UPDATE txns SET state='committed', commit_seq=? WHERE txn_id=?",
                (self._commit_seq, txn_id),
            )
            self._exec(
                "UPDATE write_sets SET commit_seq=? WHERE txn_id=?",
                (self._commit_seq, txn_id),
            )
            self.release_locks(txn_id)

    def abort_txn(self, txn_id: int) -> None:
        self._exec("UPDATE txns SET state='aborted' WHERE txn_id=?", (txn_id,))
        self.release_locks(txn_id)

    def get_snapshot(self) -> Snapshot:
        hwm = self._q1("SELECT COALESCE(MAX(txn_id),0) FROM txns")
        opens = frozenset(
            r[0] for r in self._q("SELECT txn_id FROM txns WHERE state='open'")
        )
        aborted = frozenset(
            r[0] for r in self._q("SELECT txn_id FROM txns WHERE state='aborted'")
        )
        return Snapshot(hwm, opens, aborted)

    def writeid_list(self, table: str, snapshot: Snapshot) -> WriteIdList:
        """Project the global txn list onto one table's WriteIds (§3.2)."""
        t = self.get_table(table)
        rows = self._q(
            "SELECT txn_id, write_id FROM write_ids WHERE table_id=?", (t.table_id,)
        )
        hwm_w = 0
        invalid = set()
        for txn_id, wid in rows:
            if txn_id <= snapshot.hwm:
                hwm_w = max(hwm_w, wid)
            if not snapshot.txn_visible(txn_id):
                invalid.add(wid)
        return WriteIdList(table, hwm_w, frozenset(invalid))

    def min_open_txn(self) -> Optional[int]:
        return self._q1("SELECT MIN(txn_id) FROM txns WHERE state='open'")

    # ======================================================================
    # Locks (§3.2: partition granularity when partitioned, else table)
    # ======================================================================
    def acquire_lock(self, txn_id: int, table: str, part_values, mode: str) -> int:
        assert mode in ("shared", "exclusive")
        t = self.get_table(table)
        key = json.dumps(list(part_values)) if part_values else None
        with self._lock:
            holders = self._q(
                "SELECT txn_id, part_values, mode FROM locks WHERE table_id=?",
                (t.table_id,),
            )
            for other_txn, other_key, other_mode in holders:
                if other_txn == txn_id:
                    continue
                overlap = key is None or other_key is None or key == other_key
                if overlap and ("exclusive" in (mode, other_mode)):
                    raise LockConflict(
                        f"{mode} lock on {table} blocked by txn {other_txn}"
                    )
            cur = self._exec(
                "INSERT INTO locks(txn_id, table_id, part_values, mode)"
                " VALUES (?,?,?,?)",
                (txn_id, t.table_id, key, mode),
            )
            return cur.lastrowid

    def release_locks(self, txn_id: int) -> None:
        self._exec("DELETE FROM locks WHERE txn_id=?", (txn_id,))

    # ======================================================================
    # Materialized views (§4.4)
    # ======================================================================
    def register_mv(
        self,
        name: str,
        sql_text: str,
        source_tables: Sequence[str],
        build_snapshot: Dict[str, int],
        rebuild_seconds: float = 0.0,
        staleness_window: float = 0.0,
    ) -> None:
        self._exec(
            "INSERT OR REPLACE INTO mv_registry VALUES (?,?,?,?,?,?,?)",
            (
                name,
                sql_text,
                json.dumps(list(source_tables)),
                json.dumps(build_snapshot),
                rebuild_seconds,
                staleness_window,
                time.time(),
            ),
        )

    def list_mvs(self) -> List[dict]:
        rows = self._q(
            "SELECT name, sql_text, source_tables, build_snapshot, rebuild_seconds,"
            " staleness_window, last_rebuild_at FROM mv_registry"
        )
        return [
            {
                "name": n,
                "sql": s,
                "source_tables": json.loads(st),
                "build_snapshot": {k: int(v) for k, v in json.loads(bs).items()},
                "rebuild_seconds": rs,
                "staleness_window": sw,
                "last_rebuild_at": lra,
            }
            for n, s, st, bs, rs, sw, lra in rows
        ]

    def update_mv_snapshot(self, name: str, build_snapshot: Dict[str, int]) -> None:
        self._exec(
            "UPDATE mv_registry SET build_snapshot=?, last_rebuild_at=? WHERE name=?",
            (json.dumps(build_snapshot), time.time(), name),
        )

    # ======================================================================
    # Resource plans (§5.2)
    # ======================================================================
    def save_resource_plan(self, name: str, plan: dict) -> None:
        self._exec(
            "INSERT OR REPLACE INTO resource_plans(name, plan_json, is_active)"
            " VALUES (?,?, COALESCE((SELECT is_active FROM resource_plans"
            " WHERE name=?),0))",
            (name, json.dumps(plan), name),
        )

    def activate_resource_plan(self, name: str) -> None:
        # only one plan may be active at a time (paper §5.2)
        self._exec("UPDATE resource_plans SET is_active=0")
        self._exec("UPDATE resource_plans SET is_active=1 WHERE name=?", (name,))

    def get_resource_plan(self, name: str) -> Optional[dict]:
        js = self._q1("SELECT plan_json FROM resource_plans WHERE name=?", (name,))
        return json.loads(js) if js else None

    def active_resource_plan(self) -> Optional[dict]:
        js = self._q1("SELECT plan_json FROM resource_plans WHERE is_active=1")
        return json.loads(js) if js else None

    # ======================================================================
    # Runtime stats persisted for re-optimization feedback (§4.2, §9 roadmap)
    # ======================================================================
    def record_runtime_stats(self, fingerprint: str, op_id: str, est: float, act: float):
        self._exec(
            "INSERT INTO runtime_stats VALUES (?,?,?,?,?)",
            (fingerprint, op_id, est, act, time.time()),
        )

    def runtime_stats_for(self, fingerprint: str) -> Dict[str, float]:
        rows = self._q(
            "SELECT op_id, actual_rows FROM runtime_stats WHERE query_fingerprint=?"
            " ORDER BY at",
            (fingerprint,),
        )
        return {op: act for op, act in rows}

    # ======================================================================
    # Notification log + metastore hooks (§6.1)
    # ======================================================================
    def register_hook(self, hook) -> None:
        self._hooks.append(hook)

    def _notify(self, event_type: str, payload: dict) -> None:
        self._exec(
            "INSERT INTO notifications(event_type, payload, at) VALUES (?,?,?)",
            (event_type, json.dumps(payload), time.time()),
        )
        for hook in self._hooks:
            fn = getattr(hook, "on_" + event_type.lower(), None)
            if fn is not None:
                fn(payload)

    def notifications(self) -> List[tuple]:
        return self._q("SELECT event_id, event_type, payload FROM notifications")

    def close(self) -> None:
        with self._lock:
            self._conn.close()
