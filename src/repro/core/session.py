"""HiveServer2 analogue: the query driver (paper §2, Figure 2).

``Warehouse`` owns cluster-wide state (metastore, LLAP daemon, storage
handlers, workload manager, query-result cache, and the async
``QueryScheduler`` worker pool); ``Session`` executes SQL:

    parse -> bind (logical plan) -> [result cache probe] -> [MV rewrite]
         -> rule/cost optimization -> semijoin reducers -> shared-work marks
         -> task-DAG compile -> scheduled execution (LLAP or containers)
         -> [re-optimization on runtime errors] -> cache fill

DML statements (INSERT/UPDATE/DELETE/MERGE) run under single-statement ACID
transactions (§3.2); materialized views rebuild incrementally when possible
(§4.4); resource-plan DDL administers the workload manager (§5.2).

``Session.execute`` drives the pipeline synchronously; ``Session.submit``
hands the statement to the warehouse scheduler and returns a
:class:`~repro.core.runtime.scheduler.QueryTask` that the client-side
``QueryHandle`` polls, streams from, or cancels.
"""
from __future__ import annotations

import itertools
import os
import shutil
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..kernels.registry import VALID_ENGINES as _VALID_ENGINES
from .acid import AcidTable, PlainIO
from .config_keys import DEFAULT_CONFIG, SessionConfig
from .compaction import CompactionConfig, compact_partition, maybe_compact
from .federation.catalog import CatalogRegistry
from .federation.datasource import expand_federated_splits, negotiate_federated
from .federation.druid import DruidHandler
from .federation.handler import HandlerRegistry
from .federation.jdbc import JdbcHandler
from .federation.memtable import MemTableHandler
from .metastore import Metastore, TxnAborted, WriteConflict
from .obs import WarehouseObs
from .obs.trace import emit_event
from .optimizer import plan as P
from .serving import ResultCacheServer, SharedScanRegistry
from .pipeline import (
    POST_PROBE_STAGES,
    PRE_ADMISSION_STAGES,
    PlanCache,
    QueryContext,
    QueryPipeline,
    is_cacheable,
    plan_only_stages,
)
from .runtime.dag import compile_dag, describe_exchanges
from .schema import annotate_plan
from .runtime.exec import ExecContext, Executor, eval_expr
from .runtime.llap import LlapDaemon, LlapIO
from .runtime.scheduler import QueryScheduler, QueryTask
from .runtime.vector import ROWID_COL, WRITEID_COL, VectorBatch
from .runtime.wlm import WorkloadManager
from .sql import ast as A
from .sql.binder import Binder, _classify_join_condition
from .sql.parser import parse, parse_many

# DEFAULT_CONFIG now lives in repro.core.config_keys (the REP001
# registry): every knob is declared there once with its default, type,
# and planning flag; this module re-exports the derived dict for
# backwards compatibility (repro.api.connection and tests import it).


class QueryResult:
    def __init__(self, batch: VectorBatch, info: Optional[dict] = None):
        self.batch = batch
        self.info = info or {}

    @property
    def rows(self) -> List[tuple]:
        return self.batch.to_rows()

    @property
    def num_rows(self) -> int:
        return self.batch.num_rows

    def __repr__(self):
        return f"QueryResult({self.num_rows} rows, info={self.info})"


class Warehouse:
    """Cluster-scoped state (one per deployment)."""

    def __init__(self, warehouse_dir: str, llap_cache_bytes: int = 256 << 20,
                 llap_executors: int = 4, query_workers: int = 8,
                 result_cache_bytes: int = 64 << 20):
        self.dir = warehouse_dir
        os.makedirs(warehouse_dir, exist_ok=True)
        self.hms = Metastore(warehouse_dir)
        self.llap = LlapDaemon(cache_bytes=llap_cache_bytes,
                               num_executors=llap_executors)
        self.handlers = HandlerRegistry()
        self.handlers.register(DruidHandler(), self.hms)
        self.handlers.register(JdbcHandler(), self.hms)
        self.handlers.register(MemTableHandler(), self.hms)
        # federated catalogs (§6): whole external systems mounted at once,
        # re-instantiated from metastore persistence on reopen
        self.catalogs = CatalogRegistry(self.hms)
        # observability (PR 10): metrics registry + query log + trace store;
        # created before the serving tier/WLM so they register counters on it
        self.obs = WarehouseObs()
        # serving tier: byte-bounded LRFU result cache + shared-scan registry
        self.result_cache = ResultCacheServer(max_bytes=result_cache_bytes,
                                              metrics=self.obs.metrics)
        self.shared_scans = SharedScanRegistry(metrics=self.obs.metrics)
        self.plan_cache = PlanCache()
        self.wlm = WorkloadManager(self.hms, total_executors=llap_executors,
                                   metrics=self.obs.metrics)
        self._qid = itertools.count()
        self.scheduler = QueryScheduler(self, max_workers=query_workers)

    def serving_stats(self) -> Dict[str, dict]:
        """Serving-tier counters (result cache, shared scans, admission),
        surfaced through ``QueryHandle.poll()`` and
        ``Connection.server_stats()``."""
        return {
            "result_cache": self.result_cache.stats_snapshot(),
            "shared_scans": self.shared_scans.stats_snapshot(),
            "admission_queues": self.wlm.queue_depths(),
        }

    def resolve_handler(self, name: Optional[str]):
        """Resolve a TableDesc.handler reference: either a globally
        registered handler name or a mounted catalog's connector instance
        (``catalog:<name>``)."""
        if not name:
            return None
        if name.startswith("catalog:"):
            cat = self.catalogs.get(name.split(":", 1)[1])
            return cat.handler if cat is not None else None
        return self.handlers.get(name)

    def session(self, **config) -> "Session":
        # SessionConfig warns on keys the registry doesn't declare — the
        # silent-typo class (a misspelled knob falling back to its default
        # without a trace) REP001 exists to catch
        cfg = SessionConfig(DEFAULT_CONFIG, config)
        if cfg.get("engine") not in _VALID_ENGINES:
            raise ValueError(
                f"engine must be one of {_VALID_ENGINES}, got {cfg['engine']!r}"
            )
        return Session(self, cfg)

    def close(self) -> None:
        """Decommission cluster state (LLAP thread pools, caches)."""
        self.scheduler.shutdown()  # cancels in-flight async handles
        self.llap.shutdown()
        self.result_cache.invalidate_all()
        self.shared_scans.invalidate_all()
        self.plan_cache.invalidate_all()


class Session:
    def __init__(self, wh: Warehouse, config: dict):
        self.wh = wh
        self.hms = wh.hms
        self.config = config
        self.last_info: dict = {}

    # ==================================================================
    # public API
    # ==================================================================
    def execute(self, sql: str, params: Optional[Sequence] = None) -> QueryResult:
        stmt = parse(sql)
        return self.execute_stmt(stmt, sql, params)

    def submit(self, sql: str, params: Optional[Sequence] = None) -> QueryTask:
        """Submit a statement for asynchronous execution.

        Parsing and parameter arity run synchronously (so syntax errors
        surface at submit time, like HS2 compilation); everything else —
        WLM admission, planning, execution — happens on the warehouse
        scheduler's worker pool.  The returned :class:`QueryTask` is the
        engine side of a client :class:`repro.api.handle.QueryHandle`.
        """
        stmt = parse(sql)
        params = tuple(params) if params is not None else ()
        target = stmt.stmt if isinstance(stmt, A.Explain) else stmt
        n = A.count_params(target)
        if n != len(params):
            raise ValueError(
                f"statement has {n} parameter placeholder(s) but "
                f"{len(params)} value(s) were supplied"
            )
        return self.wh.scheduler.submit(self, stmt, sql, params)

    def execute_script(self, sql: str) -> List[QueryResult]:
        return [self.execute_stmt(s, "") for s in parse_many(sql)]

    def explain(self, sql: str) -> str:
        stmt = parse(sql)
        if isinstance(stmt, A.Explain):
            stmt = stmt.stmt
        plan, info = self._plan_query(stmt)
        annotate_plan(plan)  # per-node schema: lines in the rendering
        pretty = plan.pretty()  # before DAG compilation mutates the tree
        expanded = self._expand_for_compile(plan)
        annotate_plan(expanded)
        dag = compile_dag(expanded)
        lines = [pretty, "", f"DAG edges: {dag.edge_summary()}",
                 "exchanges:"] + describe_exchanges(dag)
        for k, v in info.items():
            lines.append(f"{k}: {v}")
        return "\n".join(lines)

    # ==================================================================
    # statement dispatch
    # ==================================================================
    def execute_stmt(self, stmt, sql_text: str = "",
                     params: Optional[Sequence] = None) -> QueryResult:
        params = tuple(params) if params is not None else ()
        if isinstance(stmt, A.Explain):
            inner = stmt.stmt
            if not isinstance(inner, (A.Select, A.SetOp)):
                raise ValueError("EXPLAIN supports queries only")
            n = A.count_params(inner)
            if n != len(params):
                raise ValueError(
                    f"statement has {n} parameter placeholder(s) but "
                    f"{len(params)} value(s) were supplied"
                )
            if stmt.analyze:
                return self._explain_analyze(inner, sql_text, params)
            return QueryResult(
                VectorBatch({"plan": np.array(self.explain_stmt(inner).split("\n"))})
            )
        if isinstance(stmt, (A.Select, A.SetOp)):
            return self._run_query(stmt, sql_text, params)
        n_params = A.count_params(stmt)
        if n_params != len(params):
            raise ValueError(
                f"statement has {n_params} parameter placeholder(s) but "
                f"{len(params)} value(s) were supplied"
            )
        if params:
            # DML/DDL take the substitution path: placeholders become literals
            stmt = A.substitute_params(stmt, params)
        if isinstance(stmt, A.CreateCatalog):
            self.wh.catalogs.create(stmt.name, stmt.connector, stmt.props)
            self.wh.plan_cache.invalidate_all()
            return QueryResult(VectorBatch({}), {"catalog": stmt.name})
        if isinstance(stmt, A.DropCatalog):
            self.wh.catalogs.drop(stmt.name, if_exists=stmt.if_exists)
            self.wh.plan_cache.invalidate_all()
            self.wh.result_cache.invalidate_all()
            return QueryResult(VectorBatch({}))
        if isinstance(stmt, A.CreateTable):
            return self._create_table(stmt)
        if isinstance(stmt, A.CreateMaterializedView):
            return self._create_mv(stmt)
        if isinstance(stmt, A.DropTable):
            if stmt.if_exists and not self.hms.table_exists(stmt.name):
                return QueryResult(VectorBatch({}))
            desc = self.hms.get_table(stmt.name)
            self.hms.drop_table(stmt.name)
            self.wh.result_cache.invalidate_all()
            self.wh.plan_cache.invalidate_all()
            # stop new shared-scan attachments; consumers already attached
            # replay exchange-owned chunks and are unaffected by the purge
            self.wh.shared_scans.invalidate_table(stmt.name)
            if not desc.handler:
                # managed table: purge the LLAP cache and the data files, so
                # a table re-created under the same name never scans the old
                # delta stores (stale-rows-after-DROP seed bug)
                self.wh.llap.invalidate_location(desc.location)
                shutil.rmtree(desc.location, ignore_errors=True)
            return QueryResult(VectorBatch({}))
        if isinstance(stmt, A.Insert):
            return self._insert(stmt)
        if isinstance(stmt, A.Update):
            return self._update(stmt)
        if isinstance(stmt, A.Delete):
            return self._delete(stmt)
        if isinstance(stmt, A.Merge):
            return self._merge(stmt)
        if isinstance(stmt, A.RebuildMaterializedView):
            return self._rebuild_mv(stmt.name)
        if isinstance(stmt, A.CreateResourcePlan):
            self.wh.wlm.create_plan(stmt.name)
            return QueryResult(VectorBatch({}))
        if isinstance(stmt, A.CreatePool):
            self.wh.wlm.create_pool(stmt.plan, stmt.pool, stmt.alloc_fraction,
                                    stmt.query_parallelism)
            return QueryResult(VectorBatch({}))
        if isinstance(stmt, A.CreateWMRule):
            self.wh.wlm.create_rule(stmt.plan, stmt.rule, stmt.metric,
                                    stmt.threshold, stmt.action, stmt.target_pool)
            return QueryResult(VectorBatch({}))
        if isinstance(stmt, A.AddWMRuleToPool):
            plan_name = stmt.plan or self._only_plan()
            self.wh.wlm.add_rule_to_pool(plan_name, stmt.rule, stmt.pool)
            return QueryResult(VectorBatch({}))
        if isinstance(stmt, A.CreateWMMapping):
            self.wh.wlm.create_mapping(stmt.plan, stmt.kind, stmt.entity, stmt.pool)
            return QueryResult(VectorBatch({}))
        if isinstance(stmt, A.AlterResourcePlan):
            if stmt.default_pool:
                self.wh.wlm.set_default_pool(stmt.plan, stmt.default_pool)
            if stmt.enable_activate:
                self.wh.wlm.activate(stmt.plan)
            return QueryResult(VectorBatch({}))
        raise ValueError(f"unsupported statement {type(stmt).__name__}")

    def explain_stmt(self, stmt) -> str:
        plan, info = self._plan_query(stmt)
        annotate_plan(plan)
        pretty = plan.pretty()
        expanded = self._expand_for_compile(plan)
        annotate_plan(expanded)
        dag = compile_dag(expanded)
        edge_lines = "\n".join(describe_exchanges(dag))
        return (pretty + f"\nDAG edges: {dag.edge_summary()}"
                + f"\nexchanges:\n{edge_lines}\ninfo: {info}")

    def _only_plan(self) -> str:
        if self.wh.wlm.active_plan:
            return self.wh.wlm.active_plan.name
        names = [r[0] for r in self.hms._q("SELECT name FROM resource_plans")]
        if len(names) == 1:
            return names[0]
        raise ValueError("ADD RULE requires an active plan or plan qualifier")

    # ==================================================================
    # query path (staged pipeline; see repro.core.pipeline)
    # ==================================================================
    def _plan_query(self, stmt, runtime_overrides: Optional[dict] = None,
                    config: Optional[dict] = None) -> Tuple[P.PlanNode, dict]:
        """Plan-only pipeline run (bind + MV rewrite + optimize)."""
        q = QueryContext(session=self, stmt=stmt, config=config or self.config)
        QueryPipeline(self, plan_only_stages(runtime_overrides)).run(q)
        info = {k: v for k, v in q.info.items()
                if k not in ("stage_times_ms", "seconds")}
        return q.plan, info

    def _push_federated(self, plan: P.PlanNode,
                        config: Optional[dict] = None):
        """Capability-negotiated pushdown for every federated scan: returns
        ``(new_plan, summary)``; declined work stays as local residual
        operators (see ``core.federation.datasource``)."""
        return negotiate_federated(plan, self.wh.resolve_handler,
                                   config or self.config)

    def _expand_federated(self, plan: P.PlanNode,
                          config: Optional[dict] = None) -> P.PlanNode:
        """Fan federated scans out over their connectors' splits (one DAG
        vertex per split; compile-time, never cached)."""
        return expand_federated_splits(plan, self.wh.resolve_handler,
                                       config or self.config)

    def _expand_shuffle(self, plan: P.PlanNode,
                        config: Optional[dict] = None,
                        events: Optional[list] = None) -> P.PlanNode:
        """Clone pipeline-breaker consumers per shuffle partition (compile
        time, like split expansion — cached plans re-expand per execution).
        Compile-time adaptive decisions (co-partition shuffle elision) are
        appended to ``events``."""
        from .optimizer.cost import CostModel
        from .runtime.shuffle import expand_shuffle_partitions

        cfg = config or self.config
        cm = CostModel(self.hms, handler_resolver=self.wh.resolve_handler)
        return expand_shuffle_partitions(plan, cfg, cost_model=cm,
                                         events=events)

    def _expand_for_compile(self, plan: P.PlanNode,
                            config: Optional[dict] = None) -> P.PlanNode:
        """The full compile-time expansion pipeline (splits, then lanes)."""
        return self._expand_shuffle(self._expand_federated(plan, config),
                                    config)

    def _run_pipeline(self, stmt, sql_text: str = "", params: Tuple = (),
                      config: Optional[dict] = None, task=None,
                      slot=None) -> QueryContext:
        q = QueryContext(session=self, sql=sql_text, stmt=stmt,
                         params=tuple(params), config=config or self.config,
                         task=task, slot=slot,
                         qid=task.qid if task is not None else "",
                         cancel_token=(task.cancel_token
                                       if task is not None else None))
        return QueryPipeline(self).run(q)

    def _run_query(self, stmt, sql_text: str = "",
                   params: Tuple = ()) -> QueryResult:
        q = self._run_pipeline(stmt, sql_text, params)
        self.last_info = q.info
        self._note_sync_done(q)
        return QueryResult(q.batch, q.info)

    def _note_sync_done(self, q: QueryContext) -> None:
        """Record a synchronously executed query in the warehouse query log
        (async queries are recorded by the scheduler's worker instead).
        Observability must never fail the query it observes."""
        try:
            self.wh.obs.note_query_done({
                "qid": q.qid,
                "sql": q.sql,
                "status": "SUCCEEDED",
                "wall_ms": round(float(q.info.get("seconds", 0.0)) * 1e3, 3),
                "queue_wait_ms": 0.0,
                "rows": q.batch.num_rows if q.batch is not None else 0,
                "pool": None,
                "cache_hit": bool(q.info.get("cache_hit", False)),
                "error": None,
            }, trace=q.trace)
        except Exception:
            pass

    def _probe_result_cache(self, task: QueryTask):
        """Serving-tier pre-admission probe (run by the async scheduler).

        Parses and binds the statement, then probes the result cache.  On a
        hit the query is finished — served without a WLM slot and without
        execution.  Returns ``(QueryResult | None, QueryContext | None)``;
        a non-None context on a miss carries the bound plan and any pending
        cache entry into :meth:`_run_query_task` so the remaining stages
        resume without re-probing (re-probing would deadlock behind our own
        pending entry)."""
        if isinstance(task.stmt, A.Explain):
            return None, None  # EXPLAIN ANALYZE always executes
        q = QueryContext(session=self, sql=task.sql, stmt=task.stmt,
                         params=tuple(task.params), config=self.config,
                         task=task, qid=task.qid,
                         cancel_token=task.cancel_token)
        QueryPipeline(self, stages=PRE_ADMISSION_STAGES).run(q)
        if not q.finished:
            return None, q
        q.info["admission_skipped"] = True
        # a cache-served result reports the same stage_times_ms keys as an
        # executed one: the post-probe stages ran for 0 ms, not "not at all"
        # (dashboards keying on stage names would otherwise KeyError on hits)
        st = q.info.setdefault("stage_times_ms", {})
        for stage in POST_PROBE_STAGES:
            st.setdefault(stage.name, 0.0)
        emit_event(q.trace, "serving:result_cache_hit", "serving")
        self.last_info = q.info
        return QueryResult(q.batch, q.info), q

    def _run_query_task(self, task: QueryTask, slot,
                        pre: Optional[QueryContext] = None) -> QueryResult:
        """Async query entry point, called by the scheduler's worker with an
        already-admitted WLM slot (or None when no plan is active)."""
        if isinstance(task.stmt, A.Explain):
            # EXPLAIN ANALYZE executes the inner query, so it is admitted
            # like one; the scheduler only routes the analyze variant here
            return self._explain_analyze(task.stmt.stmt, task.sql,
                                         task.params, task=task, slot=slot)
        if pre is not None:
            # resume the pre-admission QueryContext past the cache probe
            pre.slot = slot
            q = QueryPipeline(self, stages=POST_PROBE_STAGES).run(pre)
        else:
            q = self._run_pipeline(task.stmt, task.sql, task.params,
                                   task=task, slot=slot)
        self.last_info = q.info
        return QueryResult(q.batch, q.info)

    def _explain_analyze(self, stmt, sql_text: str, params: Tuple = (),
                         task=None, slot=None) -> QueryResult:
        """EXPLAIN ANALYZE: run the query, report plan + per-stage timings.

        The result cache is bypassed — ANALYZE means "actually execute and
        measure"; a cache hit would short-circuit before the plan exists.
        Tracing is forced on so the report is built from the query's own
        :class:`~repro.core.obs.trace.QueryTrace` (per-vertex compute /
        exchange-wait / spill-I/O breakdowns, lane skew, serving and
        adaptive events) rather than ad-hoc timers."""
        q = self._run_pipeline(stmt, sql_text, params,
                               config={**self.config, "result_cache": False,
                                       "obs.tracing": True},
                               task=task, slot=slot)
        self.last_info = q.info
        lines: List[str] = []
        if q.plan_pretty:
            lines.extend(q.plan_pretty.split("\n"))
            lines.append("")
        lines.append("stage timings:")
        for name, ms in q.info.get("stage_times_ms", {}).items():
            lines.append(f"  {name}: {ms:.3f} ms")
        adaptive = q.info.get("adaptive")
        if adaptive:
            lines.append("adaptive decisions:")
            for ev in adaptive:
                rest = ", ".join(f"{k}={v}" for k, v in ev.items()
                                 if k != "kind")
                lines.append(f"  {ev.get('kind')}: {rest}")
        lines.extend(self._analyze_trace_lines(q))
        for k, v in q.info.items():
            if k not in ("stage_times_ms", "adaptive"):
                lines.append(f"{k}: {v}")
        return QueryResult(VectorBatch({"plan": np.array(lines)}), q.info)

    @staticmethod
    def _analyze_trace_lines(q: QueryContext) -> List[str]:
        """Trace-derived EXPLAIN ANALYZE sections: per-vertex wall split,
        shuffle-lane skew, and the serving/kernel event log."""
        if q.trace is None:
            return []
        summ = q.trace.summary()
        lines: List[str] = []
        verts = summ.get("vertices", {})
        if verts:
            lines.append("vertex breakdown:")
            for vid, v in verts.items():
                lines.append(
                    f"  {vid}: total={v['total_ms']:.3f} ms"
                    f" compute={v['compute_ms']:.3f} ms"
                    f" exchange_wait={v['exchange_wait_ms']:.3f} ms"
                    f" spill_io={v['spill_io_ms']:.3f} ms"
                    f" rows={v['rows']}")
                lanes = v.get("lanes")
                if lanes:
                    rows = [int(ln.get("rows", 0)) for ln in lanes]
                    mean = sum(rows) / len(rows)
                    skew = (max(rows) / mean) if mean else 1.0
                    lines.append(
                        f"    lanes={len(rows)}"
                        f" rows/lane min={min(rows)} max={max(rows)}"
                        f" skew={skew:.2f}x")
        dispatches = summ.get("kernel_dispatches", {})
        if dispatches:
            lines.append("kernel dispatches:")
            for name, n in sorted(dispatches.items()):
                lines.append(f"  {name}: {n}")
        events = [ev for ev in summ.get("events", [])
                  if ev.get("cat") in ("serving", "adaptive", "wlm")]
        if events:
            lines.append("trace events:")
            for ev in events:
                lines.append(f"  +{ev['ts_ms']:.3f} ms [{ev['cat']}] "
                             f"{ev['name']}")
        return lines

    def _make_ctx(self, cfg, params: Tuple = (),
                  cancel_token=None) -> ExecContext:
        ctx = ExecContext(
            self.hms,
            self.hms.get_snapshot(),
            config=cfg,
            io=LlapIO(self.wh.llap) if cfg["llap"] else PlainIO(),
            handlers={**self.wh.handlers.as_dict(),
                      **self.wh.catalogs.handler_map()},
            params=params,
            cancel_token=cancel_token,
        )
        if cfg.get("serving.shared_scans", True):
            ctx.shared_scans = self.wh.shared_scans
        return ctx

    def _persist_runtime_stats(self, plan, ctx) -> None:
        fp = plan.digest()
        for op, rows in list(ctx.op_stats.items())[:64]:
            self.hms.record_runtime_stats(fp, op, -1.0, float(rows))

    # ==================================================================
    # DDL
    # ==================================================================
    def _create_table(self, stmt: A.CreateTable) -> QueryResult:
        handler_name = None
        if stmt.stored_by:
            h = self.wh.handlers.get(stmt.stored_by)
            if h is None:
                raise ValueError(f"unknown storage handler {stmt.stored_by}")
            handler_name = h.name
        schema = [(c.name, c.type) for c in stmt.columns]
        if not schema and handler_name:
            h = self.wh.handlers.get(handler_name)
            inferred = h.infer_schema(stmt.props)
            if inferred is None:
                raise ValueError("cannot infer schema from external system")
            schema = inferred
        part_cols = [c.name for c in stmt.partition_by]
        # Hive keeps partition columns out of the file schema but they are
        # part of the table schema
        for c in stmt.partition_by:
            if c.name not in [n for n, _ in schema]:
                schema.append((c.name, c.type))
        self.hms.create_table(
            stmt.name, schema, partition_cols=part_cols, props=stmt.props,
            handler=handler_name,
        )
        self.wh.plan_cache.invalidate_all()
        return QueryResult(VectorBatch({}))

    def _create_mv(self, stmt: A.CreateMaterializedView) -> QueryResult:
        # 1. evaluate the definition
        plan, _ = self._plan_query(stmt.query)
        ctx = self._make_ctx(self.config)
        batch = Executor(ctx).execute(plan)
        names = plan.output_names()
        out_cols = {}
        for n in names:
            base = n.split(".", 1)[1] if "." in n else n
            out_cols[base] = batch.cols[n]
        batch = VectorBatch(out_cols)
        schema = [(c, _sql_type(batch.cols[c])) for c in batch.column_names]

        source_tables = sorted(
            {s.table.name for s in P.walk_plan(plan)
             if isinstance(s, (P.Scan, P.FederatedScan))}
        )
        handler_name = None
        if stmt.stored_by:
            handler_name = self.wh.handlers.get(stmt.stored_by).name

        desc = self.hms.create_table(
            stmt.name, schema, props=stmt.props, handler=handler_name,
            is_mv=True, mv_sql=_mv_sql_of(stmt),
        )
        if handler_name:
            self._write_external(desc, batch)
        else:
            txn = self.hms.open_txn()
            AcidTable(desc, self.hms).insert(txn, batch)
            self.hms.commit_txn(txn)

        snap = self.hms.get_snapshot()
        build = {t: self._hwm_of(t, snap) for t in source_tables}
        window = float(stmt.props.get("staleness_window", 0) or 0)
        self.hms.register_mv(stmt.name, _mv_sql_of(stmt), source_tables, build,
                             staleness_window=window)
        self.wh.plan_cache.invalidate_all()  # cached plans now miss the MV
        return QueryResult(VectorBatch({}), {"mv": stmt.name, "rows": batch.num_rows})

    def _rebuild_mv(self, name: str) -> QueryResult:
        mvs = {m["name"]: m for m in self.hms.list_mvs()}
        if name not in mvs:
            raise KeyError(f"no materialized view {name}")
        mv = mvs[name]
        desc = self.hms.get_table(name)
        snap = self.hms.get_snapshot()

        # which sources changed, and did any change involve deletes?
        # (catalog-mounted external sources have no WriteId state: remote
        # changes are undetectable, so they never trigger an incremental
        # path on their own — ALTER ... REBUILD still recomputes via "full")
        changed, has_deletes = [], False
        for t in mv["source_tables"]:
            if not self.hms.table_exists(t):
                continue
            wl = self.hms.writeid_list(t, snap)
            old = mv["build_snapshot"].get(t, 0)
            if wl.hwm != old:
                changed.append((t, old))
                tdesc = self.hms.get_table(t)
                from .acid import list_stores

                locs = ([loc for _, loc in self.hms.list_partitions(t)]
                        if tdesc.partition_cols else [tdesc.location])
                for loc in locs:
                    for s in list_stores(loc):
                        if s.kind == "delete_delta" and s.max_writeid > old:
                            has_deletes = True

        mode = "noop"
        stmt = parse(mv["sql"])
        if not changed:
            pass
        elif has_deletes or len(changed) > 1:
            # UPDATE/DELETE (or multi-table inserts) force a full rebuild (§4.4)
            mode = "full"
            self._replace_mv_contents(desc, stmt)
        else:
            # incremental: rewrite reads the MV + only the new data (§4.4);
            # SPJA views MERGE the delta partials into existing groups
            mode = "incremental"
            table, old_wid = changed[0]
            plan, _ = self._plan_query(stmt, config={**self.config,
                                                     "mv_rewriting": False})
            for s in P.walk_plan(plan):
                if isinstance(s, P.Scan) and s.table.name == table:
                    s.min_writeid = old_wid  # snapshot filter on WriteId (§4.4)
            ctx = self._make_ctx(self.config)
            delta = Executor(ctx).execute(plan)
            self._merge_mv_delta(desc, stmt, delta, plan.output_names())

        build = {t: self._hwm_of(t, snap) for t in mv["source_tables"]}
        self.hms.update_mv_snapshot(name, build)
        self.wh.result_cache.invalidate_all()
        self.wh.plan_cache.invalidate_all()
        return QueryResult(VectorBatch({}), {"rebuild_mode": mode})

    def _hwm_of(self, table: str, snap) -> int:
        try:
            return self.hms.writeid_list(table, snap).hwm
        except KeyError:  # catalog-mounted external table: no WriteIds
            return 0

    def _replace_mv_contents(self, desc, stmt) -> None:
        plan, _ = self._plan_query(stmt, config={**self.config,
                                                 "mv_rewriting": False})
        ctx = self._make_ctx(self.config)
        batch = Executor(ctx).execute(plan)
        renamed = VectorBatch({
            c: batch.cols[n]
            for (c, _), n in zip(desc.schema, plan.output_names())
        })
        tbl = AcidTable(desc, self.hms)
        txn = self.hms.open_txn()
        wl = self.hms.writeid_list(desc.name, self.hms.get_snapshot())
        targets = {}
        for pvals, b in tbl.scan(wl, keep_acid_cols=True):
            t = np.stack([b.cols[WRITEID_COL], b.cols[ROWID_COL]], axis=1)
            targets[pvals] = t
        if targets:
            tbl.delete(txn, targets)
        tbl.insert(txn, renamed, update_stats=False)
        self.hms.commit_txn(txn)

    def _merge_mv_delta(self, desc, stmt, delta: VectorBatch, out_names) -> None:
        """MERGE the delta aggregation into the MV table (paper §4.4)."""
        sel = stmt if isinstance(stmt, A.Select) else None
        n_keys = len(sel.group_by) if sel and sel.group_by else 0
        cols = [c for c, _ in desc.schema]
        key_cols, agg_cols = cols[:n_keys], cols[n_keys:]
        delta_renamed = VectorBatch({c: delta.cols[n] for c, n in zip(cols, out_names)})

        tbl = AcidTable(desc, self.hms)
        txn = self.hms.open_txn()
        wl = self.hms.writeid_list(desc.name, self.hms.get_snapshot())
        cur_parts = list(tbl.scan(wl, keep_acid_cols=True))
        cur = VectorBatch.concat([b for _, b in cur_parts])

        if n_keys == 0 or cur.num_rows == 0:
            if cur.num_rows and n_keys == 0:
                merged = {}
                agg_fns = self._agg_fns_of(sel)
                for c, fn in zip(cols, agg_fns):
                    merged[c] = _fold_partial(fn, cur.cols[c], delta_renamed.cols[c])
                targets = {(): np.stack([cur.cols[WRITEID_COL], cur.cols[ROWID_COL]], axis=1)}
                tbl.delete(txn, targets)
                tbl.insert(txn, VectorBatch(merged), update_stats=False)
            else:
                tbl.insert(txn, delta_renamed, update_stats=False)
            self.hms.commit_txn(txn)
            return

        # match delta groups against current rows (WHEN MATCHED -> fold)
        from .runtime.exec import _factorize_pair, _combine_codes

        pairs = [_factorize_pair(cur.cols[k], delta_renamed.cols[k]) for k in key_cols]
        cc, dc = _combine_codes(pairs)
        matched_mask = np.isin(cc, dc)
        # delete matched current rows; fold their aggs into the delta rows
        agg_fns = self._agg_fns_of(sel)
        d_index = {code: i for i, code in enumerate(dc)}
        folded = {c: delta_renamed.cols[c].copy() for c in cols}
        for i in np.flatnonzero(matched_mask):
            j = d_index[cc[i]]
            for c, fn in zip(agg_cols, agg_fns[n_keys:] if len(agg_fns) == len(cols) else agg_fns):
                folded[c][j] = _fold_partial(fn, np.array([cur.cols[c][i]]),
                                             np.array([folded[c][j]]))[0]
        if matched_mask.any():
            targets = {(): np.stack([
                cur.cols[WRITEID_COL][matched_mask],
                cur.cols[ROWID_COL][matched_mask],
            ], axis=1)}
            tbl.delete(txn, targets)
        tbl.insert(txn, VectorBatch(folded), update_stats=False)
        self.hms.commit_txn(txn)

    @staticmethod
    def _agg_fns_of(sel: Optional[A.Select]) -> List[str]:
        if sel is None:
            return []
        fns = []
        for e, _ in sel.projections:
            aggs = [x for x in A.walk(e) if isinstance(x, A.Func) and x.name in A.AGG_FUNCS]
            fns.append(aggs[0].name if aggs else "key")
        return fns

    # ==================================================================
    # DML (§3.2: single-statement transactions, update = delete + insert)
    # ==================================================================
    def _write_external(self, desc, batch: VectorBatch) -> None:
        """Batched write path: morsels stream through the connector's
        :class:`~repro.core.federation.datasource.Writer` and become visible
        atomically on ``commit`` (replaces the one-shot ``write``)."""
        handler = self.wh.resolve_handler(desc.handler)
        if handler is None:
            raise ValueError(f"no storage handler registered: {desc.handler}")
        writer = handler.writer(desc)
        rows = int(self.config.get("exchange.batch_rows", 1024) or 1024)
        try:
            for chunk in batch.iter_chunks(rows):
                writer.write_batch(chunk)
            writer.commit()
        except Exception:
            writer.abort()
            raise

    def _post_write(self, table: str) -> None:
        desc = self.hms.get_table(table)
        if not desc.handler and self.config["compaction_enabled"]:
            maybe_compact(
                AcidTable(desc, self.hms), self.hms,
                CompactionConfig(
                    minor_delta_threshold=self.config["compaction_minor_threshold"],
                    major_ratio_threshold=self.config["compaction_major_ratio"],
                ),
            )

    def _insert(self, stmt: A.Insert) -> QueryResult:
        desc = self.hms.get_table(stmt.table)
        if isinstance(stmt.source, A.Values):
            names = stmt.columns or [c for c, _ in desc.schema]
            one = VectorBatch({"__d": np.zeros(1)})
            cols = {n: [] for n in names}
            for row in stmt.source.rows:
                for n, e in zip(names, row):
                    cols[n].append(eval_expr(e, one, None)[0])
            batch = VectorBatch({n: np.array(v) for n, v in cols.items()})
        else:
            plan, _ = self._plan_query(stmt.source)
            ctx = self._make_ctx(self.config)
            out = Executor(ctx).execute(plan)
            names = stmt.columns or [c for c, _ in desc.schema]
            batch = VectorBatch(dict(zip(names, (out.cols[n] for n in plan.output_names()))))
        batch = _coerce_schema(batch, desc)

        if desc.handler:
            self._write_external(desc, batch)
            return QueryResult(VectorBatch({}), {"inserted": batch.num_rows})
        txn = self.hms.open_txn()
        try:
            AcidTable(desc, self.hms).insert(txn, batch)
            self.hms.commit_txn(txn)
        except Exception:
            if self.hms.txn_state(txn) == "open":
                self.hms.abort_txn(txn)
            raise
        self._post_write(stmt.table)
        return QueryResult(VectorBatch({}), {"inserted": batch.num_rows, "txn": txn})

    def _scan_with_acid(self, desc, where: Optional[A.Expr], alias: str):
        """Yield (pvals, batch, mask) for DML target selection."""
        tbl = AcidTable(desc, self.hms)
        wl = self.hms.writeid_list(desc.name, self.hms.get_snapshot())
        scope_cols = {f"{alias}.{c}": c for c, _ in desc.schema}
        for pvals, b in tbl.scan(wl, keep_acid_cols=True,
                                 io=LlapIO(self.wh.llap) if self.config["llap"] else None):
            qb = b.rename({c: f"{alias}.{c}" for c in b.column_names
                           if not c.startswith("__")})
            if where is not None and qb.num_rows:
                bound = Binder(self.hms)._bind_expr(
                    where, _dml_scope(alias, [c for c, _ in desc.schema])
                )
                mask = eval_expr(bound, qb, None).astype(bool)
            else:
                mask = np.ones(qb.num_rows, dtype=bool)
            yield pvals, qb, mask

    def _delete(self, stmt: A.Delete) -> QueryResult:
        desc = self.hms.get_table(stmt.table)
        # DELETE ... WHERE col IN (subquery) takes the semi-join path
        where = stmt.where
        alias = stmt.table
        txn = self.hms.open_txn()
        deleted = 0
        try:
            targets = {}
            if where is not None and _has_subquery(where):
                sel = A.Select(projections=[(A.Star(), None)],
                               from_=A.TableRef(stmt.table, alias), where=where)
                plan = Binder(self.hms).bind(sel)
                ctx = self._make_ctx({**self.config, "keep_acid_cols": True})
                out = Executor(ctx).execute(plan)
                wid_col = WRITEID_COL if WRITEID_COL in out.cols else f"{alias}.{WRITEID_COL}"
                t = np.stack([out.cols[WRITEID_COL], out.cols[ROWID_COL]], axis=1)
                targets[()] = t
                deleted = len(t)
            else:
                for pvals, qb, mask in self._scan_with_acid(desc, where, alias):
                    t = np.stack([qb.cols[WRITEID_COL][mask],
                                  qb.cols[ROWID_COL][mask]], axis=1)
                    if len(t):
                        targets[pvals] = t
                        deleted += len(t)
            if targets:
                AcidTable(desc, self.hms).delete(txn, targets)
            self.hms.commit_txn(txn)
        except (WriteConflict, TxnAborted):
            raise
        except Exception:
            if self.hms.txn_state(txn) == "open":
                self.hms.abort_txn(txn)
            raise
        self._post_write(stmt.table)
        self.wh.result_cache.invalidate_all()
        return QueryResult(VectorBatch({}), {"deleted": deleted, "txn": txn})

    def _update(self, stmt: A.Update) -> QueryResult:
        desc = self.hms.get_table(stmt.table)
        alias = stmt.table
        tbl = AcidTable(desc, self.hms)
        txn = self.hms.open_txn()
        updated = 0
        try:
            all_targets, new_parts = {}, []
            scope = _dml_scope(alias, [c for c, _ in desc.schema])
            binder = Binder(self.hms)
            for pvals, qb, mask in self._scan_with_acid(desc, stmt.where, alias):
                if not mask.any():
                    continue
                t = np.stack([qb.cols[WRITEID_COL][mask],
                              qb.cols[ROWID_COL][mask]], axis=1)
                all_targets[pvals] = t
                sel = qb.select(mask)
                cols = {}
                for c, _ty in desc.schema:
                    if c in desc.partition_cols:
                        cols[c] = np.full(sel.num_rows, dict(zip(desc.partition_cols, pvals))[c])
                    else:
                        cols[c] = sel.cols[f"{alias}.{c}"]
                for col, e in stmt.assignments:
                    bound = binder._bind_expr(e, scope)
                    cols[col] = eval_expr(bound, sel, None)
                new_parts.append(VectorBatch(cols))
                updated += sel.num_rows
            if all_targets:
                # update = delete + insert under one WriteId (§3.2)
                tbl.delete(txn, all_targets)
                for pvals in all_targets:
                    self.hms.record_write_set(txn, desc.name, pvals, "update")
                tbl.insert(txn, _coerce_schema(VectorBatch.concat(new_parts), desc))
            self.hms.commit_txn(txn)
        except (WriteConflict, TxnAborted):
            raise
        except Exception:
            if self.hms.txn_state(txn) == "open":
                self.hms.abort_txn(txn)
            raise
        self._post_write(stmt.table)
        self.wh.result_cache.invalidate_all()
        return QueryResult(VectorBatch({}), {"updated": updated, "txn": txn})

    def _merge(self, stmt: A.Merge) -> QueryResult:
        tgt_desc = self.hms.get_table(stmt.target.name)
        t_alias = stmt.target.alias or stmt.target.name
        tbl = AcidTable(tgt_desc, self.hms)

        # source relation
        binder = Binder(self.hms)
        if isinstance(stmt.source, A.TableRef):
            s_alias = stmt.source.alias or stmt.source.name
            src_sel = A.Select(projections=[(A.Star(), None)],
                               from_=A.TableRef(stmt.source.name, s_alias))
        else:
            s_alias = stmt.source.alias
            src_sel = A.Select(projections=[(A.Star(), None)], from_=stmt.source)
        src_plan = binder.bind(src_sel)
        ctx = self._make_ctx(self.config)
        src = Executor(ctx).execute(src_plan)
        src = src.rename({n: (n if "." in n else f"{s_alias}.{n}")
                          for n in src.column_names})

        # target snapshot with ACID columns, qualified
        wl = self.hms.writeid_list(tgt_desc.name, self.hms.get_snapshot())
        tgt_parts = list(tbl.scan(wl, keep_acid_cols=True))
        tgt = VectorBatch.concat([
            b.rename({c: f"{t_alias}.{c}" for c in b.column_names
                      if not c.startswith("__")})
            for _, b in tgt_parts
        ]) if tgt_parts else VectorBatch({})

        merged_scope = _dml_scope2({t_alias: [c for c, _ in tgt_desc.schema],
                                    s_alias: [n.split(".", 1)[1] for n in src.column_names]})
        on = binder._bind_expr(stmt.on, merged_scope)
        lkeys, rkeys, residual = _classify_join_condition(
            on, set(tgt.column_names), set(src.column_names)
        )
        from .runtime.exec import _factorize_pair, _combine_codes, _expand_matches

        pairs = [_factorize_pair(tgt.cols[lk], src.cols[rk])
                 for lk, rk in zip(lkeys, rkeys)]
        tc, sc = _combine_codes(pairs)
        order = np.argsort(sc, kind="stable")
        sc_sorted = sc[order]
        lo = np.searchsorted(sc_sorted, tc, "left")
        hi = np.searchsorted(sc_sorted, tc, "right")
        counts = hi - lo
        ti, si = _expand_matches(lo, counts, order)
        joined = VectorBatch({**{k: tgt.cols[k][ti] for k in tgt.cols},
                              **{k: src.cols[k][si] for k in src.cols}})
        if residual is not None and joined.num_rows:
            ok = eval_expr(residual, joined, None).astype(bool)
            joined = joined.select(ok)

        src_matched = np.zeros(src.num_rows, dtype=bool)
        if len(si):
            src_matched[si] = True
        not_matched = src.select(~src_matched)

        txn = self.hms.open_txn()
        n_upd = n_del = n_ins = 0
        try:
            consumed = np.zeros(joined.num_rows, dtype=bool)
            del_targets = []
            ins_parts = []
            for action in stmt.matched:
                if action.condition is not None:
                    cond = binder._bind_expr(action.condition, merged_scope)
                    m = eval_expr(cond, joined, None).astype(bool) & ~consumed
                else:
                    m = ~consumed
                if not m.any():
                    continue
                consumed |= m
                sel = joined.select(m)
                del_targets.append(np.stack([sel.cols[WRITEID_COL],
                                             sel.cols[ROWID_COL]], axis=1))
                if action.kind == "update":
                    cols = {c: sel.cols[f"{t_alias}.{c}"] for c, _ in tgt_desc.schema}
                    for col, e in action.assignments:
                        bound = binder._bind_expr(e, merged_scope)
                        cols[col] = eval_expr(bound, sel, None)
                    ins_parts.append(VectorBatch(cols))
                    n_upd += sel.num_rows
                    self.hms.record_write_set(txn, tgt_desc.name, (), "update")
                else:
                    n_del += sel.num_rows
                    self.hms.record_write_set(txn, tgt_desc.name, (), "delete")
            for action in stmt.not_matched:
                m = np.ones(not_matched.num_rows, dtype=bool)
                if action.condition is not None:
                    cond = binder._bind_expr(action.condition, merged_scope)
                    m = eval_expr(cond, not_matched, None).astype(bool)
                sel = not_matched.select(m)
                names = action.columns or [c for c, _ in tgt_desc.schema]
                cols = {}
                for n, e in zip(names, action.values):
                    bound = binder._bind_expr(e, merged_scope)
                    cols[n] = eval_expr(bound, sel, None)
                ins_parts.append(VectorBatch(cols))
                n_ins += sel.num_rows
            if del_targets:
                tbl.delete(txn, {(): np.concatenate(del_targets)})
            if ins_parts:
                tbl.insert(txn, _coerce_schema(VectorBatch.concat(ins_parts), tgt_desc))
            self.hms.commit_txn(txn)
        except (WriteConflict, TxnAborted):
            raise
        except Exception:
            if self.hms.txn_state(txn) == "open":
                self.hms.abort_txn(txn)
            raise
        self._post_write(tgt_desc.name)
        self.wh.result_cache.invalidate_all()
        return QueryResult(VectorBatch({}),
                           {"updated": n_upd, "deleted": n_del, "inserted": n_ins})


# ---------------------------------------------------------------------------
_is_cacheable = is_cacheable  # moved to repro.core.pipeline; alias kept


def _has_subquery(e: A.Expr) -> bool:
    return any(isinstance(x, A.SubqueryExpr) for x in A.walk(e))


def _dml_scope(alias: str, cols: List[str]):
    from .sql.binder import Scope

    return Scope({alias: cols})


def _dml_scope2(tables: Dict[str, List[str]]):
    from .sql.binder import Scope

    return Scope(tables)


def _sql_type(arr: np.ndarray) -> str:
    return {"i": "BIGINT", "u": "BIGINT", "f": "DOUBLE", "b": "BOOLEAN"}.get(
        arr.dtype.kind, "STRING"
    )


def _coerce_schema(batch: VectorBatch, desc) -> VectorBatch:
    from .acid import _np_dtype

    cols = {}
    for c, ty in desc.schema:
        if c in batch.cols:
            want = _np_dtype(ty)
            v = batch.cols[c]
            if v.dtype != want:
                if want.kind == "i" and v.dtype.kind == "f":
                    v = v.astype(np.int64)
                elif want.kind == "U" :
                    v = v.astype(str)
                else:
                    v = v.astype(want)
            cols[c] = v
    return VectorBatch(cols)


def _fold_partial(fn: str, a: np.ndarray, b: np.ndarray) -> np.ndarray:
    if fn in ("sum", "count"):
        return a + b
    if fn == "min":
        return np.minimum(a, b)
    if fn == "max":
        return np.maximum(a, b)
    return b


def _mv_sql_of(stmt: A.CreateMaterializedView) -> str:
    # reconstruct definition text (the parser does not retain raw text)
    return _select_to_sql(stmt.query)


def _select_to_sql(s: A.Select) -> str:
    parts = ["SELECT "]
    parts.append(", ".join(
        f"{_expr_sql(e)}" + (f" AS {a}" if a else "") for e, a in s.projections
    ))
    if s.from_ is not None:
        parts.append(" FROM " + _from_sql(s.from_))
    if s.where is not None:
        parts.append(" WHERE " + _expr_sql(s.where))
    if s.group_by:
        parts.append(" GROUP BY " + ", ".join(_expr_sql(e) for e in s.group_by))
    if s.having is not None:
        parts.append(" HAVING " + _expr_sql(s.having))
    if s.order_by:
        parts.append(" ORDER BY " + ", ".join(
            f"{_expr_sql(e)} {'DESC' if d else 'ASC'}" for e, d in s.order_by))
    if s.limit is not None:
        parts.append(f" LIMIT {s.limit}")
    return "".join(parts)


def _from_sql(f) -> str:
    if isinstance(f, A.TableRef):
        return f.name + (f" {f.alias}" if f.alias else "")
    if isinstance(f, A.JoinRef):
        if f.kind == "cross" and f.condition is None:
            return f"{_from_sql(f.left)}, {_from_sql(f.right)}"
        cond = f" ON {_expr_sql(f.condition)}" if f.condition is not None else ""
        kind = {"inner": "JOIN", "left": "LEFT JOIN", "right": "RIGHT JOIN",
                "full": "FULL JOIN", "cross": "CROSS JOIN"}[f.kind]
        return f"{_from_sql(f.left)} {kind} {_from_sql(f.right)}{cond}"
    if isinstance(f, A.SubqueryRef):
        return f"({_select_to_sql(f.query)}) {f.alias}"
    raise ValueError(type(f))


def _expr_sql(e: A.Expr) -> str:
    if isinstance(e, A.Col):
        return e.qualified
    if isinstance(e, A.Param):
        return "?"
    if isinstance(e, A.Lit):
        if isinstance(e.value, str):
            return "'" + e.value.replace("'", "''") + "'"
        return str(e.value)
    if isinstance(e, A.BinOp):
        return f"({_expr_sql(e.left)} {e.op} {_expr_sql(e.right)})"
    if isinstance(e, A.UnOp):
        return f"({e.op} {_expr_sql(e.operand)})"
    if isinstance(e, A.Func):
        d = "DISTINCT " if e.distinct else ""
        args = ", ".join(_expr_sql(a) for a in e.args) if e.args else "*"
        if not e.args:
            args = "*" if e.name == "count" else ""
        return f"{e.name}({d}{args})"
    if isinstance(e, A.Star):
        return "*"
    if isinstance(e, A.Between):
        n = "NOT " if e.negated else ""
        return f"({_expr_sql(e.expr)} {n}BETWEEN {_expr_sql(e.low)} AND {_expr_sql(e.high)})"
    if isinstance(e, A.InList):
        n = "NOT " if e.negated else ""
        return f"({_expr_sql(e.expr)} {n}IN ({', '.join(_expr_sql(v) for v in e.values)}))"
    if isinstance(e, A.IsNull):
        n = "NOT " if e.negated else ""
        return f"({_expr_sql(e.expr)} IS {n}NULL)"
    if isinstance(e, A.Case):
        ws = " ".join(f"WHEN {_expr_sql(c)} THEN {_expr_sql(v)}" for c, v in e.whens)
        el = f" ELSE {_expr_sql(e.otherwise)}" if e.otherwise is not None else ""
        return f"CASE {ws}{el} END"
    if isinstance(e, A.Cast):
        return f"CAST({_expr_sql(e.expr)} AS {e.to_type})"
    raise ValueError(f"cannot render {type(e).__name__}")
