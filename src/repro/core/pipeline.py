"""Staged query pipeline (paper §2, Figure 2 — the query driver).

``Session`` used to fuse planning and execution into a single blocking call;
this module carves that path into explicit, individually testable stages:

    Parse -> Bind -> CacheProbe -> MVRewrite -> Optimize -> Compile -> Execute

A typed :class:`QueryContext` flows through the stages; each stage's
wall-time is recorded and surfaced in ``QueryResult.info['stage_times_ms']``
and via ``EXPLAIN ANALYZE``.

The module also hosts :class:`PlanCache` (prepared-statement support): the
Bind stage probes it by statement text, and the Optimize stage fills it with
the optimized logical plan, so ``PreparedStatement.execute()`` skips
parse + bind + optimize on re-execution.  Plans are parameter-generic —
``?`` placeholders stay :class:`repro.core.sql.ast.Param` nodes in the plan
and bind to values only inside ``ExecContext`` — while the *result* cache key
includes the parameter values.
"""
from __future__ import annotations

import copy
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .config_keys import PLANNING_KEYS
from .optimizer import plan as P
from .optimizer.mv_rewrite import MVRewriter
from .optimizer.rules import Optimizer, OptimizerConfig
from .optimizer.semijoin import SemijoinConfig, insert_semijoin_reducers
from .optimizer.shared_work import find_shared_subplans
from ..analysis.lockdep import make_lock
from ..analysis.plan_validator import maybe_validate_dag
from .obs.trace import QueryTrace, emit_event, make_span, tracing_enabled
from .runtime.dag import DAGScheduler, compile_dag, describe_exchanges
from .schema import annotate_plan
from .runtime.exec import MemoryPressureError
from .runtime.scheduler import stream_batch_rows
from .runtime.vector import VectorBatch
from .sql import ast as A
from .sql.binder import Binder
from .sql.parser import parse


# ===========================================================================
# prepared-statement plan cache
# ===========================================================================
# config keys that change the shape of the optimized plan; part of the cache
# key so sessions with different planning configs don't share plans.
# Derived from the central registry (repro.core.config_keys) — a key added
# there with planning=True joins the cache key automatically, so this tuple
# can no longer drift from the declared set (the REP001 invariant).
_PLANNING_KEYS = PLANNING_KEYS


@dataclass
class PlanCacheEntry:
    stmt: object                 # parsed AST (needed for re-optimization)
    plan: P.PlanNode             # pristine optimized plan (deep-copied out)
    bound_key: str               # bound-plan key = result-cache identity
    tables: List[str]            # participating tables (cache validation)
    snapshot: Dict[str, Tuple] = field(default_factory=dict)
    info: Dict[str, object] = field(default_factory=dict)  # planning info
    row_counts: Dict[str, float] = field(default_factory=dict)  # at plan time
    uses_mv: bool = False        # MV-rewritten plans validate strictly
    created_at: float = field(default_factory=time.time)
    hits: int = 0


def table_state(hms, tables) -> Dict[str, Tuple]:
    """Per-table (hwm, invalid WriteIds): the transactional identity used to
    validate both the result cache and the plan cache.  Tables the metastore
    does not know (catalog-mounted external tables, §6) have no WriteId
    state and map to a constant — the warehouse cannot observe remote
    changes, so they neither validate nor invalidate an entry."""
    snap = hms.get_snapshot()
    out: Dict[str, Tuple] = {}
    for t in tables:
        try:
            wl = hms.writeid_list(t, snap)
            out[t] = (wl.hwm, wl.invalid)
        except KeyError:
            out[t] = (0, frozenset())
    return out


def table_row_counts(hms, tables) -> Dict[str, float]:
    """Per-table optimizer row counts (the statistics plans are costed on)."""
    out = {}
    for t in tables:
        try:
            out[t] = float(hms.get_stats(t).row_count)
        except KeyError:
            out[t] = 0.0
    return out

# a cached plan's cost-based choices (join order, broadcast sides, semijoin
# reducers) are considered stale once any base table's row count shifts by
# more than this factor in either direction
PLAN_DRIFT_FACTOR = 2.0


class PlanCache:
    """Caches optimized logical plans, keyed like the query-result cache:
    by resolved statement text plus the planning-relevant session config.

    Entries are validated against the participating tables' WriteId state.
    MV-rewritten plans drop on *any* base-table write — a stale MV-scan plan
    would silently return stale data.  Plain plans only embed cost-based
    decisions (scans re-resolve data at execution time), so they survive
    writes and drop only when a table's row count drifts by more than
    ``PLAN_DRIFT_FACTOR`` from what the plan was costed on — the point at
    which join order / broadcast choices deserve re-optimization (§4.2)."""

    def __init__(self, max_entries: int = 128):
        self.max_entries = max_entries
        self._lock = make_lock("plan_cache")
        self._entries: Dict[str, PlanCacheEntry] = {}
        self.stats = {"hits": 0, "misses": 0}

    @staticmethod
    def key_of(sql: str, config: dict) -> Optional[str]:
        if not sql or not sql.strip():
            return None
        cfg = "|".join(f"{k}={config.get(k)!r}" for k in _PLANNING_KEYS)
        return f"{' '.join(sql.split())}#{cfg}"

    def get(self, key: Optional[str], hms=None) -> Optional[PlanCacheEntry]:
        if key is None:
            return None
        with self._lock:
            entry = self._entries.get(key)
        if entry is None:
            self.stats["misses"] += 1
            return None
        if hms is not None:
            current = table_state(hms, entry.snapshot)
            if current != entry.snapshot:
                if entry.uses_mv or self._drifted(hms, entry):
                    with self._lock:
                        self._entries.pop(key, None)
                    self.stats["misses"] += 1
                    return None
                # plan survives the write: adopt the new WriteId state so the
                # next hit skips the drift re-check (row_counts keeps the
                # plan-time baseline the drift factor is measured against)
                entry.snapshot = current
        entry.hits += 1
        self.stats["hits"] += 1
        return entry

    @staticmethod
    def _drifted(hms, entry: PlanCacheEntry) -> bool:
        """Did any base table's row count shift past the drift factor?"""
        try:
            current = table_row_counts(hms, entry.row_counts)
        except Exception:  # noqa: BLE001 - e.g. table vanished mid-check
            return True
        for t, base in entry.row_counts.items():
            cur = current.get(t, 0.0)
            if base <= 0.0:
                if cur > 0.0:
                    return True  # empty -> populated: unbounded drift
            elif cur > base * PLAN_DRIFT_FACTOR or cur < base / PLAN_DRIFT_FACTOR:
                return True
        return False

    def put(self, key: Optional[str], entry: PlanCacheEntry) -> None:
        if key is None:
            return
        with self._lock:
            self._entries[key] = entry
            if len(self._entries) > self.max_entries:
                victims = sorted(self._entries.items(),
                                 key=lambda kv: (kv[1].hits, kv[1].created_at))
                for k, _ in victims[: len(self._entries) - self.max_entries]:
                    self._entries.pop(k, None)

    def invalidate_all(self) -> None:
        with self._lock:
            self._entries.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


# ===========================================================================
# query context
# ===========================================================================
@dataclass
class QueryContext:
    """Typed state flowing through the pipeline stages."""

    session: object                       # repro.core.session.Session
    sql: str = ""                         # statement text ("" when unknown)
    stmt: object = None                   # parsed AST (Select | SetOp)
    params: Tuple = ()                    # qmark parameter values
    config: dict = field(default_factory=dict)
    info: dict = field(default_factory=dict)

    # planning state
    plan: Optional[P.PlanNode] = None
    plan_pretty: str = ""                 # captured before DAG compilation
    bound_key: str = ""                   # parameter-generic plan identity
    result_key: str = ""                  # + parameter values
    tables: List[str] = field(default_factory=list)
    from_plan_cache: bool = False
    plan_cache_key: Optional[str] = None

    # result-cache state
    cacheable: bool = False
    filling: bool = False

    # execution state
    exec_ctx: object = None
    dag: object = None
    batch: Optional[VectorBatch] = None

    # async-handle state (None on the synchronous path)
    task: object = None                   # runtime.scheduler.QueryTask
    slot: object = None                   # WLM slot admitted by the scheduler
    qid: str = ""                         # query id ("" -> allocate one)
    cancel_token: object = None           # runtime.cancel.CancelToken

    # observability (PR 10): the query's QueryTrace, resolved once by
    # QueryPipeline.run (None = tracing off)
    trace: object = None

    # bookkeeping
    stage_times: Dict[str, float] = field(default_factory=dict)
    finished: bool = False                # short-circuits remaining stages


# ===========================================================================
# stages
# ===========================================================================
class Stage:
    name = "stage"

    def run(self, q: QueryContext) -> None:
        raise NotImplementedError


class ParseStage(Stage):
    name = "parse"

    def run(self, q: QueryContext) -> None:
        if q.stmt is None:
            q.stmt = parse(q.sql)
        n = A.count_params(q.stmt)
        if n != len(q.params):
            raise ValueError(
                f"statement has {n} parameter placeholder(s) but "
                f"{len(q.params)} value(s) were supplied"
            )


class BindStage(Stage):
    """Name resolution + subquery unnesting; also probes the plan cache —
    a hit yields the fully optimized plan and skips MVRewrite/Optimize."""

    name = "bind"

    def run(self, q: QueryContext) -> None:
        s = q.session
        q.plan_cache_key = PlanCache.key_of(q.sql, q.config)
        entry = s.wh.plan_cache.get(q.plan_cache_key, s.hms)
        if entry is not None:
            q.plan = copy.deepcopy(entry.plan)  # Compile mutates the tree
            q.bound_key = entry.bound_key
            q.tables = list(entry.tables)
            q.from_plan_cache = True
            q.info.update(entry.info)  # mv_used / semijoin_reducers / ...
            q.info["plan_cache_hit"] = True
            return
        q.plan = Binder(s.hms, catalogs=getattr(s.wh, "catalogs", None)).bind(q.stmt)
        q.bound_key = q.plan.key()
        q.tables = [sc.table.name for sc in P.walk_plan(q.plan)
                    if isinstance(sc, (P.Scan, P.FederatedScan))]


class CacheProbeStage(Stage):
    """Query-result cache (§4.3), probed on the bound-plan identity so a hit
    skips optimization entirely.  Parameter values are part of the key."""

    name = "cache_probe"

    def run(self, q: QueryContext) -> None:
        s, cfg = q.session, q.config
        # mv_rewriting is part of the identity: an MV-rewritten execution may
        # legitimately serve stale-within-window data that a non-MV session
        # must never be handed from the cache
        q.result_key = q.bound_key + f"|mv={bool(cfg['mv_rewriting'])}" + (
            f"|params={q.params!r}" if q.params else "")
        # catalog-mounted external tables have no WriteId identity, so the
        # warehouse cannot detect remote changes: never cache their results
        # (detected from the plan — no extra metastore roundtrips)
        uses_catalog = any(
            isinstance(n, P.FederatedScan)
            and (n.table.handler or "").startswith("catalog:")
            for n in P.walk_plan(q.plan)
        )
        q.cacheable = bool(
            cfg["result_cache"] and cfg.get("serving.result_cache", True)
            and is_cacheable(q.stmt) and q.tables
            and not uses_catalog
        )
        if not q.cacheable:
            return
        hit = s.wh.result_cache.lookup(q.result_key, s.hms, q.tables)
        if hit is not None:
            q.batch = hit
            q.info["cache_hit"] = True
            q.finished = True
            return
        q.filling = s.wh.result_cache.begin_pending(q.result_key, s.hms,
                                                    q.tables)
        if not q.filling:
            # someone else is filling; wait behind their pending entry
            hit = s.wh.result_cache.lookup(q.result_key, s.hms, q.tables)
            if hit is not None:
                q.batch = hit
                q.info.update(cache_hit=True, pending_wait=True)
                q.finished = True


class MVRewriteStage(Stage):
    name = "mv_rewrite"

    def run(self, q: QueryContext) -> None:
        if q.from_plan_cache or not q.config["mv_rewriting"]:
            return
        hit = MVRewriter(q.session.hms).try_rewrite(q.plan)
        if hit is not None:
            q.plan, mv_name, mode = hit
            q.info["mv_used"] = mv_name
            q.info["mv_mode"] = mode


class OptimizeStage(Stage):
    """Rule/cost optimization + semijoin reducers + federation pushdown;
    fills the plan cache with the pristine optimized plan."""

    name = "optimize"

    def __init__(self, runtime_overrides: Optional[dict] = None):
        # §4.2 re-optimization threads captured actual cardinalities in here
        self.runtime_overrides = runtime_overrides

    def run(self, q: QueryContext) -> None:
        s, cfg = q.session, q.config
        if q.from_plan_cache:
            return
        opt = Optimizer(s.hms, optimizer_config(cfg),
                        runtime_overrides=self.runtime_overrides,
                        handler_resolver=s.wh.resolve_handler)
        q.plan = opt.optimize(q.plan)
        if cfg["semijoin_reduction"]:
            added = insert_semijoin_reducers(q.plan, opt.cost_model,
                                             SemijoinConfig(enabled=True))
            q.info["semijoin_reducers"] = added
        q.plan, pushed = s._push_federated(q.plan, cfg)
        if pushed:
            q.info["federated_pushdown"] = pushed
        if q.plan_cache_key is not None:
            planning_info = {k: q.info[k] for k in
                             ("mv_used", "mv_mode", "semijoin_reducers",
                              "federated_pushdown") if k in q.info}
            s.wh.plan_cache.put(q.plan_cache_key, PlanCacheEntry(
                stmt=q.stmt,
                plan=copy.deepcopy(q.plan),
                bound_key=q.bound_key,
                tables=list(q.tables),
                snapshot=table_state(s.hms, q.tables),
                info=planning_info,
                row_counts=table_row_counts(s.hms, q.tables),
                uses_mv="mv_used" in q.info,
            ))


class CompileStage(Stage):
    """Shared-work detection (§4.5) + Tez-style task-DAG compilation."""

    name = "compile"

    def run(self, q: QueryContext) -> None:
        s, cfg = q.session, q.config
        ctx = s._make_ctx(cfg, params=q.params, cancel_token=q.cancel_token)
        # fan federated scans out over their connectors' splits (compile
        # time so cached plans re-enumerate fresh splits per execution)
        q.plan = s._expand_federated(q.plan, cfg)
        if cfg["shared_work"]:
            # detected before partition expansion: per-partition clone keys
            # embed their lane and must never be mistaken for shared subplans
            ctx.shared_keys = find_shared_subplans(q.plan)
            q.info["shared_subplans"] = len(ctx.shared_keys)
        # partitioned shuffle service: clone pipeline-breaker consumers per
        # lane (compile time, after the plan-cache deepcopy, so cached plans
        # re-expand under the session's current shuffle.partitions); adaptive
        # compile-time decisions (co-partition shuffle elision) land in
        # q.info["adaptive"], where the runtime replanner appends later
        adaptive_events = q.info.setdefault("adaptive", [])
        q.plan = s._expand_shuffle(q.plan, cfg, events=adaptive_events)
        if not adaptive_events:
            del q.info["adaptive"]
        # (re)infer the typed schema contract on the expanded tree: EXPLAIN
        # shows per-node schemas, compile copies them onto edge placeholders
        # and the scheduler declares them on exchanges
        annotate_plan(q.plan)
        q.plan_pretty = q.plan.pretty()  # before compile_dag mutates the tree
        q.dag = compile_dag(q.plan)
        # structural validation (debug.validate_plans / REPRO_VALIDATE_PLANS):
        # catches malformed wiring — and, via the plan-cache aliasing check,
        # a compile that mutated a cached pristine plan in place
        maybe_validate_dag(q.dag, cfg, plan_cache=s.wh.plan_cache)
        q.info["dag_edges"] = q.dag.edge_summary()
        q.info["exchanges"] = [ln.strip() for ln in describe_exchanges(q.dag)]
        # observability wiring, resolved once per query: the DAG scheduler
        # propagates these onto every exchange; ExecContext.kernel and the
        # federated streamer test them per call site
        ctx.trace = q.trace
        obs = getattr(s.wh, "obs", None)
        ctx.metrics = obs.metrics if obs is not None else None
        q.exec_ctx = ctx


class ExecuteStage(Stage):
    """WLM admission (§5.2), scheduled execution (LLAP or containers),
    re-optimization on memory pressure (§4.2), result streaming to an async
    handle, result-cache fill.

    On the synchronous path this stage admits (and releases) its own WLM
    slot, raising when the pool is saturated.  On the async path the
    :class:`~repro.core.runtime.scheduler.QueryScheduler` already queued the
    handle through blocking admission and owns the slot's lifecycle; the
    stage only consumes ``q.slot``.
    """

    name = "execute"

    def run(self, q: QueryContext) -> None:
        s, cfg = q.session, q.config
        qid = q.qid or f"q{next(s.wh._qid)}"
        slot = q.slot
        own_slot = q.task is None
        try:
            if own_slot:
                with make_span(q.trace, "wlm:admission_wait", "wlm"):
                    slot = s.wh.wlm.admit(qid, cfg.get("user"),
                                          cfg.get("application"))
            if slot is not None:
                q.info["wlm_pool"] = slot.pool
            q.batch = self._run_dag(q, qid, slot)
            if q.task is not None:
                # fallback for paths that produced no live chunk stream (a
                # barrier-mode run, or a consumer that attached late): the
                # emit path already claimed the stream otherwise, so this
                # first-wins publish never double-streams
                q.task.stream.publish(q.batch, stream_batch_rows(cfg),
                                      q.cancel_token)
            if q.cacheable and q.filling:
                s.wh.result_cache.fill(q.result_key, q.batch)
            q.info["cache_hit"] = False
        finally:
            if own_slot and slot is not None:
                s.wh.wlm.release(qid)

    def _run_dag(self, q: QueryContext, qid: str, slot) -> VectorBatch:
        s, cfg, ctx = q.session, q.config, q.exec_ctx
        # adaptive execution (pipelined mode only): replan the running DAG
        # from live lane telemetry; decisions land in q.info["adaptive"]
        # (EXPLAIN ANALYZE) and stream to poll() through note_adaptive
        adaptive = None
        pipelined = bool(cfg.get("exchange.pipeline", True)) \
            and not cfg["speculative_execution"]
        if pipelined and bool(cfg.get("adaptive.enabled", True)):
            from .runtime.adaptive import AdaptiveManager

            events = q.info.setdefault("adaptive", [])
            if q.task is not None:
                for ev in events:  # compile-time decisions (elision)
                    q.task.note_adaptive(ev)
            adaptive = AdaptiveManager(
                cfg, events=events,
                on_event=(q.task.note_adaptive if q.task is not None
                          else None),
                trace=q.trace)
        sched = DAGScheduler(
            pool=s.wh.llap.executors if cfg["llap"] else None,
            speculative=cfg["speculative_execution"],
            vertex_delay=float(cfg.get("debug_vertex_delay_s", 0.0) or 0.0),
            adaptive=adaptive,
        )
        if q.task is not None:
            q.task.note_vertices_total(len(q.dag.vertices))

        def on_vertex(vid, rows, stats):
            if q.task is not None:
                q.task.note_vertex_done(vid, stats)
            if slot is not None:
                s.wh.wlm.update_metrics(qid, rows_produced=rows)
            if stats.get("lanes"):
                # per-lane row counts per partitioned edge: skew shows up in
                # EXPLAIN ANALYZE (and through poll() on the async path)
                q.info.setdefault("exchange_lanes", {})[vid] = [
                    lane["rows"] for lane in stats["lanes"]
                ]

        def on_root_chunk(chunk):
            # thread root-vertex morsels to the handle's stream while the
            # DAG is still running: first rows reach fetch_stream() before
            # upstream vertices finish
            if q.task is not None:
                q.task.stream.emit(chunk, stream_batch_rows(cfg),
                                   q.cancel_token)

        try:
            batch = sched.execute(q.dag, ctx, on_vertex_done=on_vertex,
                                  on_root_chunk=on_root_chunk)
            if not q.info.get("adaptive", True):
                del q.info["adaptive"]  # no adaptive decision fired
            s._persist_runtime_stats(q.plan, ctx)
            if any(sched.shared_scan_stats.values()):
                q.info["shared_scans"] = dict(sched.shared_scan_stats)
                if q.task is not None:
                    q.task.note_shared_scans(sched.shared_scan_stats)
            return batch
        except MemoryPressureError as mem_err:
            mode = cfg["reopt_mode"]
            if mode == "off":
                raise
            if q.task is not None:
                # a live consumer may hold a partial chunk prefix; fail the
                # stream rather than splicing re-executed output onto it
                # (result()/replay consumers get the re-executed result)
                q.task.stream.abort_live(mem_err)
            q.info["reexecuted"] = True
            q.info["reopt_mode"] = mode
            emit_event(q.trace, "reopt:reexecute", "adaptive", mode=mode)
            s._persist_runtime_stats(q.plan, ctx)
            # re-executions run with materialized (barrier) exchanges: the
            # pressure signal may have come from a spill-disabled exchange
            # overflow, which an unchanged budget would deterministically
            # hit again
            if mode == "overlay":
                # §4.2 overlay: re-run every re-execution with config overrides
                cfg2 = {**cfg, **cfg.get("overlay", {}), "reopt_mode": "off",
                        "exchange.pipeline": False}
                plan2, _ = s._plan_query(q.stmt, config=cfg2)
            else:
                # §4.2 reoptimize: feed captured actual cardinalities back in;
                # the failure also teaches the planner the broadcast budget
                cfg2 = {
                    **cfg,
                    "reopt_mode": "off",
                    "exchange.pipeline": False,
                    "broadcast_threshold_rows": min(
                        cfg["broadcast_threshold_rows"],
                        float(cfg["mapjoin_max_rows"]),
                    ),
                }
                plan2, _ = s._plan_query(
                    q.stmt, runtime_overrides=dict(ctx.op_stats), config=cfg2
                )
            ctx2 = s._make_ctx(cfg2, params=q.params,
                               cancel_token=q.cancel_token)
            ctx2.trace = q.trace
            ctx2.metrics = ctx.metrics
            plan2 = s._expand_federated(plan2, cfg2)
            if cfg2["shared_work"]:
                ctx2.shared_keys = find_shared_subplans(plan2)
            plan2 = s._expand_shuffle(plan2, cfg2)
            annotate_plan(plan2)
            dag2 = compile_dag(plan2)
            # §4.2 re-optimized plans never came from the cache, but their
            # rewritten shuffle/split wiring is exactly where structural
            # bugs would hide — validate them like first compiles
            maybe_validate_dag(dag2, cfg2, plan_cache=s.wh.plan_cache)
            if q.task is not None:
                q.task.note_vertices_total(len(dag2.vertices))
            return DAGScheduler(
                pool=s.wh.llap.executors if cfg2["llap"] else None,
                vertex_delay=float(cfg.get("debug_vertex_delay_s", 0.0) or 0.0),
            ).execute(dag2, ctx2, on_vertex_done=on_vertex)


# ===========================================================================
# the pipeline
# ===========================================================================
DEFAULT_STAGES: Tuple[Stage, ...] = (
    ParseStage(), BindStage(), CacheProbeStage(), MVRewriteStage(),
    OptimizeStage(), CompileStage(), ExecuteStage(),
)

# serving tier: the async scheduler probes the result cache *before* WLM
# admission (a hit is served without a slot and without execution), then
# resumes the same QueryContext through the remaining stages on a miss
PRE_ADMISSION_STAGES: Tuple[Stage, ...] = (
    ParseStage(), BindStage(), CacheProbeStage(),
)
POST_PROBE_STAGES: Tuple[Stage, ...] = (
    MVRewriteStage(), OptimizeStage(), CompileStage(), ExecuteStage(),
)

def plan_only_stages(runtime_overrides: Optional[dict] = None):
    """Bind + rewrite + optimize, no caches / compile / execute — the shape
    used by MV maintenance and §4.2 re-planning."""
    return (BindStage(), MVRewriteStage(), OptimizeStage(runtime_overrides))


class QueryPipeline:
    """Runs a :class:`QueryContext` through the staged query path."""

    def __init__(self, session, stages: Tuple[Stage, ...] = DEFAULT_STAGES):
        self.session = session
        self.stages = stages

    def run(self, q: QueryContext) -> QueryContext:
        # resolve the query's trace exactly once (lockdep factory pattern):
        # the async scheduler already allocated one on the QueryTask when
        # obs.tracing was on at submit; EXPLAIN ANALYZE and sync callers
        # force/enable it via config, in which case the pipeline allocates
        # (and hands the task the trace so the warehouse stores it)
        if q.trace is None and q.task is not None:
            q.trace = q.task.trace
        if q.trace is None and tracing_enabled(q.config):
            if not q.qid:
                q.qid = f"q{next(self.session.wh._qid)}"
            q.trace = QueryTrace(q.qid, q.sql)
            if q.task is not None:
                q.task.trace = q.trace
        t0 = time.perf_counter()
        try:
            for stage in self.stages:
                if q.finished:
                    break
                t = time.perf_counter()
                with make_span(q.trace, f"stage:{stage.name}", "stage"):
                    stage.run(q)
                q.stage_times[stage.name] = (
                    q.stage_times.get(stage.name, 0.0)
                    + time.perf_counter() - t
                )
        except Exception:
            if q.cacheable and q.filling:
                self.session.wh.result_cache.cancel_pending(q.result_key)
            raise
        q.info["stage_times_ms"] = {
            k: round(v * 1e3, 3) for k, v in q.stage_times.items()
        }
        q.info["seconds"] = time.perf_counter() - t0
        return q


def optimizer_config(cfg: dict) -> OptimizerConfig:
    return OptimizerConfig(
        cbo=cfg["cbo"],
        pushdown=cfg["pushdown"],
        prune_columns=cfg["prune_columns"],
        join_reorder=cfg["join_reorder"],
        transitive_inference=cfg["transitive_inference"],
        broadcast_threshold_rows=cfg["broadcast_threshold_rows"],
        partition_pruning=cfg["partition_pruning"],
    )


def is_cacheable(stmt) -> bool:
    """No non-deterministic or runtime-constant functions (§4.3)."""
    bad = A.NON_DETERMINISTIC_FUNCS | A.RUNTIME_CONSTANT_FUNCS

    def scan_sel(s) -> bool:
        if isinstance(s, A.SetOp):
            return scan_sel(s.left) and scan_sel(s.right)
        if not isinstance(s, A.Select):
            return True
        exprs = [e for e, _ in s.projections]
        exprs += [x for x in (s.where, s.having) if x is not None]
        exprs += [e for e, _ in s.order_by] + list(s.group_by)
        for e in exprs:
            for node in A.walk(e):
                if isinstance(node, A.Func) and node.name in bad:
                    return False
                if isinstance(node, A.SubqueryExpr) and not scan_sel(node.query):
                    return False
        if isinstance(s.from_, A.SubqueryRef) and not scan_sel(s.from_.query):
            return False
        return True

    return scan_sel(stmt)
