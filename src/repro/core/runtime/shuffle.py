"""Partitioned shuffle service (paper §4/§5: MPP-style shuffle edges).

Tez fans a SHUFFLE edge out across executors: the producer hash-partitions
its output on the consumer's keys and every downstream task owns one
partition, so pipeline breakers (join build+probe, grouped aggregation,
DISTINCT state) scale with workers instead of running on one lane.  This
module is that layer for our DAG runtime:

  * :func:`expand_shuffle_partitions` — compile-time plan transform: every
    eligible pipeline-breaker consumer (shuffle hash join, grouped
    aggregation, global DISTINCT aggregate) is cloned once per partition;
    each clone reads one :class:`~repro.core.optimizer.plan.ShuffleRead`
    lane of the shared producer subtree and the clones merge back through a
    UNION ALL (or a merging-fold Aggregate for global partials);
  * :class:`ShuffleWriter` — the producer side of a partitioned edge: a
    lane array of spill-aware :class:`Exchange` buffers, each with its own
    slice of the edge budget.  Every morsel is bucket-assigned by the
    ``hash_partition`` kernel (``engine: pallas|ref``; the numpy host path
    computes the identical hash bit-for-bit) and routed to its lane;
  * :func:`partition_select` — the barrier-mode equivalent (filter a
    materialized batch down to one partition).

Partition count comes from the ``shuffle.partitions`` session config
(``auto`` derives it from CBO row estimates); per-lane rows/bytes/spill are
surfaced through ``stats()['lanes']`` into ``poll()`` so skew is observable,
and every lane inherits the exchange cancel/spill semantics, keeping
kill latency bounded by one morsel.
"""
from __future__ import annotations

import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..optimizer import plan as P
from ..sql import ast as A
from .exchange import Exchange, ExchangeConfig
from .exec import _FOLD_FN
from .vector import VectorBatch

# auto mode: one lane per this many estimated input rows, capped at the
# host's core count (lanes beyond the cores just pay routing overhead).
# Both thresholds are declared config keys (shuffle.auto_rows_per_partition
# / shuffle.auto_scan_fed_rows_per_partition) — the module constants are
# only the registry defaults' mirrors for callers without a config.
AUTO_ROWS_PER_PARTITION = 32_768
AUTO_MAX_PARTITIONS = 8


def auto_partition_cap() -> int:
    import os

    return max(2, min(AUTO_MAX_PARTITIONS, os.cpu_count() or 4))

# mirror of the kernel constants (repro.kernels.hash_partition)
_FNV_PRIME = np.uint32(16777619)
_MIX1 = np.uint32(0x7FEB352D)
_MIX2 = np.uint32(0x846CA68B)


# ===========================================================================
# bucket assignment
# ===========================================================================
def _numeric_words(col: np.ndarray) -> np.ndarray:
    """Canonical uint32 hash word per value: the float32 bit pattern (with
    -0.0 normalized), so equal values agree across int/float sides and
    across the kernel and host paths."""
    v = col.astype(np.float32) + np.float32(0.0)
    return np.ascontiguousarray(v).view(np.uint32)


def _string_words(col: np.ndarray) -> np.ndarray:
    s = col.astype(str)
    uniq, inv = np.unique(s, return_inverse=True)
    words = np.fromiter((zlib.crc32(u.encode("utf-8")) for u in uniq),
                        dtype=np.uint32, count=len(uniq))
    return words[inv]


def _avalanche(h: np.ndarray) -> np.ndarray:
    h = h ^ (h >> np.uint32(16))
    h = h * _MIX1
    h = h ^ (h >> np.uint32(15))
    h = h * _MIX2
    return h ^ (h >> np.uint32(16))


def partition_codes(batch: VectorBatch, keys: Sequence[str],
                    num_partitions: int, engine: str = "auto") -> np.ndarray:
    """Bucket id in ``[0, num_partitions)`` per row of ``batch``.

    Under ``engine: pallas|ref`` all-numeric key sets dispatch through the
    ``hash_partition`` kernel; the numpy path computes the identical hash,
    so lanes agree even when one edge of a join is kernel-shaped and the
    other is not.
    """
    n = batch.num_rows
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    cols = [batch.cols[k] for k in keys]
    if engine != "auto" and all(c.dtype.kind in "iufb" for c in cols):
        from ...kernels.registry import resolve

        fn = resolve("hash_partition", engine)
        f32 = tuple(c.astype(np.float32) for c in cols)
        return np.asarray(fn(f32, int(num_partitions))).astype(np.int64)
    h = np.zeros(n, dtype=np.uint32)
    for c in cols:
        words = (_numeric_words(c) if c.dtype.kind in "iufb"
                 else _string_words(c))
        h = h * _FNV_PRIME ^ words
    h = _avalanche(h)
    return (h % np.uint32(num_partitions)).astype(np.int64)


def partition_select(batch: VectorBatch, keys: Sequence[str], partition: int,
                     num_partitions: int, engine: str = "auto") -> VectorBatch:
    """Rows of ``batch`` that belong to ``partition`` (barrier mode)."""
    if batch.num_rows == 0 or num_partitions <= 1:
        return batch
    codes = partition_codes(batch, keys, num_partitions, engine)
    return batch.select(codes == partition)


# ===========================================================================
# the producer side of a partitioned edge
# ===========================================================================
class ShuffleWriter:
    """Duck-types the scheduler's producer-side :class:`Exchange` surface
    (``put``/``close``/``stats``/``discard``/``retain``) over N per-partition
    lanes, hash-routing every morsel as it streams through.

    Routed rows are *coalesced* per lane up to ``batch_rows`` before they hit
    the lane exchange: naive routing would hand every consumer clone N×
    more, N×-smaller morsels, multiplying the per-morsel operator overhead
    that full-size morsels amortize."""

    def __init__(self, tag: str, cfg: ExchangeConfig, num_partitions: int,
                 keys: Sequence[str], engine: str = "auto",
                 batch_rows: int = 8192):
        self.tag = tag
        self.cfg = cfg
        self.num_partitions = int(num_partitions)
        self.keys = list(keys)
        self.engine = engine
        self.batch_rows = max(int(batch_rows), 1)
        # every lane owns a full edge budget (the Tez per-partition buffer
        # model): a hot lane under key skew spills on its own budget without
        # starving siblings, and per-lane spill counters expose exactly which
        # lane went hot
        self.lanes = [
            Exchange(f"{tag}.p{i}", cfg,
                     buffer_rows=cfg.buffer_rows,
                     buffer_bytes=cfg.buffer_bytes)
            for i in range(self.num_partitions)
        ]
        self._proto: Optional[VectorBatch] = None
        self._seen = [False] * self.num_partitions
        self._pending: List[List[VectorBatch]] = [
            [] for _ in range(self.num_partitions)
        ]
        self._pending_rows = [0] * self.num_partitions
        # adaptive execution: a lane split mid-stream by the hot-lane
        # mitigation routes its *remaining* rows round-robin over fresh
        # sub-lane exchanges (round-robin, not sub-hash: a single hot key
        # would land every row in one sub-hash bucket).  Splits happen on
        # the producer thread only (inside the put -> on_progress callback),
        # so routing state needs no lock; consumers address sub-lanes
        # through :meth:`sub_lane_reader` global indices.
        self._subs: List[Exchange] = []
        self._split: Dict[int, Tuple[int, int]] = {}  # lane -> (start, ways)
        self._rr: Dict[int, int] = {}
        self.on_progress = None  # callable(writer) | None, set by adaptive
        # declared edge schema — shared by every lane (and propagated to
        # adaptive sub-lane exchanges as they are created)
        self.schema = None

    def declare_schema(self, schema) -> None:
        self.schema = schema
        for lane in self.lanes:
            lane.declare_schema(schema)
        for ex in self._subs:
            ex.declare_schema(schema)

    # ------------------------------------------------------------ producer
    def put(self, batch: VectorBatch) -> None:
        if self._proto is None:
            self._proto = batch.slice(0, 0)
        if batch.num_rows == 0:
            return  # lanes get a schema-carrying empty morsel at close()
        codes = partition_codes(batch, self.keys, self.num_partitions,
                                self.engine)
        for p in range(self.num_partitions):
            part = batch.select(codes == p)
            if not part.num_rows:
                continue
            split = self._split.get(p)
            if split is not None:
                start, ways = split
                j = start + self._rr[p] % ways
                self._rr[p] += 1
                self._subs[j].put(part)
                continue
            self._pending[p].append(part)
            self._pending_rows[p] += part.num_rows
            if self._pending_rows[p] >= self.batch_rows:
                self._flush(p)
        if self.on_progress is not None:
            # adaptive telemetry hook: runs on the producer thread so a
            # split decision mutates routing state without a lock
            self.on_progress(self)

    def _flush(self, p: int) -> None:
        parts = self._pending[p]
        if not parts:
            return
        self._pending[p] = []
        self._pending_rows[p] = 0
        self.lanes[p].put(parts[0] if len(parts) == 1
                          else VectorBatch.concat(parts))
        self._seen[p] = True

    def split_lane(self, p: int, ways: int) -> List[int]:
        """Split lane ``p``'s *remaining* stream across ``ways`` fresh
        sub-lane exchanges (hot-lane skew mitigation).

        Producer-thread only.  The already-buffered prefix stays in lane
        ``p`` (its exchange closes now, bounding the original consumer),
        and every subsequent routed morsel round-robins over the sub-lanes.
        Returns the global sub-lane indices for :meth:`sub_lane_reader`."""
        assert p not in self._split and 0 <= p < self.num_partitions
        ways = max(int(ways), 2)
        self._flush(p)
        if not self._seen[p] and self._proto is not None:
            self.lanes[p].put(self._proto)
            self._seen[p] = True
        self.lanes[p].close()
        start = len(self._subs)
        for j in range(ways):
            ex = Exchange(f"{self.tag}.p{p}.s{j}", self.cfg,
                          buffer_rows=self.cfg.buffer_rows,
                          buffer_bytes=self.cfg.buffer_bytes)
            ex.retain = False  # exactly one adaptive consumer per sub-lane
            ex.declare_schema(self.schema)
            self._subs.append(ex)
        self._split[p] = (start, ways)
        self._rr[p] = 0
        return list(range(start, start + ways))

    def close(self, error: Optional[BaseException] = None) -> None:
        if error is None:
            for p in range(self.num_partitions):
                if p not in self._split:
                    self._flush(p)
            if self._proto is not None:
                # operators downstream rely on at least one (possibly empty)
                # schema-carrying morsel per stream
                for p, seen in enumerate(self._seen):
                    if not seen:
                        self.lanes[p].put(self._proto)
                for ex in self._subs:
                    if ex.total_rows == 0:
                        ex.put(self._proto)
        for lane in self.lanes:
            lane.close(error=error)
        for ex in self._subs:
            ex.close(error=error)

    # ------------------------------------------------------------ consumers
    def lane_reader(self, partition: int):
        return self.lanes[partition].reader()

    def sub_lane_reader(self, idx: int):
        """Reader over one adaptive sub-lane created by :meth:`split_lane`."""
        return self._subs[idx].reader()

    def reader(self):
        """Full-stream replay (lane by lane) for an unpartitioned consumer
        sharing this producer (shared-work reuse); row order across lanes is
        not the producer order, which UNION ALL semantics tolerate."""
        for lane in self.lanes:
            yield from lane.reader()

    def read_all(self) -> VectorBatch:
        chunks = [b for lane in self.lanes for b in lane.reader()]
        return VectorBatch.concat(chunks) if chunks else VectorBatch({})

    # ------------------------------------------------------------ lifecycle
    @property
    def retain(self) -> bool:
        return any(lane.retain for lane in self.lanes)

    @retain.setter
    def retain(self, value: bool) -> None:
        for lane in self.lanes:
            lane.retain = value

    def configure_retention(self, lane_readers: List[int],
                            full_readers: int) -> None:
        """Single-reader lanes free chunks as consumed, like single-consumer
        FORWARD edges; a full-stream reader forces retention everywhere."""
        for p, lane in enumerate(self.lanes):
            lane.retain = full_readers > 0 or lane_readers[p] != 1

    def lane_rows(self) -> List[int]:
        """Live per-lane routed row counts (pending + exchanged), including
        sub-lane rows credited to their parent lane — the adaptive layer's
        skew signal."""
        rows = [lane.total_rows + self._pending_rows[p]
                for p, lane in enumerate(self.lanes)]
        for p, (start, ways) in list(self._split.items()):
            rows[p] += sum(self._subs[j].total_rows
                           for j in range(start, start + ways))
        return rows

    def stats(self) -> Dict[str, object]:
        per_lane = [lane.stats() for lane in self.lanes]
        per_sub = [ex.stats() for ex in list(self._subs)]
        agg = {
            "rows": sum(s["rows"] for s in per_lane + per_sub),
            "spilled_rows": sum(s["spilled_rows"] for s in per_lane + per_sub),
            "spilled_bytes": sum(s["spilled_bytes"]
                                 for s in per_lane + per_sub),
            "spilled_chunks": sum(s["spilled_chunks"]
                                  for s in per_lane + per_sub),
            "peak_buffered_rows": sum(s["peak_buffered_rows"]
                                      for s in per_lane + per_sub),
            "freed_chunks": sum(s["freed_chunks"] for s in per_lane + per_sub),
        }
        agg["lanes"] = [
            {"rows": s["rows"], "spilled_rows": s["spilled_rows"],
             "spilled_bytes": s["spilled_bytes"]}
            for s in per_lane
        ]
        if self._split:
            agg["splits"] = {p: ways
                             for p, (_, ways) in sorted(self._split.items())}
        return agg

    def discard(self) -> None:
        for lane in self.lanes:
            lane.discard()
        for ex in list(self._subs):
            ex.discard()


# ===========================================================================
# compile-time partition expansion
# ===========================================================================
# how a per-partition partial folds in the global merging Aggregate — the
# executor's incremental-merge map (COUNT partials re-combine with SUM)
_MERGE_FOLD = _FOLD_FN


# lane-payoff threshold for *scan-fed* consumers (BENCH_PR5 regression
# fix): when the consumer's input is a pure scan pipeline, the single-lane
# plan fuses the scan straight into the consumer vertex with no exchange at
# all — fanning out then ADDS a routing hop, which only pays off once the
# per-lane share of work is much larger than for consumers that already sit
# behind a SHUFFLE edge
AUTO_SCAN_FED_ROWS_PER_PARTITION = 262_144


def resolve_partition_count(cfg_value, est_rows: Optional[float],
                            rows_per_partition: int = AUTO_ROWS_PER_PARTITION
                            ) -> int:
    """``shuffle.partitions``: an int, or ``auto`` (CBO-derived)."""
    if cfg_value in (None, "", 0, 1, "1"):
        return 1
    if cfg_value == "auto":
        if not est_rows or est_rows <= rows_per_partition:
            return 1
        n = int(-(-est_rows // rows_per_partition))  # ceil
        return max(1, min(n, auto_partition_cap()))
    return max(int(cfg_value), 1)


def _scan_fed(node: P.PlanNode) -> bool:
    """True when ``node``'s subtree is a pure scan pipeline (no pipeline
    breaker below), i.e. a single-lane plan would fuse it into the consumer
    vertex without any exchange."""
    breakers = (P.Join, P.Aggregate, P.Sort, P.Union, P.WindowOp,
                P.FederatedScan, P.ShuffleRead)
    return not any(isinstance(n, breakers) for n in P.walk_plan(node))


def _expandable_join(node: P.PlanNode) -> bool:
    return (isinstance(node, P.Join) and node.strategy == "shuffle"
            and node.kind in ("inner", "left", "full", "semi", "anti")
            and bool(node.left_keys))


def _distinct_partition_col(node: P.Aggregate) -> Optional[str]:
    """For a *global* aggregate with DISTINCT specs: the single column every
    DISTINCT argument references (the partitioning key), or None."""
    col = None
    for s in node.aggs:
        if not s.distinct:
            continue
        if not isinstance(s.arg, A.Col):
            return None
        if col is not None and s.arg.qualified != col:
            return None
        col = s.arg.qualified
    return col


def _copartition_lanes(agg: P.Aggregate,
                       union: P.PlanNode) -> Optional[List[P.PlanNode]]:
    """Lane-join list when ``agg`` can reuse ``union``'s shuffle lanes.

    ``union`` is the already-expanded lane Union of a shuffle join.  When
    the aggregate's group keys cover the join's shuffle keys on a side
    whose rows survive the join intact, every group lives wholly inside
    one lane (same shuffle-key values -> same hash -> same lane, including
    null-extended outer rows), so the aggregate can run per-lane on the
    join's lanes and elide its own shuffle hop entirely."""
    if not (isinstance(union, P.Union) and union.all
            and len(union.inputs) >= 2):
        return None
    lanes = union.inputs
    gk = set(agg.group_keys)
    for i, j in enumerate(lanes):
        if not (isinstance(j, P.Join) and j.strategy == "shuffle"
                and isinstance(j.left, P.ShuffleRead)
                and isinstance(j.right, P.ShuffleRead)
                and j.left.partition == i
                and j.left.num_partitions == len(lanes)
                and j.right.partition == i
                and j.right.num_partitions == len(lanes)):
            return None
        # coverage must come from a side whose key columns reach the join
        # output unmodified: the left side for every supported kind (outer
        # rows keep their left keys), the right side only for inner joins
        left_cover = (set(j.left_keys) <= gk
                      and j.kind in ("inner", "left", "semi", "anti"))
        right_cover = set(j.right_keys) <= gk and j.kind == "inner"
        if not (left_cover or right_cover):
            return None
    return list(lanes)


def expand_shuffle_partitions(plan: P.PlanNode, config: dict,
                              cost_model=None,
                              events: Optional[list] = None) -> P.PlanNode:
    """Clone pipeline-breaker consumers per partition (compile time).

    Runs after federated split expansion and after shared-work detection —
    clone keys embed their ``ShuffleRead`` lane, so clones are never
    mistaken for shared subplans.  Runtime-filter producer subtrees are left
    untouched (they execute inline inside scan vertices).

    Compile-time adaptive decisions (co-partition shuffle elision) are
    appended to ``events`` so they surface in ``poll()["adaptive"]`` and
    EXPLAIN ANALYZE alongside the runtime ones.
    """
    cfg_value = config.get("shuffle.partitions", 1)
    if cfg_value in (None, "", 0, 1, "1"):
        return plan
    auto_rows = int(config.get("shuffle.auto_rows_per_partition",
                               AUTO_ROWS_PER_PARTITION))
    auto_scan_fed = int(config.get("shuffle.auto_scan_fed_rows_per_partition",
                                   AUTO_SCAN_FED_ROWS_PER_PARTITION))
    elide = bool(config.get("adaptive.elide_copartition", True))
    replaced: Dict[int, P.PlanNode] = {}
    visited: set = set()

    def partitions_for(node: P.PlanNode) -> Tuple[int, Optional[float]]:
        """(lane count, CBO row estimate the count was derived from)."""
        if cfg_value != "auto":
            return resolve_partition_count(cfg_value, None), None
        if cost_model is None:
            return 1, None
        try:
            if isinstance(node, P.Join):
                rows = max(cost_model.estimate(node.left).rows,
                           cost_model.estimate(node.right).rows)
            else:
                rows = cost_model.estimate(node.inputs[0]).rows
        except Exception:  # noqa: BLE001 - estimation must never break compile
            return 1, None
        # scan-fed consumers (aggregate/DISTINCT straight over a scan) pay
        # for an exchange hop the single-lane plan doesn't have: demand a
        # much larger per-lane share before fanning out (the BENCH_PR5
        # partitioned-DISTINCT regression)
        per_lane = auto_rows
        if not isinstance(node, P.Join) and _scan_fed(node.inputs[0]):
            per_lane = auto_scan_fed
        return resolve_partition_count("auto", rows,
                                       rows_per_partition=per_lane), rows

    def expand(node: P.PlanNode) -> Optional[P.PlanNode]:
        if isinstance(node, P.Join) and _expandable_join(node):
            n, rows = partitions_for(node)
            if n <= 1:
                return None
            left, right = node.left, node.right
            clones: List[P.PlanNode] = []
            for p in range(n):
                clones.append(P.Join(
                    P.ShuffleRead(left, node.left_keys, p, n,
                                  est_rows=rows),
                    P.ShuffleRead(right, node.right_keys, p, n,
                                  est_rows=rows),
                    node.kind, list(node.left_keys), list(node.right_keys),
                    residual=node.residual, strategy="shuffle",
                ))
            return P.Union(clones, all=True)
        if isinstance(node, P.Aggregate) and node.grouping_sets is None:
            source = node.input
            if node.group_keys:
                if elide:
                    # co-partition elision: the input was already expanded
                    # (post-order visit) — if it is the lane Union of a
                    # shuffle join whose keys the group keys cover, reuse
                    # those lanes and skip this aggregate's own shuffle hop.
                    # A pruning Project between the aggregate and the join
                    # is pushed into each lane (column projection is
                    # per-row, so it commutes with the lane partition).
                    inner, wrap = source, None
                    if isinstance(inner, P.Project) and all(
                            isinstance(e, A.Col) and e.qualified == name
                            for e, name in inner.exprs):
                        # identity pruning only: a renaming projection would
                        # break the key-name coverage check below
                        inner, wrap = inner.input, inner
                    lanes = _copartition_lanes(node, inner)
                    if lanes is not None:
                        if events is not None:
                            events.append({
                                "kind": "elided_shuffle",
                                "at": "compile",
                                "lanes": len(lanes),
                                "group_keys": list(node.group_keys),
                                "join_keys": list(lanes[0].left_keys),
                            })
                        if wrap is not None:
                            lanes = [P.Project(lane, list(wrap.exprs))
                                     for lane in lanes]
                        return P.Union(
                            [P.Aggregate(lane, list(node.group_keys),
                                         list(node.aggs))
                             for lane in lanes],
                            all=True)
                # groups are disjoint across lanes: UNION ALL merges exactly
                n, rows = partitions_for(node)
                if n <= 1:
                    return None
                clones = [
                    P.Aggregate(
                        P.ShuffleRead(source, node.group_keys, p, n,
                                      est_rows=rows),
                        list(node.group_keys), list(node.aggs))
                    for p in range(n)
                ]
                return P.Union(clones, all=True)
            dcol = _distinct_partition_col(node)
            if dcol is not None and all(s.fn in _MERGE_FOLD
                                        for s in node.aggs):
                # global DISTINCT: partition on the DISTINCT argument so each
                # lane owns a disjoint value range; per-lane partials fold in
                # a global merging Aggregate (COUNT partials re-SUM)
                n, rows = partitions_for(node)
                if n <= 1:
                    return None
                clones = [
                    P.Aggregate(P.ShuffleRead(source, [dcol], p, n,
                                              est_rows=rows),
                                [], list(node.aggs))
                    for p in range(n)
                ]
                folds = [
                    P.AggSpec(_MERGE_FOLD[s.fn], A.Col(s.out_name), False,
                              s.out_name)
                    for s in node.aggs
                ]
                return P.Aggregate(P.Union(clones, all=True), [], folds)
        return None

    def visit(node: P.PlanNode) -> P.PlanNode:
        if id(node) in replaced:
            return replaced[id(node)]
        if id(node) in visited:
            return node
        visited.add(id(node))
        node.inputs = [visit(c) for c in node.inputs]
        new = expand(node)
        if new is not None:
            replaced[id(node)] = new
            return new
        return node

    return visit(plan)
