"""Cooperative cancellation for in-flight queries.

A :class:`CancelToken` travels with a query from the client handle through
``QueryContext``/``ExecContext`` into the DAG scheduler; vertex boundaries
(and the WLM admission wait) poll it.  Two trip kinds exist, because the
paper distinguishes them (§5.2): a *cancel* originates from the client
(``QueryHandle.cancel()``) and surfaces as :class:`QueryCancelledError`,
while a *kill* originates from a workload-manager trigger rule and surfaces
as :class:`repro.core.runtime.wlm.QueryKilledError`.
"""
from __future__ import annotations

import threading
from typing import Optional

from ...analysis.lockdep import make_lock
from .wlm import QueryKilledError


class QueryCancelledError(Exception):
    """The query was cancelled by the client before it completed."""


class CancelToken:
    """Thread-safe, single-trip cancellation flag (first trip wins)."""

    def __init__(self):
        self._event = threading.Event()
        self._lock = make_lock("cancel_token")
        self.reason: str = ""
        self.kind: Optional[str] = None  # 'cancel' | 'kill'

    def cancel(self, reason: str = "cancelled by client") -> None:
        self._trip("cancel", reason)

    def kill(self, reason: str = "killed by workload manager") -> None:
        self._trip("kill", reason)

    def _trip(self, kind: str, reason: str) -> None:
        with self._lock:
            if self.kind is None:
                self.kind = kind
                self.reason = reason
        self._event.set()

    def is_set(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._event.wait(timeout)

    def check(self) -> None:
        """Raise at a cancellation point if the token has tripped."""
        if not self._event.is_set():
            return
        if self.kind == "kill":
            raise QueryKilledError(self.reason)
        raise QueryCancelledError(self.reason)
