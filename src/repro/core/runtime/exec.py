"""Vectorized plan execution (paper §5, [39]).

A pipelined interpreter over `VectorBatch`es.  Every operator is vectorized:
expressions evaluate to whole numpy column vectors; joins/aggregations use
factorized key codes.  When the session enables the JAX path
(``vectorized_jax``), predicate evaluation and grouped aggregation are routed
through the jitted kernels in ``repro.kernels`` (Pallas on TPU, interpret
mode on CPU).

The executor also:
  * records per-operator actual cardinalities (for §4.2 re-optimization),
  * honors shared-work results (§4.5) via a per-query subplan cache,
  * enforces a broadcast-join memory budget, raising ``MemoryPressureError``
    to exercise the re-optimization path (§4.2).
"""
from __future__ import annotations

import re as _re
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..acid import AcidTable
from ..bloomfilter import BloomFilter
from ..metastore import Metastore, Snapshot, WriteIdList
from ..obs.trace import make_span
from ..optimizer import plan as P
from ..sql import ast as A
from ..storage import SargPredicate
from .vector import DEFAULT_BATCH_ROWS, ROWID_COL, WRITEID_COL, VectorBatch


class ExecError(Exception):
    pass


class MemoryPressureError(ExecError):
    """Simulates the runtime errors (§4.2) that trigger re-optimization."""


class ExecContext:
    def __init__(
        self,
        hms: Metastore,
        snapshot: Snapshot,
        config: Optional[dict] = None,
        io=None,
        handlers=None,
        params: Tuple = (),
        cancel_token=None,
    ):
        self.hms = hms
        self.snapshot = snapshot
        self.config = config or {}
        self.io = io
        self.handlers = handlers or {}
        self.params = tuple(params)  # qmark placeholder values, by ordinal
        self.cancel_token = cancel_token  # CancelToken of an async handle
        # serving tier: SharedScanRegistry when serving.shared_scans is on
        self.shared_scans = None
        # observability (PR 10), resolved once per query by the execute
        # stage: the query's QueryTrace (None = tracing off) and the
        # warehouse MetricsRegistry — instrumented paths pay one attribute
        # test when off
        self.trace = None
        self.metrics = None
        self.engine = self.config.get("engine", "auto")  # auto | pallas | ref
        self.op_stats: Dict[str, int] = {}  # plan key digest -> actual rows
        self.shared_keys: set = set()  # filled by shared-work optimizer (§4.5)
        self.subplan_cache: Dict[str, VectorBatch] = {}
        self.runtime_filter_cache: Dict[str, dict] = {}
        self._widlists: Dict[str, WriteIdList] = {}

    def widlist(self, table: str) -> WriteIdList:
        if table not in self._widlists:
            self._widlists[table] = self.hms.writeid_list(table, self.snapshot)
        return self._widlists[table]

    def record(self, node: P.PlanNode, rows: int) -> None:
        self.op_stats[node.digest()] = rows

    def kernel(self, name: str):
        """Resolve a compute kernel for this query's engine selection."""
        from ...kernels.registry import resolve

        if self.trace is not None:
            self.trace.kernel_dispatch(name, self.engine)
        return resolve(name, self.engine)


# ===========================================================================
# expression evaluation
# ===========================================================================
_NULL_STR = ""


def _lookup(batch: VectorBatch, col: A.Col) -> np.ndarray:
    key = col.qualified
    if key in batch.cols:
        return batch.cols[key]
    if col.table is None:
        # unqualified: match unique suffix
        hits = [k for k in batch.cols if k == col.name or k.endswith("." + col.name)]
        if len(hits) == 1:
            return batch.cols[hits[0]]
        if len(hits) > 1:
            raise ExecError(f"ambiguous column {col.name}: {hits}")
    raise ExecError(f"column {key} not found in {list(batch.cols)[:12]}...")


def _broadcast(value, n: int) -> np.ndarray:
    if value is None:
        return np.full(n, np.nan)
    if isinstance(value, bool):
        return np.full(n, value, dtype=bool)
    if isinstance(value, int):
        return np.full(n, value, dtype=np.int64)
    if isinstance(value, float):
        return np.full(n, value, dtype=np.float64)
    return np.full(n, value, dtype=f"U{max(len(str(value)), 1)}")


def _is_null_mask(v: np.ndarray) -> np.ndarray:
    if v.dtype.kind == "f":
        return np.isnan(v)
    if v.dtype.kind in ("U", "S"):
        return v == _NULL_STR if False else np.zeros(len(v), dtype=bool)
    return np.zeros(len(v), dtype=bool)


def _like_to_regex(pattern: str) -> str:
    out = []
    for ch in pattern:
        if ch == "%":
            out.append(".*")
        elif ch == "_":
            out.append(".")
        else:
            out.append(_re.escape(ch))
    return "^" + "".join(out) + "$"


_SCALAR_FUNCS = {}


def scalar_fn(name):
    def deco(f):
        _SCALAR_FUNCS[name] = f
        return f
    return deco


@scalar_fn("abs")
def _f_abs(args):
    return np.abs(args[0])


@scalar_fn("floor")
def _f_floor(args):
    return np.floor(args[0])


@scalar_fn("ceil")
def _f_ceil(args):
    return np.ceil(args[0])


@scalar_fn("round")
def _f_round(args):
    d = int(args[1][0]) if len(args) > 1 else 0
    return np.round(args[0], d)


@scalar_fn("lower")
def _f_lower(args):
    return np.char.lower(args[0].astype(str))


@scalar_fn("upper")
def _f_upper(args):
    return np.char.upper(args[0].astype(str))


@scalar_fn("length")
def _f_length(args):
    return np.char.str_len(args[0].astype(str)).astype(np.int64)


@scalar_fn("substr")
def _f_substr(args):
    start = int(args[1][0]) - 1
    ln = int(args[2][0]) if len(args) > 2 else None
    s = args[0].astype(str)
    return np.array([x[start:start + ln] if ln else x[start:] for x in s])


@scalar_fn("coalesce")
def _f_coalesce(args):
    out = args[0].copy()
    for nxt in args[1:]:
        m = _is_null_mask(out) | (np.isnan(out) if out.dtype.kind == "f" else False)
        out = np.where(m, nxt, out)
    return out


@scalar_fn("extract")
def _f_extract(args):  # extract(year, datestr) simplified
    part = args[0]
    vals = args[1].astype(str)
    idx = {"year": slice(0, 4), "month": slice(5, 7), "day": slice(8, 10)}[str(part[0]).lower()]
    return np.array([int(v[idx]) if len(v) >= 10 else -1 for v in vals], dtype=np.int64)


@scalar_fn("year")
def _f_year(args):
    return np.array([int(str(v)[:4]) if len(str(v)) >= 4 else -1 for v in args[0]],
                    dtype=np.int64)


def eval_expr(e: A.Expr, batch: VectorBatch, ctx: Optional[ExecContext] = None) -> np.ndarray:
    n = batch.num_rows
    if isinstance(e, A.Col):
        return _lookup(batch, e)
    if isinstance(e, A.Lit):
        return _broadcast(e.value, n)
    if isinstance(e, A.Param):
        if ctx is None:
            raise ExecError(f"parameter ?{e.index} outside an execution context")
        if e.index >= len(ctx.params):
            raise ExecError(
                f"unbound parameter ?{e.index}: only {len(ctx.params)} "
                "parameter value(s) supplied"
            )
        return _broadcast(ctx.params[e.index], n)
    if isinstance(e, A.BinOp):
        if e.op == "AND":
            l = eval_expr(e.left, batch, ctx).astype(bool)
            if not l.any():
                return l
            r = eval_expr(e.right, batch, ctx).astype(bool)
            return l & r
        if e.op == "OR":
            l = eval_expr(e.left, batch, ctx).astype(bool)
            r = eval_expr(e.right, batch, ctx).astype(bool)
            return l | r
        l = eval_expr(e.left, batch, ctx)
        r = eval_expr(e.right, batch, ctx)
        if e.op == "LIKE":
            rx = _re.compile(_like_to_regex(str(r[0]) if len(r) else ""))
            return np.array([bool(rx.match(str(x))) for x in l])
        if e.op == "||":
            return np.char.add(l.astype(str), r.astype(str))
        if l.dtype.kind in ("U", "S") or r.dtype.kind in ("U", "S"):
            l, r = l.astype(str), r.astype(str)
        ops = {
            "+": np.add, "-": np.subtract, "*": np.multiply,
            "%": np.mod,
            "=": np.equal, "!=": np.not_equal,
            "<": np.less, "<=": np.less_equal,
            ">": np.greater, ">=": np.greater_equal,
        }
        if e.op == "/":
            with np.errstate(divide="ignore", invalid="ignore"):
                return np.divide(l.astype(np.float64), r.astype(np.float64))
        return ops[e.op](l, r)
    if isinstance(e, A.UnOp):
        v = eval_expr(e.operand, batch, ctx)
        return ~v.astype(bool) if e.op == "NOT" else -v
    if isinstance(e, A.Func):
        if e.name in _SCALAR_FUNCS:
            args = [eval_expr(a, batch, ctx) for a in e.args]
            return _SCALAR_FUNCS[e.name](args)
        raise ExecError(f"unknown scalar function {e.name}")
    if isinstance(e, A.Case):
        result = None
        assigned = np.zeros(n, dtype=bool)
        for cond, val in e.whens:
            m = eval_expr(cond, batch, ctx).astype(bool) & ~assigned
            v = eval_expr(val, batch, ctx)
            if result is None:
                result = np.zeros(n, dtype=v.dtype) if v.dtype.kind != "U" else np.full(n, "", dtype=f"U64")
                if v.dtype.kind == "f" or result.dtype.kind == "f":
                    result = result.astype(np.float64) + np.nan
            result = np.where(m, v, result)
            assigned |= m
        if e.otherwise is not None:
            v = eval_expr(e.otherwise, batch, ctx)
            result = np.where(~assigned, v, result)
        return result
    if isinstance(e, A.InList):
        v = eval_expr(e.expr, batch, ctx)
        vals = [x.value for x in e.values]  # type: ignore
        if v.dtype.kind in ("U", "S"):
            vals = [str(x) for x in vals]
        m = np.isin(v, np.array(vals))
        return ~m if e.negated else m
    if isinstance(e, A.Between):
        v = eval_expr(e.expr, batch, ctx)
        lo = eval_expr(e.low, batch, ctx)
        hi = eval_expr(e.high, batch, ctx)
        m = (v >= lo) & (v <= hi)
        return ~m if e.negated else m
    if isinstance(e, A.IsNull):
        v = eval_expr(e.expr, batch, ctx)
        m = _is_null_mask(v)
        return ~m if e.negated else m
    if isinstance(e, A.Cast):
        v = eval_expr(e.expr, batch, ctx)
        t = e.to_type.upper()
        if t.startswith(("INT", "BIGINT")):
            return v.astype(np.float64).astype(np.int64) if v.dtype.kind != "U" else np.array([int(float(x)) for x in v], dtype=np.int64)
        if t.startswith("FLOAT"):
            return v.astype(np.float32)  # Hive FLOAT is single-precision
        if t.startswith(("DOUBLE", "DECIMAL", "REAL")):
            return v.astype(np.float64)
        return v.astype(str)
    raise ExecError(f"cannot evaluate {type(e).__name__}")


# ===========================================================================
# factorized keys (shared by join/aggregate/window)
# ===========================================================================
def _factorize_pair(l: np.ndarray, r: np.ndarray) -> Tuple[np.ndarray, np.ndarray, int]:
    if l.dtype.kind in ("U", "S") or r.dtype.kind in ("U", "S"):
        l, r = l.astype(str), r.astype(str)
    elif l.dtype != r.dtype:
        l, r = l.astype(np.float64), r.astype(np.float64)
    cat = np.concatenate([l, r])
    uniq, codes = np.unique(cat, return_inverse=True)
    return codes[: len(l)], codes[len(l):], len(uniq)


def _combine_codes(pairs: List[Tuple[np.ndarray, np.ndarray, int]]):
    lc = pairs[0][0].astype(np.int64)
    rc = pairs[0][1].astype(np.int64)
    for codes_l, codes_r, k in pairs[1:]:
        lc = lc * k + codes_l
        rc = rc * k + codes_r
    return lc, rc


def _group_codes(batch: VectorBatch, keys: Sequence[str]) -> Tuple[np.ndarray, np.ndarray]:
    """Return (codes, first_occurrence_index) for composite group keys."""
    if not keys:
        return np.zeros(batch.num_rows, dtype=np.int64), np.array([0] if batch.num_rows else [], dtype=np.int64)
    cols = [batch.cols[k] for k in keys]
    if len(cols) == 1:
        uniq, first, codes = np.unique(cols[0], return_index=True, return_inverse=True)
        return codes.astype(np.int64), first
    rec = np.rec.fromarrays(cols)
    uniq, first, codes = np.unique(rec, return_index=True, return_inverse=True)
    return codes.astype(np.int64), first


# ===========================================================================
# operators
# ===========================================================================
# how a partial aggregate folds into the running incremental-merge state:
# partial SUMs and COUNTs add, partial MIN/MAX re-minimize/-maximize
_FOLD_FN = {"sum": "sum", "count": "sum", "min": "min", "max": "max"}


class _KernelBloomProbe:
    """Adapter routing runtime-filter bloom probes through the kernel
    registry (``bloom_probe`` under ``engine: pallas|ref``) while presenting
    the ``might_contain`` surface the scan I/O layer expects."""

    def __init__(self, bf: BloomFilter, engine: str):
        self._bf = bf
        self._engine = engine

    def might_contain(self, values: np.ndarray) -> np.ndarray:
        from ...kernels.bloom.ops import probe_bloom_filter

        return np.asarray(probe_bloom_filter(self._bf, values,
                                             engine=self._engine))


class _BuildTable:
    """Build-side dictionary state for streaming hash-join probes.

    The build side's key columns are dictionary-encoded once (sorted
    uniques); every probe chunk then maps its key values into build codes —
    via the ``key_lookup`` kernel under ``engine: pallas|ref`` — so probing
    is O(chunk) instead of re-factorizing the whole build side per morsel.
    """

    def __init__(self, rb: VectorBatch, right_keys, left_keys,
                 lproto: VectorBatch, ctx: ExecContext):
        self.ctx = ctx
        self.left_keys = list(left_keys)
        self.keys = []  # (uniq_sorted, cast, cardinality+1) per key column
        rc = None
        for rk, lk in zip(right_keys, left_keys):
            rv, lv = rb.cols[rk], lproto.cols[lk]
            if rv.dtype.kind in ("U", "S") or lv.dtype.kind in ("U", "S"):
                cast: Optional[type] = str
                rv = rv.astype(str)
            elif rv.dtype != lv.dtype:
                cast = float
                rv = rv.astype(np.float64)
            else:
                cast = None
            uniq, inv = np.unique(rv, return_inverse=True)
            k = np.int64(len(uniq) + 1)
            self.keys.append((uniq, cast, k))
            inv = inv.astype(np.int64)
            rc = inv if rc is None else rc * k + inv
        self.order = np.argsort(rc, kind="stable")
        self.rc_sorted = rc[self.order]

    def probe_codes(self, lb: VectorBatch) -> np.ndarray:
        """Combined build codes for a probe chunk; -1 marks no-match rows."""
        lc, valid = None, None
        for (uniq, cast, k), lk in zip(self.keys, self.left_keys):
            v = lb.cols[lk]
            if cast is str:
                v = v.astype(str)
            elif cast is float:
                v = v.astype(np.float64)
            codes = self._lookup(uniq, v)
            ok = codes >= 0
            valid = ok if valid is None else (valid & ok)
            c = np.where(ok, codes, 0)
            lc = c if lc is None else lc * k + c
        if lc is None:
            return np.full(lb.num_rows, -1, dtype=np.int64)
        return np.where(valid, lc, np.int64(-1))

    def _lookup(self, uniq: np.ndarray, vals: np.ndarray) -> np.ndarray:
        if len(uniq) == 0:
            return np.full(len(vals), -1, dtype=np.int64)
        if (self.ctx.engine != "auto" and uniq.dtype.kind in "iuf"
                and vals.dtype.kind in "iuf"):
            # kernel contract is float32: only when the cast round-trips
            u32, v32 = uniq.astype(np.float32), vals.astype(np.float32)
            if (np.array_equal(u32.astype(uniq.dtype), uniq)
                    and np.array_equal(v32.astype(vals.dtype), vals)):
                fn = self.ctx.kernel("key_lookup")
                return np.asarray(fn(u32, v32)).astype(np.int64)
        idx = np.minimum(np.searchsorted(uniq, vals), len(uniq) - 1)
        found = uniq[idx] == vals
        return np.where(found, idx, -1).astype(np.int64)


class Executor:
    """Pipelined interpreter: operators are generators over ``VectorBatch``
    morsels (``exchange.batch_rows``, default ``DEFAULT_BATCH_ROWS``).

    ``stream`` is the primary entry point; scans, filters, projects, limits
    and UNION ALL pipeline chunk-by-chunk, while pipeline breakers (join
    build sides, grouped aggregation, sort, window, DISTINCT union)
    accumulate incremental-merge state and then stream their output in
    morsels.  ``execute`` materializes a stream for callers that need the
    whole relation (DML, MV maintenance).  The cancel token is observed at
    every batch boundary, so kill/cancel latency is bounded by one morsel.
    """

    def __init__(self, ctx: ExecContext):
        self.ctx = ctx
        self.batch_rows = int(
            ctx.config.get("exchange.batch_rows", DEFAULT_BATCH_ROWS)
            or DEFAULT_BATCH_ROWS
        )

    def execute(self, node: P.PlanNode) -> VectorBatch:
        chunks = list(self.stream(node))
        return chunks[0] if len(chunks) == 1 else VectorBatch.concat(chunks)

    def stream(self, node: P.PlanNode):
        """Yield the node's output as a sequence of morsels.

        Every operator stream yields at least one (possibly empty) batch so
        downstream operators always see the output schema.
        """
        key = node.key()
        cached = self.ctx.subplan_cache.get(key)
        if cached is not None:  # shared-work reuse (§4.5)
            yield from self._emit(cached)
            return
        if key in self.ctx.shared_keys:
            # shared subplans materialize once, then replay per consumer
            out = VectorBatch.concat(list(self._dispatch(node)))
            self.ctx.record(node, out.num_rows)
            self.ctx.subplan_cache[key] = out
            yield from self._emit(out)
            return
        rows, first = 0, True
        for chunk in self._dispatch(node):
            self._checkpoint()
            if chunk.num_rows == 0 and not first:
                continue
            first = False
            rows += chunk.num_rows
            yield chunk
        self.ctx.record(node, rows)

    def _dispatch(self, node: P.PlanNode):
        method = getattr(self, "_stream_" + type(node).__name__.lower(), None)
        if method is None:
            raise ExecError(f"no operator for {type(node).__name__}")
        return method(node)

    def _checkpoint(self) -> None:
        """Cancellation point at every batch boundary (bounds cancel/kill
        latency — including inside speculated vertex clones — to one morsel)."""
        token = self.ctx.cancel_token
        if token is not None:
            token.check()

    def _emit(self, batch: VectorBatch):
        if batch.num_rows == 0:
            yield batch  # schema-carrying empty morsel
            return
        yield from batch.iter_chunks(self.batch_rows)

    def _collect(self, node: P.PlanNode) -> VectorBatch:
        return VectorBatch.concat(list(self.stream(node)))

    # ---- scans -------------------------------------------------------------
    def _stream_scan(self, node: P.Scan):
        desc = node.table
        tbl = AcidTable(desc, self.ctx.hms)
        wid = self.ctx.widlist(desc.name)

        # sargable predicate extraction from the pushed filter (§5.1)
        sargs = _extract_sargs(node.pushed_filter) if node.pushed_filter else []

        # dynamic semijoin reducers (§4.6): evaluate producers, build filters
        runtime_blooms: Dict[str, object] = {}
        part_value_sets: Dict[str, np.ndarray] = {}
        for rf in node.runtime_filters:
            res = self._runtime_filter_values(rf)
            if rf.kind == "partition":
                part_value_sets[rf.target_column] = res["values"]
            else:
                bloom = res["bloom"]
                if self.ctx.engine != "auto":
                    # route stripe-level probes through the kernel registry
                    bloom = _KernelBloomProbe(bloom, self.ctx.engine)
                runtime_blooms[rf.target_column] = bloom
                sargs.append(SargPredicate(rf.target_column, ">=", res["min"]))
                sargs.append(SargPredicate(rf.target_column, "<=", res["max"]))

        pcols = desc.partition_cols

        def part_filter(pvals: tuple) -> bool:
            if node.partition_filter is not None:
                b = VectorBatch({
                    f"{node.alias}.{c}": _broadcast(v, 1)
                    for c, v in zip(pcols, pvals)
                })
                if not bool(eval_expr(node.partition_filter, b, self.ctx)[0]):
                    return False
            for col, values in part_value_sets.items():
                if col in pcols:
                    v = pvals[pcols.index(col)]
                    if v not in values:
                        return False  # dynamic partition pruning (§4.6)
            return True

        want = [c for c in node.columns]
        keep_acid = self.ctx.config.get("keep_acid_cols", False)
        qualify = lambda b: b.rename(  # noqa: E731
            {c: f"{node.alias}.{c}" for c in b.column_names
             if not c.startswith("__")}
        )
        pushed = (_qualify(node.pushed_filter, node.alias)
                  if node.pushed_filter is not None else None)
        yielded = False
        try:
            for pvals, b in tbl.scan_chunks(
                wid,
                columns=want,
                sarg_preds=[s for s in sargs if s.column not in pcols],
                runtime_blooms=runtime_blooms or None,
                partition_filter=part_filter,
                io=self.ctx.io,
                keep_acid_cols=keep_acid or node.min_writeid is not None,
            ):
                if node.min_writeid is not None:
                    # incremental MV rebuild: only rows above the build snapshot (§4.4)
                    b = b.select(b.cols[WRITEID_COL] > node.min_writeid)
                    if not keep_acid:
                        b = b.drop_acid_cols()
                b = qualify(b)
                if pushed is not None and b.num_rows:
                    b = b.select(self._filter_mask(pushed, b))
                if b.num_rows == 0:
                    if not yielded:
                        yield b
                        yielded = True
                    continue
                for chunk in b.iter_chunks(self.batch_rows):
                    yield chunk
                    yielded = True
        except OSError as exc:
            # a concurrent DROP TABLE purged the data directory out from
            # under this snapshot: fail cleanly (the exchange propagates the
            # error to every consumer) instead of surfacing a partial scan
            # as a bare file error
            if not self.ctx.hms.table_exists(desc.name):
                raise ExecError(
                    f"table {desc.name} was dropped during an in-flight "
                    f"scan; partial results discarded"
                ) from exc
            raise
        if not yielded:
            # schema-carrying empty batch; _empty_batch holds only data
            # columns, so directory-encoded partition columns are injected
            # here (chunked scans yield nothing when every stripe filters
            # out, unlike the old per-partition batches)
            from ..acid import _np_dtype

            out = tbl._empty_batch(want)
            for col in desc.partition_cols:
                if col in want and col not in out.cols:
                    out = out.with_column(
                        col, np.empty(0, dtype=_np_dtype(desc.dtype_of(col))))
            yield qualify(out)

    def _runtime_filter_values(self, rf: P.RuntimeFilterSpec) -> dict:
        ck = rf.key()
        if ck in self.ctx.runtime_filter_cache:
            return self.ctx.runtime_filter_cache[ck]
        producer_out = self.execute(rf.producer)
        vals = producer_out.cols[rf.producer_column]
        vals = np.unique(vals)
        res = {"values": vals}
        if rf.kind == "index":
            bf = BloomFilter.for_expected(len(vals))
            if len(vals):
                bf.add(vals)
            res["bloom"] = bf
            res["min"] = vals.min().item() if len(vals) else 0
            res["max"] = vals.max().item() if len(vals) else 0
        self.ctx.runtime_filter_cache[ck] = res
        return res

    def _stream_federatedscan(self, node: P.FederatedScan):
        """Split-parallel streaming reads through the DataSource API.

        The connector's :class:`ScanBuilder` is rebuilt from the negotiated
        spec; each split's reader is a generator yielding morsels, so
        external rows stream through the exchange layer (and observe the
        cancel token at every batch boundary) like native scans.  Compile-
        time split expansion pins one split per vertex; an unexpanded node
        (synchronous helpers, MV maintenance) drains every split inline.
        """
        from ..federation.datasource import apply_spec

        handler = self.ctx.handlers.get(node.table.handler)
        if handler is None:
            raise ExecError(f"no storage handler registered: {node.table.handler}")
        builder = handler.scan_builder(node.table, self.ctx.config)
        apply_spec(builder, node.spec)
        splits = [node.split] if node.split is not None \
            else (builder.to_splits() or [None])
        out_names = node.output_names()
        yielded = False
        trace = self.ctx.trace
        for i, split in enumerate(splits):
            # one span per federated split drain (tracing off: the shared
            # no-op context manager — no allocation per split)
            with make_span(trace, f"fed:{node.table.name}.split{i}",
                           "federation", pinned=node.split is not None):
                if self.ctx.metrics is not None:
                    self.ctx.metrics.inc("federation.splits_read")
                for batch in builder.read_split(split):
                    # cancel point per connector batch: a filtered-out batch
                    # yields no chunk downstream, so without this a cancelled
                    # query keeps draining the remote split to its end
                    self._checkpoint()
                    if node.spec is not None:
                        # connector outputs follow the spec's column order
                        b = batch.rename(
                            dict(zip(batch.column_names, out_names)))
                    else:
                        b = batch.rename(
                            {c: f"{node.alias}.{c}"
                             for c in batch.column_names})
                    if b.num_rows == 0:
                        if not yielded:
                            yield b
                            yielded = True
                        continue
                    for chunk in b.iter_chunks(self.batch_rows):
                        yield chunk
                        yielded = True
        if not yielded:
            empty = builder.empty_batch()
            yield empty.rename(dict(zip(empty.column_names, out_names)))

    # ---- relational ops ------------------------------------------------------
    def _stream_filter(self, node: P.Filter):
        for b in self.stream(node.input):
            if b.num_rows == 0:
                yield b
                continue
            yield b.select(self._filter_mask(node.predicate, b))

    def _filter_mask(self, predicate: A.Expr, b: VectorBatch) -> np.ndarray:
        # engine != auto routes sargable conjunctions through the registered
        # filter kernel (pallas or jnp ref) instead of the numpy interpreter
        if self.ctx.engine != "auto":
            compiled = _compile_kernel_filter(predicate, b)
            if compiled is not None:
                cols, ops, lits = compiled
                fn = self.ctx.kernel("filter_eval")
                return np.asarray(fn(cols, ops, lits)).astype(bool)
        return eval_expr(predicate, b, self.ctx).astype(bool)

    def _stream_project(self, node: P.Project):
        for b in self.stream(node.input):
            yield VectorBatch({n: eval_expr(e, b, self.ctx)
                               for e, n in node.exprs})

    def _stream_valuesnode(self, node: P.ValuesNode):
        one = VectorBatch({"__dummy__": np.zeros(1)})
        cols: Dict[str, list] = {n: [] for n in node.names}
        for row in node.rows:
            for n, e in zip(node.names, row):
                cols[n].append(eval_expr(e, one, self.ctx)[0])
        yield from self._emit(VectorBatch({n: np.array(v)
                                           for n, v in cols.items()}))

    def _stream_union(self, node: P.Union):
        names = node.output_names()
        # mixed-dtype branches (int64 UNION ALL float64, ...) must emit one
        # consistent promoted dtype per column — numpy promotion, taken from
        # the inferred schema — instead of flickering per source chunk
        promote = _union_promotions(node)
        if node.all:
            # UNION ALL is streaming-safe: chunks pass through aligned
            for i in node.inputs:
                for o in self.stream(i):
                    yield _promoted(VectorBatch(dict(zip(
                        names, (o.cols[c] for c in o.column_names)))), promote)
            return
        # DISTINCT union stays a pipeline breaker (dedup needs the full set)
        aligned = [
            _promoted(VectorBatch(dict(zip(
                names, (o.cols[c] for c in o.column_names)))), promote)
            for i in node.inputs for o in self.stream(i)
        ]
        out = VectorBatch.concat(aligned)
        codes, first = _group_codes(out, names)
        yield from self._emit(out.take(np.sort(first)))

    def _stream_limit(self, node: P.Limit):
        remaining = int(node.n)
        gen = self.stream(node.input)
        first = True
        for b in gen:
            take = b if b.num_rows <= remaining else b.slice(0, remaining)
            remaining -= take.num_rows
            if first or take.num_rows:
                yield take
            first = False
            if remaining <= 0:
                # early-out: stop pulling upstream morsels.  Abandoned
                # upstream streams skip their ctx.record() on purpose — a
                # partial row count would poison §4.2 reoptimization stats
                gen.close()
                return

    def _stream_sort(self, node: P.Sort):
        # pipeline breaker: accumulate morsels, sort once, stream the output
        b = self._collect(node.input)
        yield from self._emit(
            b.sort_by([k for k, _ in node.keys], [d for _, d in node.keys])
        )

    # ---- join ----------------------------------------------------------------
    def _stream_join(self, node: P.Join):
        # build side: the pipeline breaker.  Chunks accumulate incrementally
        # and broadcast builds fail fast the moment they exceed the budget,
        # instead of after materializing the whole side.
        limit = (self.ctx.config.get("mapjoin_max_rows", 10_000_000)
                 if node.strategy == "broadcast" else None)
        build_chunks, build_rows = [], 0
        for rb_chunk in self.stream(node.right):
            build_rows += rb_chunk.num_rows
            if limit is not None and build_rows > limit:
                raise MemoryPressureError(
                    f"broadcast build side {build_rows} rows exceeds {limit}"
                )
            build_chunks.append(rb_chunk)
        rb = VectorBatch.concat(build_chunks)

        if node.kind == "cross":
            for lb in self.stream(node.left):
                li = np.repeat(np.arange(lb.num_rows), rb.num_rows)
                ri = np.tile(np.arange(rb.num_rows), lb.num_rows)
                out = _concat_sides(lb.take(li), rb.take(ri))
                if node.residual is not None and out.num_rows:
                    out = out.select(
                        eval_expr(node.residual, out, self.ctx).astype(bool))
                yield out
            return

        if node.kind in ("left", "full"):
            # the padded side pads with NaN (float64): cast its numeric
            # columns up front so matched and unmatched chunks agree on one
            # dtype instead of flickering int64/float64 per morsel
            rb = _null_extendable(rb)

        # probe side streams: each morsel joins against the build dictionary
        probe: Optional[_BuildTable] = None
        rmatched = np.zeros(rb.num_rows, dtype=bool)
        lproto: Optional[VectorBatch] = None
        for lb in self.stream(node.left):
            if node.kind == "full":
                lb = _null_extendable(lb)
            if probe is None:
                lproto = lb
                probe = _BuildTable(rb, node.right_keys, node.left_keys,
                                    lb, self.ctx)
            lc = probe.probe_codes(lb)
            lo = np.searchsorted(probe.rc_sorted, lc, side="left")
            hi = np.searchsorted(probe.rc_sorted, lc, side="right")
            counts = np.where(lc < 0, 0, hi - lo)

            if node.kind in ("semi", "anti"):
                mask = counts > 0 if node.kind == "semi" else counts == 0
                if node.residual is not None and node.kind == "semi":
                    li, ri = _expand_matches(lo, counts, probe.order)
                    joined = _concat_sides(lb.take(li), rb.take(ri))
                    ok = eval_expr(node.residual, joined, self.ctx).astype(bool)
                    good_left = np.unique(li[ok])
                    mask = np.zeros(lb.num_rows, dtype=bool)
                    mask[good_left] = True
                yield lb.select(mask)
                continue

            li, ri = _expand_matches(lo, counts, probe.order)
            joined = _concat_sides(lb.take(li), rb.take(ri))
            if node.residual is not None and joined.num_rows:
                ok = eval_expr(node.residual, joined, self.ctx).astype(bool)
                joined = joined.select(ok)
                li, ri = li[ok], ri[ok]

            if node.kind == "inner":
                yield joined
                continue
            if node.kind not in ("left", "full"):
                raise ExecError(f"join kind {node.kind} unsupported")
            matched = np.zeros(lb.num_rows, dtype=bool)
            if len(li):
                matched[li] = True
            unmatched = lb.select(~matched)
            null_right = _null_batch(rb, unmatched.num_rows)
            yield VectorBatch.concat(
                [joined, _concat_sides(unmatched, null_right)])
            if node.kind == "full" and len(ri):
                rmatched[ri] = True
        if node.kind == "full":
            runmatched = rb.select(~rmatched)
            null_left = _null_batch(lproto, runmatched.num_rows)
            yield _concat_sides(null_left, runmatched)

    # ---- aggregate -------------------------------------------------------------
    def _stream_aggregate(self, node: P.Aggregate):
        mergeable = node.grouping_sets is None and all(
            s.fn in _FOLD_FN for s in node.aggs
        )
        if not mergeable:
            yield from self._emit(self._aggregate_materialized(node))
            return
        # incremental-merge: per-morsel partial aggregates fold into a
        # running state (keys + partial columns), never one giant concat.
        # DISTINCT aggregates stream too: each spec keeps an incremental
        # per-group hash set — the unique (group keys, value) rows seen so
        # far — and the final fn (COUNT/SUM/MIN/MAX) evaluates over that
        # set, instead of materializing the whole input (under the
        # partitioned shuffle service that set is per-partition, so the
        # state a clone holds is its lane's share of the value domain).
        keys = node.group_keys
        plain = [s for s in node.aggs if not s.distinct]
        distincts = [s for s in node.aggs if s.distinct]
        state: Optional[VectorBatch] = None
        pending: List[VectorBatch] = []
        pending_rows = 0
        dstate: Dict[str, Optional[VectorBatch]] = {s.out_name: None
                                                    for s in distincts}
        dpending: Dict[str, List[VectorBatch]] = {s.out_name: []
                                                  for s in distincts}
        dpending_rows: Dict[str, int] = {s.out_name: 0 for s in distincts}
        first_chunk: Optional[VectorBatch] = None
        for chunk in self.stream(node.input):
            if first_chunk is None:
                first_chunk = chunk
            if chunk.num_rows == 0:
                continue
            part = self._aggregate_once(chunk, keys, plain)
            pending.append(part)
            pending_rows += part.num_rows
            # doubling schedule: merge once pending outgrows the running
            # state, so high-cardinality groupings pay O(n log n) total
            # merge work instead of re-folding the full state per morsel
            threshold = max(state.num_rows if state is not None else 0,
                            self.batch_rows, 4096)
            if pending_rows >= threshold:
                state = self._merge_partials(state, pending, keys, plain)
                pending, pending_rows = [], 0
            for s in distincts:
                vals = eval_expr(s.arg, chunk, self.ctx)
                d = VectorBatch({**{k: chunk.cols[k] for k in keys},
                                 "__dv__": vals})
                valid = ~_is_null_mask(vals)
                if vals.dtype.kind == "f":
                    valid &= ~np.isnan(vals)
                d = _dedupe(d.select(valid), keys + ["__dv__"])
                if d.num_rows == 0:
                    continue
                dpending[s.out_name].append(d)
                dpending_rows[s.out_name] += d.num_rows
                ds = dstate[s.out_name]
                dthresh = max(ds.num_rows if ds is not None else 0,
                              self.batch_rows, 4096)
                if dpending_rows[s.out_name] >= dthresh:
                    parts = ([ds] if ds is not None else []) \
                        + dpending[s.out_name]
                    dstate[s.out_name] = _dedupe(VectorBatch.concat(parts),
                                                 keys + ["__dv__"])
                    dpending[s.out_name] = []
                    dpending_rows[s.out_name] = 0
        if pending:
            state = self._merge_partials(state, pending, keys, plain)
        if state is None:
            # empty input: global aggregates still produce their single row
            src = first_chunk if first_chunk is not None else VectorBatch({})
            state = self._aggregate_once(src, keys, plain)
        for s in distincts:
            parts = ([dstate[s.out_name]] if dstate[s.out_name] is not None
                     else []) + dpending[s.out_name]
            dstate[s.out_name] = (_dedupe(VectorBatch.concat(parts),
                                          keys + ["__dv__"])
                                  if parts else None)
        if distincts:
            state = self._attach_distinct_counts(state, keys, distincts,
                                                 dstate)
        yield from self._emit(state.project(node.output_names()))

    def _attach_distinct_counts(self, state: VectorBatch, keys: List[str],
                                distincts, dstate) -> VectorBatch:
        """Evaluate each DISTINCT spec's fn (COUNT/SUM/MIN/MAX) over its
        per-group hash-set state, aligned to the running state's group rows
        (COUNT 0 / others NULL for groups whose every value was NULL)."""
        out = dict(state.cols)
        ng = state.num_rows if keys else 1
        for s in distincts:
            plain = P.AggSpec(s.fn, s.arg, False, s.out_name)
            d = dstate[s.out_name]
            if d is None or d.num_rows == 0 or ng == 0:
                codes = np.empty(0, dtype=np.int64)
                vals = np.empty(0)
            elif keys:
                # map each unique (keys, value) row to its state group row;
                # every distinct-state group also exists in the running
                # state (its rows flowed through the plain fold), so all
                # codes match — the guard covers NaN-keyed groups
                pairs = [_factorize_pair(state.cols[k], d.cols[k])
                         for k in keys]
                sc, dc = _combine_codes(pairs)
                order = np.argsort(sc, kind="stable")
                pos = np.searchsorted(sc[order], dc)
                rows = order[np.minimum(pos, ng - 1)]
                found = sc[rows] == dc
                codes, vals = rows[found], d.cols["__dv__"][found]
            else:
                codes = np.zeros(d.num_rows, dtype=np.int64)
                vals = d.cols["__dv__"]
            out[s.out_name] = _agg_column(plain, vals, codes, ng)
        return VectorBatch(out)

    def _merge_partials(self, state: Optional[VectorBatch],
                        partials: List[VectorBatch], keys: List[str],
                        aggs) -> VectorBatch:
        parts = ([state] if state is not None else []) + partials
        if len(parts) == 1:
            return parts[0]
        cat = VectorBatch.concat(parts)
        codes, first = _group_codes(cat, keys)
        ng = len(first) if keys else 1
        out: Dict[str, np.ndarray] = {}
        for k in keys:
            out[k] = cat.cols[k][np.sort(first)]
        order_of_first = np.argsort(first) if keys else np.array([0])
        remap = np.empty(ng, dtype=np.int64)
        remap[order_of_first] = np.arange(ng)
        codes2 = remap[codes] if cat.num_rows else codes
        for spec in aggs:
            fold = P.AggSpec(_FOLD_FN[spec.fn], None, False, spec.out_name)
            out[spec.out_name] = _agg_column(
                fold, cat.cols[spec.out_name], codes2, ng)
        return VectorBatch(out)

    def _aggregate_materialized(self, node: P.Aggregate) -> VectorBatch:
        """Non-mergeable shapes (DISTINCT aggregates, grouping sets) fall
        back to materializing the input."""
        b = self._collect(node.input)
        if node.grouping_sets is not None:
            parts = []
            for keyset in node.grouping_sets:
                sub = self._aggregate_once(b, keyset, node.aggs)
                # missing keys -> NULL columns, aligned to full output
                for k in node.group_keys:
                    if k not in keyset:
                        proto = b.cols[k]
                        sub = sub.with_column(k, _null_like(proto, sub.num_rows))
                parts.append(sub.project(node.output_names()))
            return VectorBatch.concat(parts)
        return self._aggregate_once(b, node.group_keys, node.aggs).project(
            node.output_names()
        )

    def _aggregate_once(self, b: VectorBatch, keys: List[str], aggs) -> VectorBatch:
        codes, first = _group_codes(b, keys)
        ng = len(first) if keys else (1 if True else 0)
        if not keys:
            ng = 1
        out: Dict[str, np.ndarray] = {}
        for k in keys:
            out[k] = b.cols[k][np.sort(first)]
        order_of_first = np.argsort(first) if keys else np.array([0])
        # map group code -> dense output row (groups ordered by first occurrence)
        remap = np.empty(ng, dtype=np.int64)
        remap[order_of_first] = np.arange(ng)
        codes2 = remap[codes] if b.num_rows else codes

        for spec in aggs:
            vals = eval_expr(spec.arg, b, self.ctx) if spec.arg is not None else None
            # engine != auto routes SUM/COUNT through the registered grouped-
            # aggregation kernel (pallas one-hot matmul or jnp ref) when the
            # float32 contract is value-preserving, mirroring the filter path
            routed = (self._kernel_agg(spec, vals, codes2, ng)
                      if self.ctx.engine != "auto" else None)
            out[spec.out_name] = (routed if routed is not None
                                  else _agg_column(spec, vals, codes2, ng))
        if not keys and b.num_rows == 0:
            # global aggregate over empty input yields a single row
            for spec in aggs:
                out[spec.out_name] = _agg_column(spec, np.empty(0), np.empty(0, np.int64), 1)
        return VectorBatch(out)

    def _kernel_agg(self, spec, vals: Optional[np.ndarray],
                    codes: np.ndarray, ng: int) -> Optional[np.ndarray]:
        """Grouped SUM/COUNT (``hash_group``) and MIN/MAX
        (``hash_group_minmax``) via the kernel registry; None when the
        aggregate is not kernel-shaped (then the numpy path runs)."""
        if spec.fn not in ("sum", "count", "min", "max") or spec.distinct \
                or vals is None:
            return None
        if ng <= 0 or vals.dtype.kind not in "iufb":
            return None
        if vals.size >= (1 << 24):
            # the kernel's float32 accumulators stop being exact integers at
            # 2^24, so COUNTs (and the row-bounded sums below) could silently
            # round; beyond that the numpy path runs
            return None
        f32 = vals.astype(np.float32)
        # the kernel accumulates in float32: only take this path when the
        # cast is value-preserving (also rejects NaN/NULL-carrying columns,
        # whose skip semantics the kernel does not implement)
        if not np.array_equal(f32.astype(vals.dtype), vals):
            return None
        if spec.fn in ("min", "max"):
            fn = self.ctx.kernel("hash_group_minmax")
            mins, maxs = fn(codes.astype(np.int32), f32, int(ng))
            out = np.asarray(mins if spec.fn == "min" else maxs,
                             dtype=np.float64)
            counts = np.bincount(codes, minlength=ng)
            out[counts == 0] = np.nan  # MIN/MAX over an empty group is NULL
            if vals.dtype.kind in "iu" and not np.isnan(out).any():
                return out.astype(np.int64)
            return out
        if spec.fn == "sum" and vals.dtype.kind in "iu" and vals.size:
            # integer sums must stay exact: every partial sum is an integer
            # bounded by sum(|v|), so < 2^24 keeps float32 accumulation exact
            if float(np.abs(vals.astype(np.int64)).sum()) >= float(1 << 24):
                return None
        fn = self.ctx.kernel("hash_group")
        sums, counts = fn(codes.astype(np.int32), f32, int(ng))
        if spec.fn == "count":
            return np.asarray(counts, dtype=np.int64)
        sums = np.asarray(sums, dtype=np.float64)
        counts = np.asarray(counts)
        sums[counts == 0] = np.nan  # SUM over an empty group is NULL
        if vals.dtype.kind in "iu" and not np.isnan(sums).any():
            return sums.astype(np.int64)
        return sums

    # ---- window functions --------------------------------------------------------
    def _stream_windowop(self, node: P.WindowOp):
        b = self._collect(node.input)  # window frames need the full input
        out = b
        for wf, name in node.funcs:
            out = out.with_column(name, _eval_window(wf, b, self.ctx))
        yield from self._emit(out)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _dedupe(batch: VectorBatch, cols: List[str]) -> VectorBatch:
    """Unique rows of ``batch`` over ``cols`` (first occurrence kept)."""
    if batch.num_rows == 0:
        return batch
    _, first = _group_codes(batch, cols)
    return batch.take(np.sort(first))


def _expand_matches(lo, counts, order):
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int64)
    li = np.repeat(np.arange(len(lo)), counts)
    offsets = np.cumsum(counts) - counts
    within = np.arange(total) - np.repeat(offsets, counts)
    ri = order[np.repeat(lo, counts) + within]
    return li, ri


def _null_extendable(b: VectorBatch) -> VectorBatch:
    """Cast an outer join's padded side to its NULL-capable dtypes:
    numeric/bool columns widen to float64 (NaN-null), strings unchanged."""
    return VectorBatch({
        k: v.astype(np.float64) if v.dtype.kind in ("i", "u", "b", "f")
        and v.dtype != np.float64 else v
        for k, v in b.cols.items()
    })


def _union_promotions(node: P.Union) -> Dict[str, np.dtype]:
    """Per-output-column promoted numpy dtype for a Union's branches, from
    the inferred schema when present (only widening casts; empty when the
    schema is unknown or branches already agree)."""
    schema = getattr(node, "schema", None)
    if schema is None:
        return {}
    out: Dict[str, np.dtype] = {}
    for name, ty in schema:
        if ty.token in ("int64", "float64", "float32", "bool"):
            out[name] = np.dtype(ty.token)
    return out


def _promoted(b: VectorBatch, promote: Dict[str, np.dtype]) -> VectorBatch:
    if not promote:
        return b
    cols = {}
    for k, v in b.cols.items():
        want = promote.get(k)
        if want is not None and v.dtype != want and v.dtype.kind in "iufb" \
                and np.promote_types(v.dtype, want) == want:
            v = v.astype(want)  # widening only; narrowing is real drift
        cols[k] = v
    return VectorBatch(cols)


def _concat_sides(lb: VectorBatch, rb: VectorBatch) -> VectorBatch:
    cols = dict(lb.cols)
    for k, v in rb.cols.items():
        if k in cols:
            k = k + "__r"
        cols[k] = v
    return VectorBatch(cols)


def _null_like(proto: np.ndarray, n: int) -> np.ndarray:
    if proto.dtype.kind in ("U", "S"):
        return np.full(n, _NULL_STR, dtype=proto.dtype if proto.dtype.itemsize else "U8")
    return np.full(n, np.nan, dtype=np.float64)


def _null_batch(proto: VectorBatch, n: int) -> VectorBatch:
    return VectorBatch({k: _null_like(v, n) for k, v in proto.cols.items()})


def _agg_column(spec, vals, codes, ng) -> np.ndarray:
    if spec.fn == "count":
        if vals is None:
            return np.bincount(codes, minlength=ng).astype(np.int64)
        valid = ~_is_null_mask(vals)
        if vals.dtype.kind == "f":
            valid &= ~np.isnan(vals)
        if spec.distinct:
            key = codes * (1 << 32)
            _, u_codes = np.unique(vals[valid], return_inverse=True)
            pairs = np.unique(codes[valid] * np.int64(1 << 32) + u_codes)
            grp = (pairs >> 32).astype(np.int64)
            return np.bincount(grp, minlength=ng).astype(np.int64)
        return np.bincount(codes[valid], minlength=ng).astype(np.int64)
    if vals is None:
        raise ExecError(f"{spec.fn} requires an argument")
    numeric = vals.dtype.kind in ("i", "u", "f", "b")
    if spec.fn == "sum":
        v = vals.astype(np.float64)
        nanmask = np.isnan(v)
        sums = np.bincount(codes[~nanmask], weights=v[~nanmask],
                           minlength=ng).astype(np.float64)
        counts = np.bincount(codes[~nanmask], minlength=ng)
        sums[counts == 0] = np.nan  # SUM over empty/NULL group is NULL
        if vals.dtype.kind in ("i", "u") and not np.isnan(sums).any():
            return sums.astype(np.int64)
        return sums
    if spec.fn in ("min", "max"):
        if numeric:
            init = np.full(ng, np.inf if spec.fn == "min" else -np.inf)
            v = vals.astype(np.float64)
            m = ~np.isnan(v)
            (np.minimum if spec.fn == "min" else np.maximum).at(init, codes[m], v[m])
            init[np.isinf(init)] = np.nan
            if vals.dtype.kind in ("i", "u") and not np.isnan(init).any():
                return init.astype(np.int64)
            if vals.dtype == np.float32:
                # MIN/MAX never create new values: a float32 input keeps its
                # dtype through partial/merge folds (the float64 round-trip
                # is value-exact, and NaN-null survives the cast)
                return init.astype(np.float32)
            return init
        out = np.full(ng, _NULL_STR, dtype=vals.dtype if vals.dtype.itemsize else "U32")
        for g in range(ng):
            sel = vals[codes == g]
            if len(sel):
                out[g] = sel.min() if spec.fn == "min" else sel.max()
        return out
    raise ExecError(f"unknown aggregate {spec.fn}")


def _eval_window(wf: A.WindowFunc, b: VectorBatch, ctx) -> np.ndarray:
    n = b.num_rows
    if n == 0:
        return np.empty(0, dtype=np.int64)
    pcols = [eval_expr(e, b, ctx) for e in wf.partition_by]
    if pcols:
        rec = np.rec.fromarrays(pcols)
        _, codes = np.unique(rec, return_inverse=True)
    else:
        codes = np.zeros(n, dtype=np.int64)
    okeys = [(eval_expr(e, b, ctx), d) for e, d in wf.order_by]

    # global order: partition first, then order keys
    sort_arrays = [codes]
    for v, d in okeys:
        if v.dtype.kind in ("U", "S"):
            _, vc = np.unique(v, return_inverse=True)
            v = vc
        sort_arrays.append(-v.astype(np.float64) if d else v.astype(np.float64))
    order = np.lexsort(tuple(reversed(sort_arrays)))
    inv = np.empty(n, dtype=np.int64)
    inv[order] = np.arange(n)
    sorted_codes = codes[order]
    starts = np.r_[0, np.flatnonzero(np.diff(sorted_codes)) + 1]
    part_start_for = np.repeat(starts, np.diff(np.r_[starts, n]))

    name = wf.func.name
    if name == "row_number":
        rn = np.arange(n) - part_start_for + 1
        return rn[inv]
    if name in ("rank", "dense_rank"):
        keyvals = np.stack([a[order].astype(np.float64) if a.dtype.kind != "U" else
                            np.unique(a, return_inverse=True)[1][order].astype(np.float64)
                            for a, _ in okeys]) if okeys else np.zeros((1, n))
        same_as_prev = np.r_[False, (np.diff(keyvals, axis=1) == 0).all(axis=0)] & \
            (np.r_[-1, sorted_codes[:-1]] == sorted_codes)
        if name == "rank":
            rn = np.arange(n) - part_start_for + 1
            out = rn.copy()
            for i in range(1, n):
                if same_as_prev[i]:
                    out[i] = out[i - 1]
            return out[inv]
        out = np.ones(n, dtype=np.int64)
        for i in range(1, n):
            if sorted_codes[i] != sorted_codes[i - 1]:
                out[i] = 1
            elif same_as_prev[i]:
                out[i] = out[i - 1]
            else:
                out[i] = out[i - 1] + 1
        return out[inv]
    if name in ("lag", "lead"):
        arg = eval_expr(wf.func.args[0], b, ctx)
        k = int(wf.func.args[1].value) if len(wf.func.args) > 1 else 1
        sa = arg[order]
        out = _null_like(arg, n)
        if name == "lag":
            out[k:] = sa[:-k]
            bad = np.arange(n) - part_start_for < k
        else:
            out[:-k] = sa[k:]
            nxt = np.r_[starts[1:], n]
            part_end_for = np.repeat(nxt, np.diff(np.r_[starts, n]))
            bad = np.arange(n) + k >= part_end_for
        out[bad] = np.nan if out.dtype.kind == "f" else out[bad]
        return out[inv]
    if name in ("sum", "count", "min", "max", "avg"):
        arg = eval_expr(wf.func.args[0], b, ctx) if wf.func.args and not isinstance(wf.func.args[0], A.Star) else None
        ng = int(codes.max()) + 1 if n else 0
        from ..optimizer.plan import AggSpec

        if name == "avg":
            s = _agg_column(AggSpec("sum", None, False, "s"), arg, codes, ng) if arg is None else _agg_column(AggSpec("sum", A.Col("x"), False, "s"), arg, codes, ng)
            c = _agg_column(AggSpec("count", A.Col("x") if arg is not None else None, False, "c"), arg, codes, ng)
            vals = s / c
        else:
            vals = _agg_column(AggSpec(name, A.Col("x") if arg is not None else None, False, "v"), arg, codes, ng)
        return vals[codes]
    raise ExecError(f"unsupported window function {name}")


_KERNEL_FILTER_OPS = {"<": 0, "<=": 1, ">": 2, ">=": 3, "=": 4, "!=": 5}


def _compile_kernel_filter(pred: A.Expr, b: VectorBatch):
    """Compile ``col <op> numeric-literal AND ...`` into the filter kernel's
    (columns, ops, lits) form; None when the predicate is not kernel-shaped."""
    from ..sql.binder import split_conjuncts

    cols, ops, lits = [], [], []
    for c in split_conjuncts(pred):
        if not (isinstance(c, A.BinOp) and c.op in _KERNEL_FILTER_OPS
                and isinstance(c.left, A.Col) and isinstance(c.right, A.Lit)):
            return None
        v = c.right.value
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            return None
        try:
            arr = _lookup(b, c.left)
        except ExecError:
            return None
        if arr.dtype.kind not in "iuf":
            return None
        # the kernel contract is float32: only take this path when the cast
        # is value-preserving, else comparisons beyond 2^24 go wrong
        f32 = arr.astype(np.float32)
        if not np.array_equal(f32.astype(arr.dtype), arr):
            return None
        if float(np.float32(v)) != float(v):
            return None
        cols.append(f32)
        ops.append(_KERNEL_FILTER_OPS[c.op])
        lits.append(float(v))
    if not cols:
        return None
    return tuple(cols), tuple(ops), tuple(lits)


def _extract_sargs(pred: A.Expr) -> List[SargPredicate]:
    out = []
    from ..sql.binder import split_conjuncts

    for c in split_conjuncts(pred):
        if isinstance(c, A.BinOp) and c.op in ("=", "<", "<=", ">", ">="):
            if isinstance(c.left, A.Col) and isinstance(c.right, A.Lit):
                out.append(SargPredicate(c.left.name, c.op, c.right.value))
            elif isinstance(c.right, A.Col) and isinstance(c.left, A.Lit):
                flip = {"<": ">", "<=": ">=", ">": "<", ">=": "<=", "=": "="}
                out.append(SargPredicate(c.right.name, flip[c.op], c.left.value))
        elif isinstance(c, A.Between) and not c.negated and isinstance(c.expr, A.Col):
            if isinstance(c.low, A.Lit) and isinstance(c.high, A.Lit):
                out.append(SargPredicate(c.expr.name, ">=", c.low.value))
                out.append(SargPredicate(c.expr.name, "<=", c.high.value))
        elif isinstance(c, A.InList) and not c.negated and isinstance(c.expr, A.Col):
            vals = [v.value for v in c.values if isinstance(v, A.Lit)]
            if vals:
                out.append(SargPredicate(c.expr.name, "in", vals))
    return out


def _qualify(e: A.Expr, alias: str) -> A.Expr:
    """Qualify raw column refs in a pushed filter with the scan alias."""
    from ..sql.binder import _rebuild

    if isinstance(e, A.Col) and e.table is None:
        return A.Col(e.name, alias)
    if isinstance(e, A.Col):
        return e
    return _rebuild(e, [_qualify(c, alias) for c in e.children()])
