"""Tez-style DAG task compiler & scheduler (paper §2, §5).

The task compiler breaks the physical operator tree into a DAG of executable
tasks: pipelineable unary operators (filter/project/limit) fuse into their
producer vertex; blocking operators (join, aggregate, sort, union, window)
start new vertices.  Edges carry the data-movement type the engine would use
(FORWARD / BROADCAST / SHUFFLE), which is what the distributed shard_map
runtime maps onto jax.lax collectives.

Scheduling runs vertices in dependency order on either throwaway "container"
threads or the persistent LLAP executor pool (§5.1), with optional
speculative re-execution of stragglers (the classic MapReduce/Tez
mitigation; here a code path exercised in tests via an injectable delay).
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set

import numpy as np

from ..optimizer import plan as P
from .exec import ExecContext, Executor
from .vector import VectorBatch

FORWARD, BROADCAST, SHUFFLE = "FORWARD", "BROADCAST", "SHUFFLE"


class MaterializedNode(P.PlanNode):
    """Vertex-input placeholder; filled with the upstream vertex's output."""

    _counter = [0]

    def __init__(self, names: List[str], tag: str):
        self.names = names
        self.tag = tag
        self.batch: Optional[VectorBatch] = None
        self.inputs = []

    def output_names(self):
        return list(self.names)

    def key(self):
        return f"materialized({self.tag})"

    def describe(self):
        return f"MaterializedEdge[{self.tag}]"


@dataclass
class Vertex:
    vid: str
    plan: P.PlanNode
    deps: List[str] = field(default_factory=list)
    edge_types: Dict[str, str] = field(default_factory=dict)  # dep vid -> type
    feeds: Dict[str, MaterializedNode] = field(default_factory=dict)


@dataclass
class TaskDAG:
    vertices: Dict[str, Vertex]
    root: str

    def topo_order(self) -> List[str]:
        out, seen = [], set()

        def visit(v):
            if v in seen:
                return
            seen.add(v)
            for d in self.vertices[v].deps:
                visit(d)
            out.append(v)

        visit(self.root)
        return out

    def edge_summary(self) -> Dict[str, int]:
        counts = {FORWARD: 0, BROADCAST: 0, SHUFFLE: 0}
        for v in self.vertices.values():
            for t in v.edge_types.values():
                counts[t] += 1
        return counts


_BLOCKING = (P.Join, P.Aggregate, P.Sort, P.Union, P.WindowOp)


def compile_dag(plan: P.PlanNode) -> TaskDAG:
    """Break the operator tree into vertices.

    Plans can be DAGs (shared-work reuse, semijoin producers referencing the
    dimension subtree), so vertex construction is memoized per node object
    and boundary placeholders are filled by tag at run time.
    """
    vertices: Dict[str, Vertex] = {}
    built: Dict[int, str] = {}
    counter = [0]

    def new_vid() -> str:
        counter[0] += 1
        return f"v{counter[0]}"

    def _edge_type(parent: P.PlanNode, input_idx: int) -> str:
        if isinstance(parent, P.Join):
            if parent.strategy == "broadcast" and input_idx == 1:
                return BROADCAST
            return SHUFFLE if parent.strategy == "shuffle" else FORWARD
        if isinstance(parent, (P.Aggregate, P.Sort, P.WindowOp)):
            return SHUFFLE
        return FORWARD

    def build(node: P.PlanNode) -> str:
        if id(node) in built:
            return built[id(node)]
        vid = new_vid()
        built[id(node)] = vid
        vertex = Vertex(vid, node)
        vertices[vid] = vertex
        split(node, vertex, set())
        # dependencies: every placeholder reachable in this vertex's subtree
        deps = {}
        for mn in _walk_materialized(node):
            deps[mn.tag] = True
        for rf_dep in vertex.feeds:
            deps[rf_dep] = True
        vertex.deps = list(deps)
        return vid

    def split(node: P.PlanNode, vertex: Vertex, visited) -> None:
        if id(node) in visited or isinstance(node, MaterializedNode):
            return
        visited.add(id(node))
        if isinstance(node, P.Scan):
            # runtime-filter producers become upstream BROADCAST vertices
            for rf in node.runtime_filters:
                dep = build(rf.producer)
                vertex.edge_types[dep] = BROADCAST
                vertex.feeds[dep] = None  # dependency only; executed inline
            return
        for i, child in enumerate(node.inputs):
            if isinstance(child, MaterializedNode):
                vertex.edge_types.setdefault(child.tag, _edge_type(node, i))
                continue
            if isinstance(child, _BLOCKING) or isinstance(node, P.Join):
                dep = build(child)
                placeholder = MaterializedNode(child.output_names(), dep)
                node.inputs[i] = placeholder
                vertex.edge_types[dep] = _edge_type(node, i)
            else:
                split(child, vertex, visited)

    root = build(plan)
    return TaskDAG(vertices, root)


def _walk_materialized(node: P.PlanNode, seen=None):
    seen = seen if seen is not None else set()
    if id(node) in seen:
        return
    seen.add(id(node))
    if isinstance(node, MaterializedNode):
        yield node
        return
    for c in node.inputs:
        yield from _walk_materialized(c, seen)
    if isinstance(node, P.Scan):
        for rf in node.runtime_filters:
            yield from _walk_materialized(rf.producer, seen)


@dataclass
class VertexMetrics:
    vid: str
    rows: int
    seconds: float
    speculated: bool = False


class DAGScheduler:
    def __init__(
        self,
        pool: Optional[ThreadPoolExecutor] = None,
        speculative: bool = False,
        straggler_factor: float = 4.0,
        injected_delays: Optional[Dict[str, float]] = None,  # test hook
        vertex_delay: float = 0.0,  # debug/test hook: sleep per vertex
    ):
        self.pool = pool
        self.speculative = speculative
        self.straggler_factor = straggler_factor
        self.injected_delays = injected_delays or {}
        self.vertex_delay = vertex_delay
        self.metrics: List[VertexMetrics] = []

    def execute(self, dag: TaskDAG, ctx: ExecContext,
                on_vertex_done: Optional[Callable] = None) -> VectorBatch:
        own_pool = False
        pool = self.pool
        if pool is None:
            pool = ThreadPoolExecutor(max_workers=4, thread_name_prefix="container")
            own_pool = True
        cancel_token = getattr(ctx, "cancel_token", None)
        try:
            results: Dict[str, VectorBatch] = {}
            done: Set[str] = set()
            order = dag.topo_order()
            pending: Dict[str, Future] = {}
            durations: List[float] = []
            lock = threading.Lock()

            def run_vertex(vid: str) -> VectorBatch:
                # vertex boundaries are the cancellation points (§5.2): a
                # tripped token stops the query without mid-operator state
                if cancel_token is not None:
                    cancel_token.check()
                if vid in self.injected_delays:
                    time.sleep(self.injected_delays[vid])
                if self.vertex_delay:
                    time.sleep(self.vertex_delay)
                v = dag.vertices[vid]
                for mn in _walk_materialized(v.plan):
                    mn.batch = results[mn.tag]
                t0 = time.perf_counter()
                ex = _VertexExecutor(ctx)
                out = ex.execute(v.plan)
                dt = time.perf_counter() - t0
                with lock:
                    durations.append(dt)
                    self.metrics.append(VertexMetrics(vid, out.num_rows, dt))
                return out

            remaining = list(order)
            while remaining or pending:
                if cancel_token is not None:
                    cancel_token.check()
                # launch every vertex whose deps are satisfied
                for vid in list(remaining):
                    v = dag.vertices[vid]
                    if all(d in done for d in v.deps):
                        pending[vid] = pool.submit(run_vertex, vid)
                        remaining.remove(vid)
                if not pending:
                    raise RuntimeError("DAG deadlock (cyclic dependencies?)")
                completed, _ = wait(list(pending.values()), return_when=FIRST_COMPLETED,
                                    timeout=self._speculation_timeout(durations))
                if not completed and self.speculative:
                    # straggler: speculatively clone the slowest pending vertex
                    vid = next(iter(pending))
                    self.injected_delays.pop(vid, None)
                    spec = pool.submit(run_vertex, vid)
                    old = pending[vid]
                    pending[vid] = spec
                    old.cancel()
                    with lock:
                        self.metrics.append(VertexMetrics(vid, -1, 0.0, True))
                    continue
                for vid in list(pending):
                    fut = pending[vid]
                    if fut.done():
                        results[vid] = fut.result()
                        done.add(vid)
                        del pending[vid]
                        if on_vertex_done is not None:
                            on_vertex_done(vid, results[vid])
            return results[dag.root]
        finally:
            if own_pool:
                pool.shutdown(wait=False)

    def _speculation_timeout(self, durations: List[float]) -> Optional[float]:
        if not self.speculative or not durations:
            return None
        med = sorted(durations)[len(durations) // 2]
        return max(med * self.straggler_factor, 0.05)


class _VertexExecutor(Executor):
    def _exec_materializednode(self, node: MaterializedNode) -> VectorBatch:
        assert node.batch is not None, f"edge {node.tag} not materialized"
        return node.batch
