"""Tez-style DAG task compiler & scheduler (paper §2, §5).

The task compiler breaks the physical operator tree into a DAG of executable
tasks: pipelineable unary operators (filter/project/limit) fuse into their
producer vertex; blocking operators (join, aggregate, sort, union, window)
start new vertices.  Edges carry the data-movement type the engine would use
(FORWARD / BROADCAST / SHUFFLE), which is what the distributed shard_map
runtime maps onto jax.lax collectives.

Scheduling runs vertices in dependency order on either throwaway "container"
threads or the persistent LLAP executor pool (§5.1), with optional
speculative re-execution of stragglers (the classic MapReduce/Tez
mitigation; here a code path exercised in tests via an injectable delay).
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set

import numpy as np

from ...analysis.lockdep import make_lock
from ..obs import clock
from ..obs.trace import close_vertex_frame, emit_event, open_vertex_frame
from ..optimizer import plan as P
from .exec import ExecContext, Executor
from .vector import VectorBatch

FORWARD, BROADCAST, SHUFFLE = "FORWARD", "BROADCAST", "SHUFFLE"


class MaterializedNode(P.PlanNode):
    """Vertex-input placeholder for one DAG edge.

    In barrier (materialized) mode the upstream vertex's whole output batch
    is assigned to ``batch``; in pipelined mode ``source`` points at the
    upstream vertex's spill-aware :class:`~repro.core.runtime.exchange.Exchange`
    and every consumer replays its chunk stream through a fresh reader.

    A *partitioned* placeholder (lowered from a
    :class:`~repro.core.optimizer.plan.ShuffleRead`) reads one hash lane of
    the producer's partitioned shuffle edge: in pipelined mode ``source`` is
    the producer's :class:`~repro.core.runtime.shuffle.ShuffleWriter` (or a
    plain exchange, filtered at read time when partitioned and full readers
    mix); in barrier mode the materialized batch is filtered to the lane."""

    _counter = [0]

    def __init__(self, names: List[str], tag: str,
                 partition: Optional[int] = None,
                 num_partitions: Optional[int] = None,
                 partition_keys: Optional[List[str]] = None,
                 sub_lane: Optional[int] = None,
                 est_rows: Optional[float] = None,
                 schema=None):
        self.names = names
        self.tag = tag
        # the producer's inferred output schema (repro.core.schema.Schema),
        # copied from the plan node this edge replaced at compile time
        self.schema = schema
        self.partition = partition
        self.num_partitions = num_partitions
        self.partition_keys = partition_keys or []
        # adaptive hot-lane split: a placeholder reading one *sub-lane* of a
        # producer's split shuffle lane (ShuffleWriter.sub_lane_reader index)
        self.sub_lane = sub_lane
        # the CBO row estimate the lane count was derived from (None under a
        # fixed shuffle.partitions) — the adaptive payoff gate compares it
        # against live producer rows
        self.est_rows = est_rows
        self.batch: Optional[VectorBatch] = None
        self.source = None  # Exchange / ShuffleWriter (pipelined scheduling)
        self.inputs = []

    def __deepcopy__(self, memo):
        # adaptive replanning clones vertex plans (speculation clones,
        # sub-lane consumers, collapse targets); the clone must NOT drag the
        # bound runtime state along — batch/source rebind at vertex start
        clone = MaterializedNode(
            list(self.names), self.tag, partition=self.partition,
            num_partitions=self.num_partitions,
            partition_keys=list(self.partition_keys),
            sub_lane=self.sub_lane, est_rows=self.est_rows,
            schema=self.schema)
        memo[id(self)] = clone
        return clone

    def output_names(self):
        return list(self.names)

    def key(self):
        if self.sub_lane is not None:
            return f"materialized({self.tag}#s{self.sub_lane})"
        if self.partition is not None:
            return (f"materialized({self.tag}"
                    f"#p{self.partition}/{self.num_partitions})")
        return f"materialized({self.tag})"

    def describe(self):
        if self.sub_lane is not None:
            return f"MaterializedEdge[{self.tag} sub-lane {self.sub_lane}]"
        if self.partition is not None:
            return (f"MaterializedEdge[{self.tag} "
                    f"lane {self.partition}/{self.num_partitions}]")
        return f"MaterializedEdge[{self.tag}]"


@dataclass
class Vertex:
    vid: str
    plan: P.PlanNode
    deps: List[str] = field(default_factory=list)
    edge_types: Dict[str, str] = field(default_factory=dict)  # dep vid -> type
    feeds: Dict[str, MaterializedNode] = field(default_factory=dict)


@dataclass
class TaskDAG:
    vertices: Dict[str, Vertex]
    root: str

    def topo_order(self) -> List[str]:
        out, seen = [], set()

        def visit(v):
            if v in seen:
                return
            seen.add(v)
            for d in self.vertices[v].deps:
                visit(d)
            out.append(v)

        visit(self.root)
        return out

    def edge_summary(self) -> Dict[str, int]:
        counts = {FORWARD: 0, BROADCAST: 0, SHUFFLE: 0}
        for v in self.vertices.values():
            for t in v.edge_types.values():
                counts[t] += 1
        return counts


# FederatedScan counts as a vertex boundary so compile-time split expansion
# (UNION ALL of per-split scans) fans external reads out across concurrently
# scheduled vertices — splits stream through exchanges in parallel.
_BLOCKING = (P.Join, P.Aggregate, P.Sort, P.Union, P.WindowOp, P.FederatedScan)


def compile_dag(plan: P.PlanNode) -> TaskDAG:
    """Break the operator tree into vertices.

    Plans can be DAGs (shared-work reuse, semijoin producers referencing the
    dimension subtree), so vertex construction is memoized per node object
    and boundary placeholders are filled by tag at run time.
    """
    # (re-)infer output schemas on the final optimized tree: optimizer
    # rewrites (projection pushdown, shuffle expansion) invalidate any
    # bind-time annotation, and edge placeholders/exchange declarations
    # below copy node.schema — a stale schema here would make the runtime
    # sanitizer reject correct morsels
    from ..schema import annotate_plan

    annotate_plan(plan)
    vertices: Dict[str, Vertex] = {}
    built: Dict[int, str] = {}
    counter = [0]

    def new_vid() -> str:
        counter[0] += 1
        return f"v{counter[0]}"

    def _edge_type(parent: P.PlanNode, input_idx: int) -> str:
        if isinstance(parent, P.Join):
            if parent.strategy == "broadcast" and input_idx == 1:
                return BROADCAST
            return SHUFFLE if parent.strategy == "shuffle" else FORWARD
        if isinstance(parent, (P.Aggregate, P.Sort, P.WindowOp)):
            return SHUFFLE
        return FORWARD

    def build(node: P.PlanNode) -> str:
        if id(node) in built:
            return built[id(node)]
        vid = new_vid()
        built[id(node)] = vid
        vertex = Vertex(vid, node)
        vertices[vid] = vertex
        split(node, vertex, set())
        # dependencies: every placeholder reachable in this vertex's subtree
        deps = {}
        for mn in _walk_materialized(node):
            deps[mn.tag] = True
        for rf_dep in vertex.feeds:
            deps[rf_dep] = True
        vertex.deps = list(deps)
        return vid

    def split(node: P.PlanNode, vertex: Vertex, visited) -> None:
        if id(node) in visited or isinstance(node, MaterializedNode):
            return
        visited.add(id(node))
        if isinstance(node, P.Scan):
            # runtime-filter producers become upstream BROADCAST vertices
            for rf in node.runtime_filters:
                dep = build(rf.producer)
                vertex.edge_types[dep] = BROADCAST
                vertex.feeds[dep] = None  # dependency only; executed inline
            return
        for i, child in enumerate(node.inputs):
            if isinstance(child, MaterializedNode):
                vertex.edge_types.setdefault(child.tag, _edge_type(node, i))
                continue
            if isinstance(child, P.ShuffleRead):
                # one hash lane of the shared producer subtree: the producer
                # compiles once (memoized) and every per-partition clone
                # reads its own lane of the partitioned SHUFFLE edge
                dep = build(child.source)
                placeholder = MaterializedNode(
                    child.output_names(), dep,
                    partition=child.partition,
                    num_partitions=child.num_partitions,
                    partition_keys=list(child.keys),
                    est_rows=child.est_rows,
                    schema=child.schema,
                )
                node.inputs[i] = placeholder
                vertex.edge_types[dep] = SHUFFLE
                continue
            if isinstance(child, _BLOCKING) or isinstance(node, P.Join):
                dep = build(child)
                placeholder = MaterializedNode(child.output_names(), dep,
                                               schema=child.schema)
                node.inputs[i] = placeholder
                vertex.edge_types[dep] = _edge_type(node, i)
            else:
                split(child, vertex, visited)

    root = build(plan)
    return TaskDAG(vertices, root)


def _walk_materialized(node: P.PlanNode, seen=None):
    seen = seen if seen is not None else set()
    if id(node) in seen:
        return
    seen.add(id(node))
    if isinstance(node, MaterializedNode):
        yield node
        return
    for c in node.inputs:
        yield from _walk_materialized(c, seen)
    if isinstance(node, P.Scan):
        for rf in node.runtime_filters:
            yield from _walk_materialized(rf.producer, seen)


def partitioned_edges(dag: TaskDAG) -> Dict[str, tuple]:
    """Producer vids whose partitioned readers agree on one
    ``(num_partitions, keys)`` spec — these edges get lane arrays; a
    producer read with conflicting specs (or only full-stream readers)
    stays a single exchange and partitioned readers filter at read time."""
    spec: Dict[str, tuple] = {}
    conflicted = set()
    for v in dag.vertices.values():
        for mn in _walk_materialized(v.plan):
            if mn.partition is None:
                continue
            this = (mn.num_partitions, tuple(mn.partition_keys))
            if mn.tag in spec and spec[mn.tag] != this:
                conflicted.add(mn.tag)
            spec.setdefault(mn.tag, this)
    return {tag: (n, list(keys)) for tag, (n, keys) in spec.items()
            if tag not in conflicted}


def describe_exchanges(dag: TaskDAG) -> List[str]:
    """One line per DAG edge: producer -> consumer, movement kind, and the
    lane count on partitioned shuffle boundaries (EXPLAIN rendering)."""
    lanes = partitioned_edges(dag)
    lines = []
    for vid in dag.topo_order():
        v = dag.vertices[vid]
        for dep in sorted(v.deps):
            kind = v.edge_types.get(dep, FORWARD)
            extra = ""
            if dep in lanes:
                n, keys = lanes[dep]
                extra = f" partitions={n} keys={keys}"
            sch = getattr(dag.vertices[dep].plan, "schema", None)
            if sch is not None:
                extra += f" schema=[{sch.describe()}]"
            lines.append(f"  {dep} -> {vid}: {kind}{extra}")
    return lines


@dataclass
class VertexMetrics:
    vid: str
    rows: int
    seconds: float
    speculated: bool = False
    spilled_rows: int = 0
    spilled_bytes: int = 0
    peak_buffered_rows: int = 0


class DAGScheduler:
    """Runs a task DAG in one of two modes.

    *Pipelined* (the default): every vertex is submitted in topological
    order and starts as soon as a worker is free; vertices exchange
    ``VectorBatch`` morsels through spill-aware :class:`Exchange` buffers,
    so a consumer processes its producer's first chunks while the producer
    is still running, and the root's chunks reach ``on_root_chunk`` (and
    from there the client's ``fetch_stream``) before the DAG finishes.
    Submission in topo order onto a FIFO pool guarantees progress: the
    earliest unfinished vertex always has every producer already running or
    done, and ``Exchange.put`` never blocks (overflow spills to scratch),
    so no producer can deadlock behind its consumers.

    *Barrier* (``exchange.pipeline = False``, and always under speculative
    execution): the pre-streaming behavior — each vertex materializes its
    whole output and downstream vertices start only when every dependency
    has finished.  Operators still stream morsels internally, so cancel/kill
    latency stays bounded by one morsel either way.
    """

    def __init__(
        self,
        pool: Optional[ThreadPoolExecutor] = None,
        speculative: bool = False,
        straggler_factor: float = 4.0,
        injected_delays: Optional[Dict[str, float]] = None,  # test hook
        vertex_delay: float = 0.0,  # debug/test hook: sleep per vertex
        adaptive=None,  # AdaptiveManager (pipelined mode only)
    ):
        self.pool = pool
        self.speculative = speculative
        self.straggler_factor = straggler_factor
        self.injected_delays = injected_delays or {}
        self.vertex_delay = vertex_delay
        self.adaptive = adaptive
        self.metrics: List[VertexMetrics] = []
        # serving tier: per-query shared-scan activity (ExecuteStage copies
        # this into q.info, surfaced through poll()/server_stats())
        self.shared_scan_stats = {"published": 0, "attached": 0,
                                  "fallbacks": 0}

    def execute(self, dag: TaskDAG, ctx: ExecContext,
                on_vertex_done: Optional[Callable] = None,
                on_root_chunk: Optional[Callable] = None) -> VectorBatch:
        own_pool = False
        pool = self.pool
        if pool is None:
            pool = ThreadPoolExecutor(max_workers=4, thread_name_prefix="container")
            own_pool = True
        pipelined = bool(ctx.config.get("exchange.pipeline", True)) \
            and not self.speculative
        try:
            if pipelined:
                return self._execute_pipelined(dag, ctx, pool,
                                               on_vertex_done, on_root_chunk)
            return self._execute_barrier(dag, ctx, pool,
                                         on_vertex_done, on_root_chunk)
        finally:
            if own_pool:
                pool.shutdown(wait=False)

    # ------------------------------------------------------------ pipelined
    def _execute_pipelined(self, dag: TaskDAG, ctx: ExecContext, pool,
                           on_vertex_done, on_root_chunk) -> VectorBatch:
        from .exchange import Exchange, ExchangeConfig
        from .shuffle import ShuffleWriter

        cancel_token = getattr(ctx, "cancel_token", None)
        excfg = ExchangeConfig(ctx.config,
                               ctx.config.get("exchange.spill_dir"))
        # observability: resolved once per query; every exchange built below
        # inherits the query's trace (None = off) and metrics registry
        trace = getattr(ctx, "trace", None)
        excfg.trace = trace
        excfg.metrics = getattr(ctx, "metrics", None)
        # partitioned SHUFFLE edges: a producer whose consumers all agree on
        # one (num_partitions, keys) spec writes through a ShuffleWriter lane
        # array; disagreeing specs (a subtree shared by differently-keyed
        # consumers) fall back to a plain exchange with read-time filtering
        lane_spec = partitioned_edges(dag)
        lane_readers: Dict[str, List[int]] = {
            tag: [0] * n for tag, (n, _) in lane_spec.items()
        }
        readers: Dict[str, int] = {vid: 0 for vid in dag.vertices}
        full_readers: Dict[str, int] = {vid: 0 for vid in dag.vertices}
        for v in dag.vertices.values():
            for mn in _walk_materialized(v.plan):
                readers[mn.tag] += 1
                if mn.tag in lane_spec and mn.partition is not None:
                    lane_readers[mn.tag][mn.partition] += 1
                else:
                    full_readers[mn.tag] += 1
        exchanges: Dict[str, object] = {}
        for vid in dag.vertices:
            if vid in lane_spec and vid != dag.root:
                n, keys = lane_spec[vid]
                exchanges[vid] = ShuffleWriter(
                    vid, excfg, n, keys, engine=ctx.engine,
                    batch_rows=int(ctx.config.get("shuffle.lane_batch_rows",
                                                  8192) or 8192))
            else:
                exchanges[vid] = Exchange(vid, excfg)
        # typed contract: every edge declares its producer's inferred output
        # schema; under debug.check_batches/REPRO_CHECK_BATCHES the exchange
        # asserts each morsel conforms (free when unset — declare_schema
        # leaves the put() fast path untouched)
        for vid, ex in exchanges.items():
            ex.declare_schema(getattr(dag.vertices[vid].plan, "schema", None))
        # refcount readers per edge: a single-consumer FORWARD edge (and a
        # single-reader shuffle lane) frees chunks (and unlinks spill files)
        # as they are consumed instead of retaining them until query end;
        # multi-consumer edges (shared-work reuse) and the root (replayed by
        # read_all) keep full retention
        for vid, ex in exchanges.items():
            if isinstance(ex, ShuffleWriter):
                ex.configure_retention(lane_readers[vid], full_readers[vid])
            else:
                ex.retain = readers[vid] != 1 or vid == dag.root
        lock = make_lock("dag.metrics")
        errors: List[BaseException] = []
        # serving tier: scan vertices whose output may be shared with (or
        # attached from) a concurrent query's identical scan
        registry = getattr(ctx, "shared_scans", None)
        shareable = self._shareable_vertices(dag, ctx, lane_spec) \
            if registry is not None else {}
        published: Dict[str, object] = {}  # vid -> registry key

        def stream_attached(handle, vid, out_ex) -> Optional[int]:
            """Replay a published exchange into this vertex's own edge.

            Returns the row count, or None when the producer failed before
            we emitted anything — the caller falls back to a fresh scan."""
            rows = 0
            try:
                for chunk in handle.reader():
                    if cancel_token is not None:
                        cancel_token.check()
                    rows += chunk.num_rows
                    out_ex.put(chunk)
                    if vid == dag.root and on_root_chunk is not None:
                        on_root_chunk(chunk)
            except BaseException:
                if rows == 0 and not (cancel_token is not None
                                      and cancel_token.is_set()):
                    return None
                raise
            finally:
                handle.release()
            return rows

        adaptive = self.adaptive

        def run_vertex(vid: str) -> None:
            out_ex = exchanges[vid]
            try:
                if cancel_token is not None:
                    cancel_token.check()
                if adaptive is not None:
                    # replanning gate: merge/clone vertices of adaptive
                    # edges wait here for the split / collapse decision;
                    # "skip" means the vertex was replanned away (its
                    # consumers were rewired through a validated mutation)
                    if adaptive.on_vertex_start(vid) == "skip":
                        out_ex.close()
                        return
                if vid in self.injected_delays:
                    time.sleep(self.injected_delays[vid])
                if self.vertex_delay:
                    time.sleep(self.vertex_delay)
                v = dag.vertices[vid]
                for mn in _walk_materialized(v.plan):
                    src = exchanges[mn.tag]
                    mn.source = (adaptive.source_for(vid, mn, src)
                                 if adaptive is not None else src)
                t0 = clock.perf_counter()
                frame = open_vertex_frame() if trace is not None else None
                rows: Optional[int] = None
                if vid in shareable:
                    key, table = shareable[vid]
                    handle = registry.attach(key)
                    if handle is not None:
                        rows = stream_attached(handle, vid, out_ex)
                        if rows is None:
                            registry.note_fallback()
                            emit_event(trace, f"serving:fallback:{vid}",
                                       "serving", table=table)
                            with lock:
                                self.shared_scan_stats["fallbacks"] += 1
                        else:
                            emit_event(trace, f"serving:attached:{vid}",
                                       "serving", table=table, rows=rows)
                            with lock:
                                self.shared_scan_stats["attached"] += 1
                    elif registry.publish(key, table, out_ex):
                        # keep every chunk for late attachers; the registry
                        # owns discard once consumers are attached
                        out_ex.retain = True
                        emit_event(trace, f"serving:published:{vid}",
                                   "serving", table=table)
                        with lock:
                            published[vid] = key
                            self.shared_scan_stats["published"] += 1
                if rows is None:
                    ex = _VertexExecutor(ctx)
                    rows = 0
                    for chunk in ex.stream(v.plan):
                        rows += chunk.num_rows
                        out_ex.put(chunk)
                        if vid == dag.root and on_root_chunk is not None:
                            on_root_chunk(chunk)
                out_ex.close()
                dt = clock.perf_counter() - t0
                st = out_ex.stats()
                if trace is not None:
                    lanes = st.get("lanes")
                    trace.add_vertex(
                        vid, t0, dt, wait_s=frame.wait_s,
                        spill_s=frame.spill_s, rows=rows,
                        lanes=([{"partition": i, **ln}
                                for i, ln in enumerate(lanes)]
                               if lanes else None))
                with lock:
                    self.metrics.append(VertexMetrics(
                        vid, rows, dt,
                        spilled_rows=st["spilled_rows"],
                        spilled_bytes=st["spilled_bytes"],
                        peak_buffered_rows=st["peak_buffered_rows"],
                    ))
                if adaptive is not None:
                    adaptive.note_vertex_done(vid, rows, dt)
                if on_vertex_done is not None:
                    on_vertex_done(vid, rows, st)
            except BaseException as exc:  # noqa: BLE001 - re-raised below
                out_ex.close(error=exc)
                if adaptive is not None \
                        and adaptive.note_vertex_error(vid, exc):
                    return  # absorbed: a replaced vertex / speculation loser
                with lock:
                    errors.append(exc)
                if cancel_token is not None and not cancel_token.is_set():
                    # wake sibling vertices blocked on other exchanges
                    cancel_token.cancel(f"vertex {vid} failed: {exc}")
            finally:
                close_vertex_frame()

        if adaptive is not None:
            adaptive.begin(dag, ctx, exchanges, lane_spec,
                           run_vertex=run_vertex, cancel_token=cancel_token)
        futures = [pool.submit(run_vertex, vid) for vid in dag.topo_order()]
        try:
            for fut in futures:
                fut.result()
            if adaptive is not None:
                # adaptive vertices (collapse targets, sub-lane consumers,
                # speculation clones) run on their own threads; the query is
                # done only when they are
                adaptive.wait()
            if errors:
                raise self._primary_error(errors)
            return exchanges[dag.root].read_all()
        finally:
            if adaptive is not None:
                adaptive.finish()
            # published exchanges may still feed attached consumers of other
            # queries: retire them through the registry, which discards when
            # the last consumer releases; the scratch dir (spilled chunks)
            # is likewise cleaned up only after the last of them releases
            state = {"held": 1}

            def released_one() -> None:
                with lock:
                    state["held"] -= 1
                    last = state["held"] == 0
                if last:
                    excfg.cleanup()

            for vid, ex in exchanges.items():
                key = published.get(vid)
                if key is None:
                    ex.discard()
                else:
                    with lock:
                        state["held"] += 1
                    if registry.retire(key, ex, on_final=released_one):
                        released_one()
            released_one()

    @staticmethod
    def _shareable_vertices(dag: TaskDAG, ctx: ExecContext,
                            lane_spec) -> Dict[str, tuple]:
        """Scan vertices eligible for the serving tier's shared-scan path.

        A vertex qualifies when it is a pure fused scan pipeline — exactly
        one managed-table :class:`~..optimizer.plan.Scan`, no federated
        scans, no runtime-filter inputs, no upstream edges — writing a
        plain (unpartitioned) exchange.  The registry key combines the
        vertex plan's ``key()`` (table, columns, pushed/partition filters,
        min write-ID), the query parameters and the table's ``(hwm,
        invalid)`` write-ID state, so only transactionally identical scans
        ever share an exchange."""
        out: Dict[str, tuple] = {}
        for vid, v in dag.vertices.items():
            if v.deps or (vid in lane_spec and vid != dag.root):
                continue
            nodes = list(P.walk_plan(v.plan))
            scans = [n for n in nodes if isinstance(n, P.Scan)]
            if len(scans) != 1:
                continue
            if any(isinstance(n, (P.FederatedScan, MaterializedNode))
                   for n in nodes):
                continue
            sc = scans[0]
            if getattr(sc.table, "handler", None) or sc.runtime_filters:
                continue
            try:
                wl = ctx.widlist(sc.table.name)
            except Exception:
                continue
            key = (v.plan.key(), repr(ctx.params), ctx.engine,
                   bool(ctx.config.get("keep_acid_cols")),
                   sc.table.name, wl.hwm, frozenset(wl.invalid))
            out[vid] = (key, sc.table.name)
        return out

    @staticmethod
    def _primary_error(errors: List[BaseException]) -> BaseException:
        # surface the root cause, not a secondary cancellation triggered by
        # the failure-propagation cancel above
        from .cancel import QueryCancelledError

        for exc in errors:
            if not isinstance(exc, QueryCancelledError):
                return exc
        return errors[0]

    # ------------------------------------------------------------ barrier
    def _execute_barrier(self, dag: TaskDAG, ctx: ExecContext, pool,
                         on_vertex_done, on_root_chunk) -> VectorBatch:
        cancel_token = getattr(ctx, "cancel_token", None)
        trace = getattr(ctx, "trace", None)
        results: Dict[str, VectorBatch] = {}
        done: Set[str] = set()
        order = dag.topo_order()
        pending: Dict[str, Future] = {}
        durations: List[float] = []
        lock = make_lock("dag.metrics")

        def run_vertex(vid: str) -> VectorBatch:
            # the vertex start is a cancellation point; operator loops also
            # observe the token at every batch boundary, so even speculated
            # clones of a cancelled vertex stop within one morsel
            if cancel_token is not None:
                cancel_token.check()
            if vid in self.injected_delays:
                time.sleep(self.injected_delays[vid])
            if self.vertex_delay:
                time.sleep(self.vertex_delay)
            v = dag.vertices[vid]
            for mn in _walk_materialized(v.plan):
                mn.batch = results[mn.tag]
            t0 = clock.perf_counter()
            ex = _VertexExecutor(ctx)
            out = ex.execute(v.plan)
            dt = clock.perf_counter() - t0
            if trace is not None:
                # barrier mode has no exchanges: the whole wall is compute
                trace.add_vertex(vid, t0, dt, rows=out.num_rows)
            with lock:
                durations.append(dt)
                self.metrics.append(VertexMetrics(vid, out.num_rows, dt))
            return out

        remaining = list(order)
        while remaining or pending:
            if cancel_token is not None:
                cancel_token.check()
            # launch every vertex whose deps are satisfied
            for vid in list(remaining):
                v = dag.vertices[vid]
                if all(d in done for d in v.deps):
                    pending[vid] = pool.submit(run_vertex, vid)
                    remaining.remove(vid)
            if not pending:
                raise RuntimeError("DAG deadlock (cyclic dependencies?)")
            completed, _ = wait(list(pending.values()), return_when=FIRST_COMPLETED,
                                timeout=self._speculation_timeout(durations))
            if not completed and self.speculative:
                # straggler: speculatively clone the slowest pending vertex
                vid = next(iter(pending))
                self.injected_delays.pop(vid, None)
                spec = pool.submit(run_vertex, vid)
                old = pending[vid]
                pending[vid] = spec
                old.cancel()
                with lock:
                    self.metrics.append(VertexMetrics(vid, -1, 0.0, True))
                continue
            for vid in list(pending):
                fut = pending[vid]
                if fut.done():
                    results[vid] = fut.result()
                    done.add(vid)
                    del pending[vid]
                    if on_vertex_done is not None:
                        # barrier mode buffers each vertex's whole output
                        on_vertex_done(vid, results[vid].num_rows, {
                            "spilled_rows": 0, "spilled_bytes": 0,
                            "peak_buffered_rows": results[vid].num_rows,
                        })
        root = results[dag.root]
        if on_root_chunk is not None:
            for chunk in root.iter_chunks():
                on_root_chunk(chunk)
        return root

    def _speculation_timeout(self, durations: List[float]) -> Optional[float]:
        if not self.speculative or not durations:
            return None
        med = sorted(durations)[len(durations) // 2]
        return max(med * self.straggler_factor, 0.05)


class _VertexExecutor(Executor):
    def _stream_materializednode(self, node: MaterializedNode):
        if node.source is not None:  # pipelined: replay the edge's exchange
            from .shuffle import ShuffleWriter, partition_select

            if node.sub_lane is not None:
                # adaptive hot-lane split: one round-robin sub-lane of a
                # split shuffle lane
                yield from node.source.sub_lane_reader(node.sub_lane)
                return
            if node.partition is not None:
                if isinstance(node.source, ShuffleWriter):
                    yield from node.source.lane_reader(node.partition)
                    return
                # conflicting-spec fallback: full stream, filtered per chunk
                for chunk in node.source.reader():
                    self._checkpoint()  # cancel point per replayed chunk
                    yield partition_select(
                        chunk, node.partition_keys, node.partition,
                        node.num_partitions, self.ctx.engine)
                return
            yield from node.source.reader()
            return
        assert node.batch is not None, f"edge {node.tag} not materialized"
        if node.partition is not None:  # barrier mode: filter to the lane
            from .shuffle import partition_select

            yield from self._emit(partition_select(
                node.batch, node.partition_keys, node.partition,
                node.num_partitions, self.ctx.engine))
            return
        yield from self._emit(node.batch)
