"""LRFU (Least Recently/Frequently Used) eviction policy (paper §5.1).

LLAP's default cache policy: each cached item carries a CRF (combined
recency-frequency) score ``F(0) + sum 2^(-lambda * age_i)`` over its past
accesses.  ``lambda`` interpolates between LRU (lambda -> large) and LFU
(lambda -> 0); the default is tuned for analytic scan-heavy workloads.
Eviction removes the lowest-CRF item.  The unit of eviction is the *chunk*
(row-group x column), matching the paper's compromise between bookkeeping
overhead and storage efficiency.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Dict, Hashable, Optional, Tuple


class LRFUPolicy:
    def __init__(self, lam: float = 0.01):
        self.lam = lam
        self.clock = itertools.count()
        self._crf: Dict[Hashable, float] = {}
        self._last: Dict[Hashable, int] = {}
        self._heap: list = []  # (crf_snapshot, tiebreak, key) lazy heap

    def _decay(self, crf: float, dt: int) -> float:
        return crf * (2.0 ** (-self.lam * dt))

    def on_access(self, key: Hashable) -> None:
        now = next(self.clock)
        old = self._crf.get(key, 0.0)
        dt = now - self._last.get(key, now)
        crf = 1.0 + self._decay(old, dt)
        self._crf[key] = crf
        self._last[key] = now
        heapq.heappush(self._heap, (crf, now, key))

    def on_remove(self, key: Hashable) -> None:
        self._crf.pop(key, None)
        self._last.pop(key, None)

    def victim(self) -> Optional[Hashable]:
        """Pop the key with the lowest current CRF (lazy-invalidated heap)."""
        while self._heap:
            crf_snap, at, key = heapq.heappop(self._heap)
            if key not in self._crf:
                continue
            # stale heap entry? current CRF recomputed at its last access
            if self._crf[key] > crf_snap + 1e-12 or self._last[key] != at:
                continue
            return key
        # fallback: linear scan (heap starved by staleness)
        if self._crf:
            now = next(self.clock)
            return min(
                self._crf,
                key=lambda k: self._decay(self._crf[k], now - self._last[k]),
            )
        return None
