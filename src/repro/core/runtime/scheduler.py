"""Asynchronous query scheduling (HiveServer2 async operations, paper §2/§5.2).

The paper's HiveServer2 serves many interactive clients at once: a client
submits a statement and gets back an *operation handle* it can poll, cancel,
or fetch from, while the server drives execution on a worker pool behind the
workload manager's admission control.  This module is that server side:

  * :class:`QueryTask` — the server-side state of one submitted statement:
    a QUEUED → ADMITTED → RUNNING → SUCCEEDED/FAILED/CANCELLED state
    machine, a :class:`~repro.core.runtime.cancel.CancelToken`, progress
    counters (DAG vertices done/total, pool, queue wait), and a
    :class:`ResultStream` for incremental fetches;
  * :class:`QueryScheduler` — runs submitted statements on a bounded worker
    pool.  Queries pass through WLM admission (blocking until their pool has
    capacity, §5.2) and then the staged ``QueryPipeline``; DML/DDL run
    directly under their usual single-statement transactions.

The public face of a task is :class:`repro.api.handle.QueryHandle`.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from queue import Empty, Full, Queue
from typing import Dict, Iterator, Optional, Tuple

from ...analysis.lockdep import make_condition, make_lock
from ..obs.trace import QueryTrace, emit_event, make_span, tracing_enabled
from ..sql import ast as A
from .cancel import CancelToken, QueryCancelledError
from .vector import VectorBatch

QUEUED = "QUEUED"
ADMITTED = "ADMITTED"
RUNNING = "RUNNING"
SUCCEEDED = "SUCCEEDED"
FAILED = "FAILED"
CANCELLED = "CANCELLED"
TERMINAL_STATES = (SUCCEEDED, FAILED, CANCELLED)

_POLL_S = 0.05  # producer/consumer wake-up to observe cancel/detach
_STREAM_STALL_S = 60.0  # give up on a consumer that stopped draining

DEFAULT_STREAM_BATCH_ROWS = 4096


def stream_batch_rows(config: dict) -> int:
    """Rows per streamed batch for a session config (single authority)."""
    return int(config.get("stream_batch_rows", DEFAULT_STREAM_BATCH_ROWS)
               or DEFAULT_STREAM_BATCH_ROWS)


class ResultStream:
    """Bounded hand-off of result row-batches from the executing worker to a
    consumer iterating ``QueryHandle.fetch_stream()``.

    The queue is small on purpose: a lagging consumer exerts backpressure on
    the producer (the worker thread blocks in :meth:`publish`), which is what
    lets a client observe batches while the query is still ``RUNNING``.  The
    producer detaches cleanly if the consumer abandons the iterator, and
    ``publish`` is first-wins so the mid-execution emit (DAG root output) and
    the post-completion fallback (cache hits, replays) never double-stream.
    """

    _DONE = object()

    def __init__(self, maxsize: int = 2):
        self._q: Queue = Queue(maxsize)
        self._lock = make_lock("scheduler.result_stream")
        self._active = False          # a consumer is (or will be) iterating
        self._started = False         # a producer reached its emit point
        self._detached = False        # consumer abandoned the iterator
        self._live = False            # incremental emit() streaming is on
        self.batch_rows: Optional[int] = None  # consumer-requested page size

    # -------------------------------------------------------- consumer side
    def activate(self, batch_rows: Optional[int] = None) -> bool:
        """Claim live streaming; ``False`` means the producer already passed
        its emit point and the caller should replay the final result."""
        with self._lock:
            if self._started:
                return False
            self._active = True
            if batch_rows:
                self.batch_rows = int(batch_rows)
            return True

    def __iter__(self) -> Iterator[VectorBatch]:
        try:
            while True:
                item = self._q.get()
                if item is self._DONE:
                    return
                if isinstance(item, BaseException):
                    raise item
                yield item
        finally:
            self._detached = True

    @staticmethod
    def iter_slices(batch: VectorBatch, rows: int) -> Iterator[VectorBatch]:
        """The one slicing rule shared by live streaming and replay."""
        rows = max(int(rows), 1)
        for lo in range(0, batch.num_rows, rows):
            yield batch.slice(lo, lo + rows)

    # -------------------------------------------------------- producer side
    def publish(self, batch: VectorBatch, default_batch_rows: int,
                cancel_token: Optional[CancelToken] = None) -> None:
        """Slice ``batch`` into row-batches and stream them to the consumer.
        First call wins; a no-op when no consumer attached in time."""
        with self._lock:
            if self._started:
                return
            self._started = True
            if not self._active:
                return
            rows = self.batch_rows or default_batch_rows
        for piece in self.iter_slices(batch, rows):
            self._put(piece, cancel_token)

    def emit(self, batch: VectorBatch, default_batch_rows: int,
             cancel_token: Optional[CancelToken] = None) -> None:
        """Incrementally stream one engine morsel to a live consumer.

        Called by the executing worker for every root-vertex chunk as the
        DAG produces it, so the consumer sees first rows while upstream
        vertices are still running.  The first call claims the stream (the
        post-completion ``publish`` fallback then no-ops); when no consumer
        attached before the first chunk, emits are dropped and the finished
        handle replays the final result instead."""
        with self._lock:
            if not self._started:
                self._started = True
                self._live = self._active
            if not self._live:
                return
            rows = self.batch_rows or default_batch_rows
        for piece in self.iter_slices(batch, rows) if batch.num_rows else ():
            self._put(piece, cancel_token)

    def abort_live(self, error: BaseException) -> None:
        """Fail a live consumer mid-stream (e.g. §4.2 re-execution after
        chunks already streamed): the partial prefix must not be silently
        passed off as a complete result."""
        with self._lock:
            if not self._live or self._detached:
                return
            self._live = False
        self._flush_error(error)

    def close(self) -> None:
        """Terminate the stream (always called by the worker, success or
        not), so a blocked consumer wakes up."""
        with self._lock:
            self._started = True  # late activate() must take the replay path
        self._put(self._DONE, None)

    def _put(self, item, cancel_token: Optional[CancelToken]) -> None:
        stalled_since = time.monotonic()
        while not self._detached:
            if cancel_token is not None:
                cancel_token.check()
            try:
                self._q.put(item, timeout=_POLL_S)
                return
            except Full:
                # backstop: a consumer that claimed the stream but stopped
                # draining it must not pin a worker thread forever.  Swap the
                # queued batches for an error so a late-waking consumer gets
                # a loud failure, never a silent truncation or a hung get()
                if time.monotonic() - stalled_since > _STREAM_STALL_S:
                    self._detached = True
                    self._flush_error(RuntimeError(
                        f"fetch_stream consumer stalled for more than "
                        f"{_STREAM_STALL_S:.0f}s; stream abandoned"
                    ))
                    return

    def _flush_error(self, error: BaseException) -> None:
        while True:
            try:
                self._q.get_nowait()
            except Empty:
                break
        try:
            self._q.put_nowait(error)
        except Full:  # consumer raced a get(); queue has room next round
            pass


class QueryTask:
    """Server-side state of one asynchronously submitted statement."""

    def __init__(self, qid: str, sql: str, stmt, params: Tuple, config: dict):
        self.qid = qid
        self.sql = sql
        self.stmt = stmt
        self.params = tuple(params)
        self.config = config
        self.cancel_token = CancelToken()
        self.stream = ResultStream()
        # per-query structured trace (PR 10): None unless obs.tracing /
        # REPRO_OBS_TRACING is on — every instrumented hot path then pays
        # one attribute test and allocates no span objects
        self.trace = QueryTrace(qid, sql) if tracing_enabled(config) else None
        self.submitted_at = time.time()
        self.admitted_at: Optional[float] = None
        self.wlm = None                        # set by QueryScheduler.submit
        self.serving_stats = None              # set by QueryScheduler.submit
        self._cond = make_condition(name="scheduler.task")
        self._state = QUEUED
        self.result = None                     # QueryResult on SUCCEEDED
        self.error: Optional[BaseException] = None
        self._progress: Dict[str, object] = {
            "pool": None, "vertices_total": 0, "vertices_done": 0,
            "rows_spilled": 0, "bytes_spilled": 0, "spill": {},
            "peak_buffered_rows": 0, "lanes": {}, "shared_scans": {},
            "adaptive": [],
        }

    # ------------------------------------------------------------- state
    @property
    def state(self) -> str:
        with self._cond:
            return self._state

    def done(self) -> bool:
        return self.state in TERMINAL_STATES

    def _set_state(self, state: str) -> None:
        with self._cond:
            if self._state in TERMINAL_STATES:
                return
            self._state = state
            self._cond.notify_all()

    def _finish(self, state: str, result=None,
                error: Optional[BaseException] = None) -> None:
        with self._cond:
            if self._state in TERMINAL_STATES:
                return
            self._state = state
            self.result = result
            self.error = error
            self._cond.notify_all()

    # ------------------------------------------------------------- client ops
    def wait(self, timeout: Optional[float] = None):
        """Block until terminal; return the QueryResult or raise the
        query's error (TimeoutError if still running after ``timeout``)."""
        with self._cond:
            if not self._cond.wait_for(
                lambda: self._state in TERMINAL_STATES, timeout
            ):
                raise TimeoutError(
                    f"query {self.qid} still {self._state} "
                    f"after {timeout:.3f}s"
                )
            if self._state == SUCCEEDED:
                return self.result
            raise self.error

    def cancel(self, reason: str = "cancelled by client") -> bool:
        """Request cooperative cancellation; ``False`` when the query
        already reached SUCCEEDED or FAILED.

        ``True`` means the request was accepted, checked atomically against
        the state transition (the worker finishes under the same lock); a
        query past its last cancellation point may still complete."""
        with self._cond:
            if self._state in TERMINAL_STATES:
                return self._state == CANCELLED
            self.cancel_token.cancel(reason)
            return True

    def poll(self) -> Dict[str, object]:
        """Progress snapshot: state, pool, vertices done/total, queue wait,
        per-vertex spill (rows/bytes) and per-pool admission queue depth."""
        with self._cond:
            out = dict(self._progress)
            out["spill"] = {k: dict(v) for k, v in out["spill"].items()}
            out["lanes"] = {k: [dict(l) for l in v]
                            for k, v in out["lanes"].items()}
            out["adaptive"] = [dict(ev) for ev in out["adaptive"]]
            out["state"] = self._state
            out["queue_wait_ms"] = (
                round((self.admitted_at - self.submitted_at) * 1e3, 3)
                if self.admitted_at is not None else None
            )
        if self.wlm is not None:
            out["pool_queue_depth"] = self.wlm.queue_depths()
        if self.serving_stats is not None:
            # warehouse-wide serving-tier counters (result-cache hit/miss/
            # eviction, shared-scan attach/publish) alongside this query's
            # own shared_scans progress entry
            out["serving"] = self.serving_stats()
        return out

    # ------------------------------------------------------------- execution
    def note_pool(self, pool: Optional[str]) -> None:
        with self._cond:
            self._progress["pool"] = pool

    def note_vertices_total(self, total: int) -> None:
        with self._cond:
            self._progress["vertices_total"] = total
            self._progress["vertices_done"] = 0

    def note_shared_scans(self, stats: Dict[str, int]) -> None:
        with self._cond:
            self._progress["shared_scans"] = dict(stats)

    def note_adaptive(self, event: Dict[str, object]) -> None:
        """One adaptive replanning decision (lane split, fan-out collapse,
        speculation swap, elided shuffle, declined mutation)."""
        with self._cond:
            self._progress["adaptive"].append(dict(event))

    def note_vertex_done(self, vid: Optional[str] = None,
                         stats: Optional[Dict[str, int]] = None) -> None:
        with self._cond:
            self._progress["vertices_done"] = (
                int(self._progress["vertices_done"]) + 1
            )
            if stats and vid is not None:
                self._progress["spill"][vid] = {
                    "rows": int(stats.get("spilled_rows", 0)),
                    "bytes": int(stats.get("spilled_bytes", 0)),
                }
                if stats.get("lanes"):
                    # per-lane rows/bytes/spill of a partitioned shuffle
                    # edge: skew across lanes is visible while running
                    self._progress["lanes"][vid] = [
                        dict(lane) for lane in stats["lanes"]
                    ]
                self._progress["rows_spilled"] = sum(
                    v["rows"] for v in self._progress["spill"].values())
                self._progress["bytes_spilled"] = sum(
                    v["bytes"] for v in self._progress["spill"].values())
                self._progress["peak_buffered_rows"] = max(
                    int(self._progress["peak_buffered_rows"]),
                    int(stats.get("peak_buffered_rows", 0)),
                )


class QueryScheduler:
    """Executes submitted statements on a worker pool behind WLM admission.

    One scheduler per :class:`~repro.core.session.Warehouse`; sessions submit
    through it, so per-pool ``query_parallelism`` is enforced across every
    connection of the deployment (paper §5.2).
    """

    def __init__(self, warehouse, max_workers: int = 8):
        self.wh = warehouse
        self._pool = ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="query-worker"
        )
        self._tasks: Dict[str, QueryTask] = {}
        self._lock = make_lock("scheduler.global")
        self._closed = False

    # ------------------------------------------------------------- submit
    def submit(self, session, stmt, sql: str = "",
               params: Tuple = ()) -> QueryTask:
        if self._closed:
            raise RuntimeError("scheduler is shut down")
        qid = f"q{next(self.wh._qid)}"
        task = QueryTask(qid, sql, stmt, params, dict(session.config))
        task.wlm = self.wh.wlm
        task.serving_stats = self.wh.serving_stats
        with self._lock:
            self._tasks[qid] = task
        self._pool.submit(self._run, session, task)
        return task

    def running(self) -> Dict[str, QueryTask]:
        with self._lock:
            return dict(self._tasks)

    def shutdown(self) -> None:
        self._closed = True
        for task in self.running().values():
            task.cancel("scheduler shut down")
        self._pool.shutdown(wait=False)

    # ------------------------------------------------------------- worker
    def _run(self, session, task: QueryTask) -> None:
        wlm = self.wh.wlm
        admitted = False
        cache_hit = False
        try:
            task.cancel_token.check()
            stmt = task.stmt
            executes_query = isinstance(stmt, (A.Select, A.SetOp)) or (
                isinstance(stmt, A.Explain) and stmt.analyze
                and isinstance(stmt.stmt, (A.Select, A.SetOp))
            )
            if executes_query:
                # serving tier: probe the result cache *before* admission —
                # a repeated dashboard query is answered from cache without
                # taking a WLM slot or executing anything
                result, pre = session._probe_result_cache(task)
                if result is not None:
                    cache_hit = True
                    task.admitted_at = time.time()
                    task._set_state(RUNNING)
                else:
                    # queries (and EXPLAIN ANALYZE, which runs one) queue
                    # behind WLM admission, then take the staged pipeline
                    # with the task threaded through for progress,
                    # cancellation, and streaming.  If admission fails
                    # while we hold a pending cache entry from the probe,
                    # release the waiters queued behind it.
                    try:
                        with make_span(task.trace, "wlm:admission_wait",
                                       "wlm"):
                            slot = wlm.wait_admit(
                                task.qid,
                                task.config.get("user"),
                                task.config.get("application"),
                                cancel_token=task.cancel_token,
                            )
                    except BaseException:
                        if (pre is not None and pre.cacheable
                                and pre.filling):
                            self.wh.result_cache.cancel_pending(
                                pre.result_key)
                        raise
                    admitted = slot is not None
                    if admitted:
                        emit_event(task.trace, "wlm:admitted", "wlm",
                                   pool=slot.pool)
                    task.admitted_at = time.time()
                    task.note_pool(slot.pool if slot is not None else None)
                    task._set_state(ADMITTED)
                    task._set_state(RUNNING)
                    result = session._run_query_task(task, slot, pre=pre)
            else:
                # DML/DDL: single-statement transactions, no WLM admission
                task.admitted_at = time.time()
                task._set_state(RUNNING)
                result = session.execute_stmt(task.stmt, task.sql,
                                              task.params or None)
            # fallback publish for paths that skipped the mid-execution emit
            # (result-cache hits, DML); first-wins, so no double streaming
            if result is not None and result.batch is not None:
                task.stream.publish(result.batch,
                                    stream_batch_rows(task.config),
                                    task.cancel_token)
            task._finish(SUCCEEDED, result=result)
        except QueryCancelledError as exc:
            task._finish(CANCELLED, error=exc)
        except BaseException as exc:  # noqa: BLE001 - surfaced via handle
            task._finish(FAILED, error=exc)
        finally:
            if admitted:
                wlm.release(task.qid)
            task.stream.close()
            self._note_done(task, cache_hit)
            with self._lock:
                self._tasks.pop(task.qid, None)

    def _note_done(self, task: QueryTask, cache_hit: bool) -> None:
        """Record the finished statement with the warehouse observability
        tier: the always-on query-log ring, outcome metrics, and — when the
        query was traced — the bounded trace store behind
        ``Connection.export_trace``."""
        obs = getattr(self.wh, "obs", None)
        if obs is None:  # pragma: no cover - warehouse always wires obs
            return
        rows = None
        result = task.result
        if result is not None and getattr(result, "batch", None) is not None:
            rows = int(result.batch.num_rows)
        with task._cond:
            pool = task._progress.get("pool")
        entry = {
            "qid": task.qid,
            "sql": task.sql,
            "status": task.state,
            "wall_ms": round((time.time() - task.submitted_at) * 1e3, 3),
            "queue_wait_ms": (
                round((task.admitted_at - task.submitted_at) * 1e3, 3)
                if task.admitted_at is not None else None
            ),
            "rows": rows,
            "pool": pool,
            "cache_hit": cache_hit,
        }
        if task.error is not None:
            entry["error"] = str(task.error)
        try:
            obs.note_query_done(entry, trace=task.trace)
        except Exception:  # pragma: no cover - observability must not fail
            pass            # the query it observes
