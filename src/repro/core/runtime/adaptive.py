"""Adaptive query execution: live-telemetry replanning (paper §4.2).

Static plans commit to a lane fan-out, a partitioning, and a vertex
placement before the first row is read; this module re-plans a *running*
pipelined DAG from the shuffle service's live telemetry:

  * **hot-lane split** — a shuffle lane whose observed rows exceed
    ``adaptive.skew_ratio`` over the lane median gets its *remaining*
    stream re-partitioned round-robin across fresh sub-lanes, each drained
    by a cloned consumer; the merge vertex is rebound with a merging-fold
    Aggregate so partials for the same group re-combine exactly;
  * **payoff-gated fan-out collapse** — per-lane consumers of an ``auto``
    fan-out hold at a gate until the producer's live row count proves the
    CBO estimate that chose the lane count; if the producer closes below
    the payoff threshold the lanes collapse to a single full-stream
    consumer (the BENCH_PR5 mis-estimate regression, fixed at run time);
  * **pipelined straggler speculation** (``adaptive.speculation``) — a
    lane consumer running far past the median of its finished siblings is
    cloned into a fresh exchange *under the pipelined scheduler*, and the
    merge's reader swaps to the first finisher atomically (before it has
    committed to the original's stream).

Every mid-query DAG mutation flows through :meth:`AdaptiveManager._adopt`,
which applies the mutation, runs ``repro.analysis.check_dag`` on the
result, and rolls the mutation back (recording a ``declined`` event) if
validation fails — the scheduler never executes an unvalidated shape.
Lint rule REP005 enforces the chokepoint statically: adopted-DAG mutations
outside this module are findings.

Decisions are appended to an event list surfaced through
``poll()["adaptive"]`` and EXPLAIN ANALYZE.
"""
from __future__ import annotations

import copy
import os
import threading
import time
from typing import Dict, List, Optional

from ...analysis.lockdep import make_condition
from ...analysis.plan_validator import PlanValidationError, check_dag
from ..obs import clock
from ..obs.trace import emit_event
from ..optimizer import plan as P
from ..sql import ast as A
from .dag import FORWARD, SHUFFLE, MaterializedNode, Vertex, \
    _walk_materialized
from .exchange import Exchange
from .shuffle import _MERGE_FOLD, AUTO_ROWS_PER_PARTITION, ShuffleWriter


def _median(xs: List[float]) -> float:
    return sorted(xs)[len(xs) // 2] if xs else 0.0


class SwappableSource:
    """A merge-side edge reader that can be atomically re-pointed at a
    speculation clone's exchange until the moment it *commits* to the
    original (claims its first available chunk).

    The commit point is claiming availability, not blocking on the
    original — the drain polls :meth:`Exchange.available` and only steps
    the underlying reader when it cannot block, so a swap request always
    finds the reader either uncommitted (swap wins) or already committed
    (swap refused, original's stream is authoritative)."""

    def __init__(self, tag: str, orig: Exchange):
        self.tag = tag
        self._orig = orig
        self._winner: Optional[Exchange] = None
        self._committed = False
        self._resolved = False  # True once no swap can ever arrive
        self._cond = make_condition(name="adaptive.swap")

    # ------------------------------------------------------------ manager
    def try_swap(self, winner: Exchange) -> bool:
        """Point the reader at ``winner`` unless it already committed."""
        with self._cond:
            if self._committed:
                return False
            self._winner = winner
            self._cond.notify_all()
            return True

    def resolve(self) -> None:
        """No swap will arrive anymore (speculation lost or query ending)."""
        with self._cond:
            self._resolved = True
            self._cond.notify_all()

    @property
    def committed(self) -> bool:
        with self._cond:
            return self._committed

    # ------------------------------------------------------------ consumer
    def reader(self):
        it = self._orig.reader()
        i = 0
        while True:
            with self._cond:
                if self._winner is not None and not self._committed:
                    break
                ready = self._orig.available(i)
                if ready and not self._committed and not self._resolved \
                        and self._orig.failed():
                    # the original died before we claimed it: hold out for
                    # a first-finisher swap instead of surfacing the error
                    self._cond.wait(0.05)
                    continue
                if ready:
                    self._committed = True
            if not ready:
                with self._cond:
                    if self._winner is None and not self._resolved:
                        self._cond.wait(0.02)
                continue
            try:
                chunk = next(it)
            except StopIteration:
                return
            i += 1
            yield chunk
        yield from self._winner.reader()


class _AggEdge:
    """One adaptive shuffle edge: a ShuffleWriter producer fanning out to
    per-lane grouped-Aggregate clones merged by a UNION ALL vertex."""

    def __init__(self, producer: str, writer: ShuffleWriter,
                 clones: Dict[int, str], merge: str, union: P.Union,
                 group_keys: List[str], aggs: List[P.AggSpec],
                 est_rows: Optional[float], payoff_threshold: int):
        self.producer = producer
        self.writer = writer
        self.clones = dict(clones)      # lane -> clone vid
        self.merge = merge
        self.union = union
        self.group_keys = list(group_keys)
        self.aggs = list(aggs)
        self.est_rows = est_rows
        self.payoff_threshold = payoff_threshold
        self.payoff_gated = False       # clones held at the gate?
        self.folded = False             # merge wrapped in a fold Aggregate?
        self.split_lanes: List[int] = []
        self.done = False               # producer closed
        self.collapsed = False
        self.progress_total = 0         # rows seen at last skew evaluation


class AdaptiveManager:
    """Replans one running pipelined DAG from live telemetry.

    Created per query by the execute stage (``adaptive.enabled`` and
    pipelined mode only) and handed to :class:`~.dag.DAGScheduler`; the
    scheduler calls ``begin`` / ``on_vertex_start`` / ``source_for`` /
    ``note_vertex_done`` / ``note_vertex_error`` / ``wait`` / ``finish``.
    All manager state is guarded by one condition (`adaptive.manager`);
    the lock order is manager -> swap -> exchange, never reversed."""

    def __init__(self, config: dict, events: Optional[list] = None,
                 on_event=None, plan_cache=None, trace=None):
        self.config = config
        self.events = events if events is not None else []
        self.on_event = on_event
        self.trace = trace  # QueryTrace (None = tracing off, PR 10)
        self.plan_cache = plan_cache
        self.skew_ratio = float(config.get("adaptive.skew_ratio", 4.0))
        self.split_min_rows = int(config.get("adaptive.split_min_rows",
                                             65_536))
        self.split_ways = int(config.get("adaptive.split_ways", 0) or 0)
        # telemetry callback throttle: re-evaluate skew only after the
        # stream grows by this many rows (the verdict can't flip sooner)
        self._progress_step = max(self.split_min_rows // 8, 4096)
        self.speculation = bool(config.get("adaptive.speculation", False))
        self.straggler_factor = float(
            config.get("adaptive.straggler_factor", 4.0))
        self.straggler_min_s = float(
            config.get("adaptive.straggler_min_s", 0.2))
        self.payoff_threshold = int(
            config.get("shuffle.auto_rows_per_partition",
                       AUTO_ROWS_PER_PARTITION))
        self._auto = config.get("shuffle.partitions", 1) == "auto"
        self._cond = make_condition(name="adaptive.manager")
        self._edges: Dict[str, _AggEdge] = {}        # producer vid -> edge
        self._gated: Dict[str, str] = {}             # vid -> gate kind
        self._skip: set = set()
        self._abandoned: set = set()
        self._staged: set = set()                    # un-adopted spec clones
        self._started: Dict[str, float] = {}
        self._done: Dict[str, float] = {}            # vid -> duration
        self._swappables: Dict[str, SwappableSource] = {}
        self._spec_groups: Dict[str, List[str]] = {}  # producer -> clone vids
        self._spec_clone_of: Dict[str, str] = {}     # clone vid -> original
        self._spec_of: Dict[str, str] = {}           # original -> clone vid
        self._spec_merge: Dict[str, str] = {}        # original -> merge vid
        self._threads: List[threading.Thread] = []
        self._monitor: Optional[threading.Thread] = None
        self._counter = 0
        self._finished = False
        self.dag = None

    # ================================================================ setup
    def begin(self, dag, ctx, exchanges, lane_spec, run_vertex,
              cancel_token=None) -> None:
        self.dag = dag
        self.ctx = ctx
        self.exchanges = exchanges
        self.run_vertex = run_vertex
        self.cancel_token = cancel_token
        self.excfg = exchanges[dag.root].cfg
        # lane consumers per ShuffleWriter producer
        lane_consumers: Dict[str, Dict[int, str]] = {}
        merge_of: Dict[str, str] = {}  # clone vid -> consumer vid
        for vid, vert in dag.vertices.items():
            for mn in _walk_materialized(vert.plan):
                if mn.partition is not None \
                        and isinstance(exchanges.get(mn.tag), ShuffleWriter):
                    lane_consumers.setdefault(mn.tag, {})
                    lane_consumers[mn.tag].setdefault(mn.partition, vid)
        for tag, clones in lane_consumers.items():
            edge = self._eligible_agg_edge(tag, clones)
            if edge is not None:
                self._edges[tag] = edge
                # the merge only sees data once the producer closes (its
                # inputs are grouped aggregates over full lanes), so gating
                # it until the split/collapse decision is free
                self._gated[edge.merge] = "merge"
                # gate only inside the estimate's uncertainty band: when
                # the CBO claims several times the fan-out threshold, even
                # a big overestimate still leaves the lanes worthwhile, and
                # holding consumers at the gate just costs overlap
                if self._auto and edge.est_rows is not None \
                        and edge.est_rows < 4 * self.payoff_threshold:
                    edge.payoff_gated = True
                    for cvid in edge.clones.values():
                        self._gated[cvid] = "payoff"
                edge.writer.on_progress = self._on_writer_progress
            if self.speculation:
                merge = self._single_consumer_of(set(clones.values()))
                if merge is None:
                    continue
                self.exchanges[tag].retain = True  # clones re-read lanes
                group = []
                for cvid in clones.values():
                    self._swappables[cvid] = SwappableSource(
                        cvid, exchanges[cvid])
                    self._spec_merge[cvid] = merge
                    group.append(cvid)
                self._spec_groups[tag] = group
        if self.speculation and self._spec_groups:
            self._monitor = threading.Thread(
                target=self._monitor_stragglers,
                name="adaptive-monitor", daemon=True)
            self._monitor.start()

    def _eligible_agg_edge(self, tag: str,
                           clones: Dict[int, str]) -> Optional[_AggEdge]:
        """Edge state when every lane clone is the same splittable grouped
        aggregate (foldable, non-DISTINCT) merged by one UNION ALL vertex."""
        writer = self.exchanges[tag]
        if sorted(clones) != list(range(writer.num_partitions)):
            return None
        plans = []
        for p, cvid in clones.items():
            plan = self.dag.vertices[cvid].plan
            if not (isinstance(plan, P.Aggregate) and plan.group_keys
                    and plan.grouping_sets is None):
                return None
            if any(s.distinct or s.fn not in _MERGE_FOLD for s in plan.aggs):
                return None
            mns = list(_walk_materialized(plan))
            if len(mns) != 1 or mns[0].tag != tag \
                    or mns[0].partition != p:
                return None
            plans.append(plan)
        gk = plans[0].group_keys
        if any(pl.group_keys != gk for pl in plans):
            return None
        merge = self._single_consumer_of(set(clones.values()))
        if merge is None:
            return None
        union = self.dag.vertices[merge].plan
        if not (isinstance(union, P.Union) and union.all):
            return None
        tags = [c.tag for c in union.inputs
                if isinstance(c, MaterializedNode)]
        if len(tags) != len(union.inputs) or set(tags) != set(clones.values()):
            return None
        est = list(_walk_materialized(plans[0]))[0].est_rows
        return _AggEdge(tag, writer, clones, merge, union, gk,
                        plans[0].aggs, est, self.payoff_threshold)

    def _single_consumer_of(self, vids: set) -> Optional[str]:
        """The one vertex whose placeholders read every vid in ``vids``."""
        consumer = None
        for vid, vert in self.dag.vertices.items():
            read = {mn.tag for mn in _walk_materialized(vert.plan)}
            if read & vids:
                if consumer is not None or not vids <= read:
                    return None
                consumer = vid
        return consumer

    # ======================================================= scheduler hooks
    def on_vertex_start(self, vid: str) -> str:
        """Gate point: block while a replanning decision for ``vid`` is
        pending; ``skip`` means the vertex was replanned away."""
        with self._cond:
            self._started[vid] = clock.monotonic()
            while vid in self._gated and not self._finished:
                self._cond.wait(0.05)
                if self.cancel_token is not None:
                    self.cancel_token.check()
            if vid in self._skip:
                return "skip"
        return "run"

    def source_for(self, vid: str, mn: MaterializedNode, src):
        """The source a consumer binds for one edge — a swappable wrapper
        on speculation-eligible merge edges, the raw exchange otherwise."""
        sw = self._swappables.get(mn.tag)
        if sw is not None and mn.partition is None \
                and self._spec_merge.get(mn.tag) == vid:
            return sw
        return src

    def note_vertex_done(self, vid: str, rows: int, seconds: float) -> None:
        with self._cond:
            self._done[vid] = seconds
            edge = self._edges.get(vid)
            if edge is not None and not edge.done:
                edge.done = True
                edge.writer.on_progress = None
                if edge.payoff_gated:
                    self._decide_payoff(edge)
                self._release(edge.merge)
            self._resolve_speculation(vid)
            self._cond.notify_all()

    def note_vertex_error(self, vid: str, exc: BaseException) -> bool:
        """True when the failure is absorbed (the vertex was replanned away
        or lost a speculation race and nothing reads its exchange)."""
        with self._cond:
            if vid in self._abandoned or vid in self._skip:
                return True
            # a failing original whose live speculation clone can still win:
            # abandon the original and let the clone's stream swap in
            svid = self._spec_of.get(vid)
            if svid is not None and svid not in self._done \
                    and svid not in self._abandoned \
                    and not self._swappables[vid].committed:
                self._abandoned.add(vid)
                return True
            edge = self._edges.get(vid)
            if edge is not None and not edge.done:
                # producer failed: nothing to decide anymore — release
                # every gate so consumers observe the error promptly
                edge.done = True
                edge.writer.on_progress = None
                edge.payoff_gated = False
                for cvid in edge.clones.values():
                    self._release(cvid)
                self._release(edge.merge)
            self._cond.notify_all()
            return False

    def wait(self) -> None:
        """Join adaptive vertex threads (the query isn't done until the
        replanned vertices are)."""
        while True:
            with self._cond:
                threads = [t for t in self._threads if t.is_alive()]
            if not threads:
                return
            for t in threads:
                t.join()

    def finish(self) -> None:
        with self._cond:
            self._finished = True
            for edge in self._edges.values():
                edge.writer.on_progress = None
            for sw in self._swappables.values():
                sw.resolve()
            self._cond.notify_all()
        if self._monitor is not None:
            self._monitor.join(timeout=5.0)
        for t in list(self._threads):
            t.join(timeout=5.0)

    # ============================================================== internals
    def _record(self, event: dict) -> None:
        self.events.append(event)
        emit_event(self.trace, f"adaptive:{event.get('kind')}", "adaptive",
                   **event)
        if self.on_event is not None:
            try:
                self.on_event(event)
            except Exception:  # noqa: BLE001 - telemetry must not kill a query
                pass

    def _release(self, vid: str) -> None:
        self._gated.pop(vid, None)

    def _new_vid(self) -> str:
        self._counter += 1
        return f"adp{self._counter}"

    def _spawn(self, vid: str) -> None:
        t = threading.Thread(target=self.run_vertex, args=(vid,),
                             name=f"adaptive-{vid}", daemon=True)
        self._threads.append(t)
        t.start()

    def _adopt(self, apply, undo, event: Optional[dict]) -> bool:
        """The validating adopt-helper: every adopted-DAG mutation runs
        through here (REP005's one allowed chokepoint).  The mutation is
        applied, the whole DAG re-validated with ``check_dag``, and rolled
        back — recording a ``declined`` event — on any violation.  A
        ``None`` event adopts (or declines) silently."""
        apply()
        try:
            check_dag(self.dag, self.plan_cache, staged=self._staged)
        except PlanValidationError as exc:
            undo()
            if event is not None:
                self._record({"kind": "declined",
                              "wanted": event.get("kind"),
                              "edge": event.get("edge"),
                              "reason": exc.violations[0]})
            return False
        if event is not None:
            self._record(event)
        return True

    # ------------------------------------------------------ payoff fan-out
    def _decide_payoff(self, edge: _AggEdge) -> None:
        """Producer closed with the clones still gated: keep the fan-out
        only if the observed rows justify it (vs the CBO estimate that
        chose the lane count)."""
        edge.payoff_gated = False
        total = sum(edge.writer.lane_rows())
        if total >= edge.payoff_threshold or edge.split_lanes:
            for cvid in edge.clones.values():
                self._release(cvid)
            return
        self._collapse(edge, total)

    def _collapse(self, edge: _AggEdge, total: int) -> None:
        """Fan-out won't pay: replace the per-lane clones with one
        full-stream consumer reading every lane of the producer."""
        dag = self.dag
        vid_new = self._new_vid()
        clone0 = dag.vertices[edge.clones[0]]
        plan = copy.deepcopy(clone0.plan)
        for mn in _walk_materialized(plan):
            mn.partition = None
            mn.num_partitions = None
        names = clone0.plan.output_names()
        merge = dag.vertices[edge.merge]
        saved = (dict(dag.vertices), list(merge.deps),
                 dict(merge.edge_types), list(edge.union.inputs))

        def apply():
            for cvid in edge.clones.values():
                dag.vertices.pop(cvid, None)
            dag.vertices[vid_new] = Vertex(
                vid_new, plan, deps=[edge.producer],
                edge_types={edge.producer: SHUFFLE})
            edge.union.inputs = [MaterializedNode(list(names), vid_new)]
            merge.deps = [d for d in merge.deps
                          if d not in edge.clones.values()] + [vid_new]
            for cvid in edge.clones.values():
                merge.edge_types.pop(cvid, None)
            merge.edge_types[vid_new] = FORWARD

        def undo():
            dag.vertices.clear()
            dag.vertices.update(saved[0])
            merge.deps = saved[1]
            merge.edge_types = saved[2]
            edge.union.inputs = saved[3]

        ok = self._adopt(apply, undo, {
            "kind": "collapsed_fanout", "edge": edge.producer,
            "lanes": edge.writer.num_partitions, "rows": total,
            "est_rows": edge.est_rows, "threshold": edge.payoff_threshold,
        })
        if not ok:
            for cvid in edge.clones.values():
                self._release(cvid)
            return
        edge.collapsed = True
        ex = Exchange(vid_new, self.excfg)
        ex.retain = False  # single consumer: the merge
        ex.declare_schema(
            getattr(self.dag.vertices[vid_new].plan, "schema", None))
        self.exchanges[vid_new] = ex
        for cvid in edge.clones.values():
            self._skip.add(cvid)
            self._release(cvid)
        self._spawn(vid_new)

    # -------------------------------------------------------- hot-lane split
    def _on_writer_progress(self, writer: ShuffleWriter) -> None:
        # producer thread: routing state is same-thread, manager state locked
        edge = self._edges.get(writer.tag)
        if edge is None:
            return
        rows = writer.lane_rows()
        total = sum(rows)
        # unlocked throttle: the callback fires on every producer batch, but
        # a skew verdict cannot change until the stream has grown by a
        # meaningful step — skip the lock (and the per-lane medians) until
        # it has.  A gated payoff edge bypasses the throttle so its clones
        # are released the moment live rows prove the fan-out.
        if not edge.payoff_gated and \
                total - edge.progress_total < self._progress_step:
            return
        with self._cond:
            if self._finished or edge.done or edge.collapsed:
                return
            edge.progress_total = total
            if edge.payoff_gated and (
                    total >= edge.payoff_threshold
                    or max(rows) >= self.excfg.buffer_rows):
                # live rows prove the fan-out — or a lane hit its in-memory
                # budget, where holding the gate would pay spill I/O just to
                # defer the decision: release the lane clones so they
                # overlap with the rest of the producer's stream
                edge.payoff_gated = False
                for cvid in edge.clones.values():
                    self._release(cvid)
                self._cond.notify_all()
            for p, r in enumerate(rows):
                if p in writer._split:
                    continue
                # skew is measured against the *sibling* lanes: with few
                # lanes a hot lane dominates the overall median and would
                # mask itself
                med = _median([x for i, x in enumerate(rows) if i != p])
                if r >= self.split_min_rows and r > self.skew_ratio \
                        * max(med, 1.0):
                    self._split_lane(edge, writer, p, r, med)
                    break  # at most one split per progress callback

    def _split_lane(self, edge: _AggEdge, writer: ShuffleWriter,
                    p: int, lane_rows: int, lane_median: float) -> None:
        dag = self.dag
        ways = self.split_ways if self.split_ways >= 2 \
            else max(2, min(4, os.cpu_count() or 4))
        start = len(writer._subs)  # sub_lane_reader indices after the split
        clone = dag.vertices[edge.clones[p]]
        sub_vids, sub_vertices, sub_mns = [], [], []
        for j in range(ways):
            svid = self._new_vid()
            splan = copy.deepcopy(clone.plan)
            for mn in _walk_materialized(splan):
                mn.partition = None
                mn.num_partitions = None
                mn.sub_lane = start + j
            sub_vids.append(svid)
            sub_vertices.append(Vertex(
                svid, splan, deps=[edge.producer],
                edge_types={edge.producer: SHUFFLE}))
            sub_mns.append(MaterializedNode(
                list(clone.plan.output_names()), svid))
        merge = dag.vertices[edge.merge]
        saved = (list(merge.deps), dict(merge.edge_types),
                 list(edge.union.inputs), merge.plan, edge.folded)

        def apply():
            for v in sub_vertices:
                dag.vertices[v.vid] = v
            edge.union.inputs = edge.union.inputs + sub_mns
            merge.deps = merge.deps + sub_vids
            for svid in sub_vids:
                merge.edge_types[svid] = FORWARD
            if not edge.folded:
                # the split lane's groups now span its prefix consumer and
                # the sub-lane consumers: re-combine partials with the
                # merging fold (COUNT partials re-SUM, like global DISTINCT)
                folds = [P.AggSpec(_MERGE_FOLD[s.fn], A.Col(s.out_name),
                                   False, s.out_name) for s in edge.aggs]
                merge.plan = P.Aggregate(edge.union, list(edge.group_keys),
                                         folds)
                edge.folded = True

        def undo():
            for svid in sub_vids:
                dag.vertices.pop(svid, None)
            merge.deps = saved[0]
            merge.edge_types = saved[1]
            edge.union.inputs = saved[2]
            merge.plan = saved[3]
            edge.folded = saved[4]

        ok = self._adopt(apply, undo, {
            "kind": "lane_split", "edge": edge.producer, "lane": p,
            "ways": ways, "lane_rows": lane_rows,
            "lane_median": lane_median,
        })
        if not ok:
            return
        edge.split_lanes.append(p)
        if edge.payoff_gated:
            # a split implies real volume; never collapse after splitting
            edge.payoff_gated = False
            for cvid in edge.clones.values():
                self._release(cvid)
        for svid in sub_vids:
            ex = Exchange(svid, self.excfg)
            ex.retain = False
            ex.declare_schema(
                getattr(self.dag.vertices[svid].plan, "schema", None))
            self.exchanges[svid] = ex
        writer.split_lane(p, ways)
        for svid in sub_vids:
            self._spawn(svid)
        self._cond.notify_all()

    # ---------------------------------------------------------- speculation
    def _monitor_stragglers(self) -> None:
        while True:
            time.sleep(0.05)
            with self._cond:
                if self._finished:
                    return
                now = clock.monotonic()
                for group in self._spec_groups.values():
                    durations = [self._done[v] for v in group
                                 if v in self._done]
                    if not durations:
                        continue
                    med = _median(durations)
                    cutoff = max(self.straggler_min_s,
                                 self.straggler_factor * med)
                    for vid in group:
                        if vid in self._done or vid in self._spec_of \
                                or vid in self._abandoned \
                                or vid in self._skip \
                                or vid not in self._started \
                                or vid not in self.dag.vertices:
                            continue
                        if now - self._started[vid] > cutoff:
                            self._speculate(vid)

    def _speculate(self, vid: str) -> None:
        """Stage a clone of straggler ``vid`` into a fresh exchange; the
        DAG adoption happens only if the clone finishes first."""
        svid = self._new_vid()
        vert = self.dag.vertices[vid]
        plan = copy.deepcopy(vert.plan)
        clone = Vertex(svid, plan, deps=list(vert.deps),
                       edge_types=dict(vert.edge_types))
        dag = self.dag

        def apply():
            dag.vertices[svid] = clone

        def undo():
            dag.vertices.pop(svid, None)

        self._staged.add(svid)
        if not self._adopt(apply, undo, {
                "kind": "speculated", "vertex": vid, "clone": svid,
                "elapsed_s": round(
                    clock.monotonic() - self._started[vid], 3)}):
            self._staged.discard(svid)
            return
        ex = Exchange(svid, self.excfg)
        ex.declare_schema(
            getattr(self.dag.vertices[svid].plan, "schema", None))
        self.exchanges[svid] = ex
        self._spec_of[vid] = svid
        self._spec_clone_of[svid] = vid
        self._spawn(svid)

    def _resolve_speculation(self, vid: str) -> None:
        """First-finisher resolution, called (under the manager lock) when
        any vertex finishes."""
        dag = self.dag
        orig = self._spec_clone_of.get(vid)
        if orig is not None:
            # a clone finished: swap the merge's reader unless the original
            # already committed
            if orig in self._abandoned or orig not in dag.vertices:
                pass
            elif vid in self._abandoned:
                return
            sw = self._swappables[orig]
            if not sw.try_swap(self.exchanges[vid]):
                self._abandoned.add(vid)
                self._retire_clone(vid)
                self._record({"kind": "speculation_lost", "vertex": orig,
                              "clone": vid})
                return
            merge = dag.vertices[self._spec_merge[orig]]
            saved_vertices = dict(dag.vertices)
            saved = (list(merge.deps), dict(merge.edge_types))
            swapped_mns = []

            def apply():
                dag.vertices.pop(orig, None)
                for mn in _walk_materialized(merge.plan):
                    if mn.tag == orig:
                        mn.tag = vid
                        swapped_mns.append(mn)
                merge.deps = [vid if d == orig else d for d in merge.deps]
                et = merge.edge_types.pop(orig, None)
                if et is not None:
                    merge.edge_types[vid] = et

            def undo():
                dag.vertices.clear()
                dag.vertices.update(saved_vertices)
                for mn in swapped_mns:
                    mn.tag = orig
                merge.deps = saved[0]
                merge.edge_types = saved[1]

            if self._adopt(apply, undo, {
                    "kind": "speculation_swap", "vertex": orig,
                    "clone": vid}):
                self._abandoned.add(orig)
                self._staged.discard(vid)
            return
        svid = self._spec_of.get(vid)
        if svid is not None and vid not in self._abandoned:
            # the original finished first: the clone lost
            sw = self._swappables[vid]
            sw.resolve()
            if svid not in self._done:
                self._abandoned.add(svid)
                self._retire_clone(svid)
                self._record({"kind": "speculation_lost", "vertex": vid,
                              "clone": svid})

    def _retire_clone(self, svid: str) -> None:
        """Drop a losing speculation clone from the DAG (validated like any
        other mid-query mutation; no event of its own — the caller records
        ``speculation_lost``)."""
        dag = self.dag
        saved = dag.vertices.get(svid)

        def apply():
            dag.vertices.pop(svid, None)

        def undo():
            if saved is not None:
                dag.vertices[svid] = saved

        if self._adopt(apply, undo, None):
            self._staged.discard(svid)
