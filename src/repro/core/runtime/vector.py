"""Columnar vectorized batches (paper §5 / [39]).

All physical operators in Tahoe exchange `VectorBatch`es: dictionaries of
equal-length column vectors.  This is the in-memory analogue of Hive's
vectorized row-batch representation; LLAP's I/O elevator produces the same
format so that I/O, cache and execution share one layout (paper §5.1).

Hidden ACID columns (`__writeid__`, `__rowid__`) ride along like ordinary
columns; operators that don't know about them simply carry them through.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List, Optional, Sequence

import numpy as np

WRITEID_COL = "__writeid__"
ROWID_COL = "__rowid__"
ACID_COLS = (WRITEID_COL, ROWID_COL)

# Default number of rows per vectorized batch.  1024 mirrors Hive's
# VectorizedRowBatch; large enough to amortize dispatch, small enough to sit
# in cache/VMEM tiles.
DEFAULT_BATCH_ROWS = 1024


@dataclasses.dataclass
class VectorBatch:
    cols: Dict[str, np.ndarray]

    # -- construction --------------------------------------------------------
    @classmethod
    def empty(cls, schema) -> "VectorBatch":
        """Zero-row batch carrying a schema: either ``(name, dtype)`` pairs
        or a :class:`repro.core.schema.Schema` — so empty results and empty
        spill-replay morsels keep correct column names/dtypes instead of
        collapsing to ``{}``."""
        pairs = schema.to_pairs() if hasattr(schema, "to_pairs") else schema
        return cls({name: np.empty(0, dtype=dtype) for name, dtype in pairs})

    @classmethod
    def concat(cls, batches: Iterable["VectorBatch"],
               context: Optional[str] = None) -> "VectorBatch":
        """Concatenate morsels.  Zero-row schemaless placeholders (``{}``)
        are dropped when schema-carrying batches exist; a genuine column-set
        mismatch raises :class:`~repro.core.schema.SchemaMismatchError`
        naming the offending edge instead of a bare ``KeyError``."""
        batches = [b for b in batches if b is not None]
        if not batches:
            return cls({})
        typed = [b for b in batches if b.cols]
        if not typed:
            return batches[0]
        keys = typed[0].cols.keys()
        for b in typed[1:]:
            if b.cols.keys() != keys:
                from ..schema import SchemaMismatchError

                raise SchemaMismatchError(
                    f"cannot concat batches with mismatched columns: "
                    f"{list(keys)[:12]} vs {list(b.cols)[:12]}", context)
        return cls({k: np.concatenate([b.cols[k] for b in typed])
                    for k in keys})

    # -- basic properties ----------------------------------------------------
    @property
    def num_rows(self) -> int:
        for v in self.cols.values():
            return len(v)
        return 0

    @property
    def column_names(self) -> List[str]:
        return list(self.cols.keys())

    def __len__(self) -> int:
        return self.num_rows

    # -- transforms (all return new batches; columns are immutable) ----------
    def select(self, mask: np.ndarray) -> "VectorBatch":
        return VectorBatch({k: v[mask] for k, v in self.cols.items()})

    def take(self, idx: np.ndarray) -> "VectorBatch":
        return VectorBatch({k: v[idx] for k, v in self.cols.items()})

    def project(self, names: Sequence[str]) -> "VectorBatch":
        return VectorBatch({n: self.cols[n] for n in names})

    def rename(self, mapping: Dict[str, str]) -> "VectorBatch":
        return VectorBatch({mapping.get(k, k): v for k, v in self.cols.items()})

    def with_column(self, name: str, values: np.ndarray) -> "VectorBatch":
        cols = dict(self.cols)
        cols[name] = values
        return VectorBatch(cols)

    def drop(self, names: Sequence[str]) -> "VectorBatch":
        return VectorBatch({k: v for k, v in self.cols.items() if k not in names})

    def drop_acid_cols(self) -> "VectorBatch":
        return self.drop(ACID_COLS)

    def slice(self, start: int, stop: int) -> "VectorBatch":
        return VectorBatch({k: v[start:stop] for k, v in self.cols.items()})

    def iter_chunks(self, rows: int = DEFAULT_BATCH_ROWS):
        n = self.num_rows
        for start in range(0, n, rows):
            yield self.slice(start, min(start + rows, n))

    # -- misc -----------------------------------------------------------------
    def to_rows(self) -> List[tuple]:
        names = self.column_names
        return list(zip(*[self.cols[n].tolist() for n in names])) if names else []

    def sort_by(self, keys: Sequence[str], descending: Sequence[bool]) -> "VectorBatch":
        if not keys or self.num_rows == 0:
            return self
        # lexsort: last key is primary
        order = None
        for key, desc in reversed(list(zip(keys, descending))):
            col = self.cols[key]
            if order is None:
                order = np.argsort(col, kind="stable")
                if desc:
                    order = order[::-1]
            else:
                sub = col[order]
                reorder = np.argsort(sub, kind="stable")
                if desc:
                    reorder = reorder[::-1]
                order = order[reorder]
        return self.take(order)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"VectorBatch({self.num_rows} rows, cols={self.column_names})"


def row_key_array(batch: VectorBatch, keys: Sequence[str]) -> np.ndarray:
    """Stable composite-key encoding used by joins/aggregations.

    Returns an int64 array of group codes (dictionary-encoded composite key).
    """
    if len(keys) == 1:
        col = batch.cols[keys[0]]
        _, codes = np.unique(col, return_inverse=True)
        return codes.astype(np.int64)
    views = [batch.cols[k] for k in keys]
    rec = np.rec.fromarrays(views)
    _, codes = np.unique(rec, return_inverse=True)
    return codes.astype(np.int64)
