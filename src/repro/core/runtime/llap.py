"""LLAP — Live Long and Process (paper §5.1).

A persistent daemon providing:

  * an **I/O elevator**: column batches are read stripe-by-stripe on separate
    I/O threads, decoded into the internal columnar format, and handed to
    execution as soon as each batch is ready; projections, sargable
    predicates and bloom filters are pushed into the reader so entire row
    groups are skipped before any decode happens;
  * a **multi-tenant chunk cache**: decoded (file, stripe, column) chunks in
    an LRFU-evicted buffer pool.  Cache identity is the content-derived
    ``file_id`` (HDFS unique-id / S3 ETag analogue), so the cache remains an
    MVCC view: ACID visibility is decided at the file level by the snapshot,
    never by the cache;
  * a **bulk metadata cache**: file footers (incl. min/max + bloom indexes)
    are cached even for data never admitted to the cache, so predicate
    evaluation can decide what to load without touching the data;
  * persistent **executors** that query fragments are scheduled onto (the
    DAG scheduler uses this pool when LLAP is enabled; otherwise it spins up
    throwaway "containers").
"""
from __future__ import annotations

import os
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from ...analysis.lockdep import make_rlock
from ..bloomfilter import BloomFilter
from ..storage import (
    FileMeta,
    SargPredicate,
    read_file_meta,
    read_stripe_column,
    stripe_may_match,
)
from .lrfu import LRFUPolicy
from .vector import VectorBatch


class LlapDaemon:
    """One in-process daemon standing in for the per-node daemon fleet."""

    def __init__(
        self,
        cache_bytes: int = 256 << 20,
        num_executors: int = 4,
        io_threads: int = 4,
        lrfu_lambda: float = 0.01,
    ):
        self.cache_bytes = cache_bytes
        self._chunks: Dict[Tuple[str, int, str], np.ndarray] = {}
        self._chunk_sizes: Dict[Tuple[str, int, str], int] = {}
        self._used = 0
        self._policy = LRFUPolicy(lrfu_lambda)
        self._meta: Dict[str, Tuple[float, FileMeta]] = {}  # path -> (mtime, meta)
        self._lock = make_rlock("llap")
        self.executors = ThreadPoolExecutor(
            max_workers=num_executors, thread_name_prefix="llap-exec"
        )
        self.io_pool = ThreadPoolExecutor(
            max_workers=io_threads, thread_name_prefix="llap-io"
        )
        self.counters = {
            "cache_hits": 0,
            "cache_misses": 0,
            "meta_hits": 0,
            "meta_misses": 0,
            "stripes_skipped": 0,
            "stripes_read": 0,
            "bytes_cached": 0,
            "evictions": 0,
        }

    def shutdown(self) -> None:
        """Release the executor/IO thread pools (daemon decommission)."""
        self.executors.shutdown(wait=False)
        self.io_pool.shutdown(wait=False)

    # ------------------------------------------------------------- metadata
    def file_meta(self, path: str) -> FileMeta:
        mtime = os.path.getmtime(path)
        with self._lock:
            hit = self._meta.get(path)
            if hit is not None and hit[0] == mtime:
                self.counters["meta_hits"] += 1
                return hit[1]
        meta = read_file_meta(path)
        with self._lock:
            self._meta[path] = (mtime, meta)
            self.counters["meta_misses"] += 1
        return meta

    # ------------------------------------------------------------- chunks
    def _get_chunk(self, path: str, meta: FileMeta, stripe: int, col: str) -> np.ndarray:
        key = (meta.file_id, stripe, col)
        with self._lock:
            if key in self._chunks:
                self.counters["cache_hits"] += 1
                self._policy.on_access(key)
                return self._chunks[key]
        arr = read_stripe_column(path, stripe, col)
        nbytes = arr.nbytes
        with self._lock:
            self.counters["cache_misses"] += 1
            if key not in self._chunks:
                while self._used + nbytes > self.cache_bytes and self._chunks:
                    victim = self._policy.victim()
                    if victim is None:
                        break
                    self._evict(victim)
                if self._used + nbytes <= self.cache_bytes:
                    self._chunks[key] = arr
                    self._chunk_sizes[key] = nbytes
                    self._used += nbytes
                    self.counters["bytes_cached"] += nbytes
                    self._policy.on_access(key)
        return arr

    def _evict(self, key) -> None:
        arr = self._chunks.pop(key, None)
        if arr is not None:
            self._used -= self._chunk_sizes.pop(key, 0)
            self.counters["evictions"] += 1
        self._policy.on_remove(key)

    def invalidate_file(self, file_id: str) -> None:
        with self._lock:
            for key in [k for k in self._chunks if k[0] == file_id]:
                self._evict(key)

    def invalidate_location(self, location: str) -> None:
        """DDL invalidation: drop cached footers and data chunks for every
        file under ``location`` (e.g. a dropped table's directory), so a
        table re-created at the same path never serves the old bytes."""
        prefix = location.rstrip(os.sep) + os.sep
        with self._lock:
            victims = [p for p in self._meta
                       if p == location or p.startswith(prefix)]
            file_ids = {self._meta[p][1].file_id for p in victims}
            for p in victims:
                del self._meta[p]
            for key in [k for k in self._chunks if k[0] in file_ids]:
                self._evict(key)

    def cache_usage(self) -> Tuple[int, int]:
        return self._used, self.cache_bytes

    def reset_counters(self) -> None:
        for k in self.counters:
            self.counters[k] = 0


class LlapIO:
    """The I/O-elevator facade handed to scans (drop-in for PlainIO)."""

    def __init__(self, daemon: LlapDaemon):
        self.daemon = daemon

    def read_meta(self, path: str) -> FileMeta:
        return self.daemon.file_meta(path)

    def read_file_chunks(
        self,
        path: str,
        columns: Optional[Sequence[str]] = None,
        sarg_preds: Sequence[SargPredicate] = (),
        runtime_blooms: Optional[Dict[str, BloomFilter]] = None,
    ):
        """Stream one decoded ``VectorBatch`` per surviving stripe.

        The I/O elevator fans stripe loads out on the I/O pool and hands each
        column batch to the operator pipeline as soon as it lands — the
        consumer processes stripe N while stripes N+1.. are still loading,
        instead of waiting for the whole file to decode."""
        from ..acid import _bloom_masked

        # metadata first — in bulk, before any data I/O (paper §5.1)
        meta = self.daemon.file_meta(path)
        cols = list(columns) if columns is not None else meta.columns

        wanted_stripes = []
        for si, smeta in enumerate(meta.stripes):
            if sarg_preds and not stripe_may_match(smeta, sarg_preds):
                self.daemon.counters["stripes_skipped"] += 1
                continue
            wanted_stripes.append(si)

        def load(si: int) -> Dict[str, np.ndarray]:
            return {c: self.daemon._get_chunk(path, meta, si, c) for c in cols}

        futures = [self.daemon.io_pool.submit(load, si) for si in wanted_stripes]
        for fut in futures:
            stripe_cols = fut.result()
            self.daemon.counters["stripes_read"] += 1
            yield _bloom_masked(stripe_cols, cols, runtime_blooms)

    def read_file(
        self,
        path: str,
        columns: Optional[Sequence[str]] = None,
        sarg_preds: Sequence[SargPredicate] = (),
        runtime_blooms: Optional[Dict[str, BloomFilter]] = None,
    ) -> Tuple[FileMeta, VectorBatch]:
        meta = self.daemon.file_meta(path)
        cols = list(columns) if columns is not None else meta.columns
        chunks = list(self.read_file_chunks(path, columns, sarg_preds,
                                            runtime_blooms))
        if chunks:
            return meta, VectorBatch.concat(chunks)
        return meta, VectorBatch({
            c: np.empty(0, dtype=meta.dtypes.get(c, "f8")) for c in cols
        })
