"""Spill-aware exchanges between DAG vertices (paper §2/§5 Tez edges).

An :class:`Exchange` is the data-movement channel behind one DAG edge: the
producer vertex appends ``VectorBatch`` morsels as it streams them, and any
number of downstream readers replay the chunk sequence (a vertex output can
feed several consumers — shared-work reuse, semijoin producers — so chunks
are retained until the whole query finishes).

Memory is bounded: the in-memory buffer holds at most ``buffer_rows`` rows /
``buffer_bytes`` bytes per exchange; overflow chunks spill to a per-query
scratch directory and are transparently re-loaded when a reader reaches
them.  With spill disabled (session config ``exchange.spill = False``) an
overflowing exchange raises :class:`MemoryPressureError` instead, feeding
the §4.2 re-optimization path.

``put`` never blocks — downstream backpressure is absorbed by the
spill-to-disk path, which is what lets upstream vertices keep running while
the client drains first rows from the root.
"""
from __future__ import annotations

import os
import threading
from typing import Dict, Iterator, List, Optional

import numpy as np

from ...analysis.lockdep import make_condition
from ..obs import clock
from ..obs.trace import note_exchange_wait, note_spill_io
from .exec import MemoryPressureError
from .vector import VectorBatch

# Defaults for the session config knobs (see session.DEFAULT_CONFIG).
DEFAULT_BUFFER_ROWS = 1 << 16
DEFAULT_BUFFER_BYTES = 64 << 20


def batch_nbytes(batch: VectorBatch) -> int:
    return int(sum(v.nbytes for v in batch.cols.values()))


class ExchangeConfig:
    """Per-query exchange policy, resolved once from the session config."""

    def __init__(self, config: Optional[dict] = None, scratch_dir: Optional[str] = None):
        config = config or {}
        self.buffer_rows = int(
            config.get("exchange.buffer_rows", DEFAULT_BUFFER_ROWS)
            or DEFAULT_BUFFER_ROWS
        )
        self.buffer_bytes = int(
            config.get("exchange.buffer_bytes", DEFAULT_BUFFER_BYTES)
            or DEFAULT_BUFFER_BYTES
        )
        self.spill = bool(config.get("exchange.spill", True))
        # runtime schema sanitizer (lockdep pattern: resolved once per query,
        # zero overhead on put() unless enabled AND a schema is declared)
        self.check_batches = bool(os.environ.get("REPRO_CHECK_BATCHES")
                                  or config.get("debug.check_batches"))
        self.scratch_dir = scratch_dir
        self._own_scratch = False
        # observability (PR 10), resolved once per query like check_batches:
        # ``trace`` is the query's QueryTrace (None = tracing off — every
        # hot-path site pays one attribute test and allocates nothing),
        # ``metrics`` the warehouse MetricsRegistry for spill counters.
        # Both set by the execute stage / DAG scheduler, never from config.
        self.trace = None
        self.metrics = None

    def make_scratch(self) -> str:
        if self.scratch_dir is None:
            import tempfile

            self.scratch_dir = tempfile.mkdtemp(prefix="repro_exchange_")
            self._own_scratch = True
        os.makedirs(self.scratch_dir, exist_ok=True)
        return self.scratch_dir

    def cleanup(self) -> None:
        """Remove an auto-created scratch directory (query teardown)."""
        if self._own_scratch and self.scratch_dir is not None:
            import shutil

            shutil.rmtree(self.scratch_dir, ignore_errors=True)
            self.scratch_dir = None
            self._own_scratch = False


class _MemSlot:
    __slots__ = ("batch",)

    def __init__(self, batch: VectorBatch):
        self.batch = batch


class _DiskSlot:
    __slots__ = ("path",)

    def __init__(self, path: str):
        self.path = path


def _save_chunk(path: str, batch: VectorBatch) -> None:
    names = np.array(batch.column_names)
    data = {f"c{i}": v for i, v in enumerate(batch.cols.values())}
    with open(path, "wb") as f:
        np.savez(f, __names__=names, **data)


def _load_chunk(path: str) -> VectorBatch:
    with np.load(path, allow_pickle=False) as z:
        names = [str(n) for n in z["__names__"]]
        return VectorBatch({n: z[f"c{i}"] for i, n in enumerate(names)})


class Exchange:
    """One producer, N replaying readers, bounded memory via spill.

    ``buffer_rows``/``buffer_bytes`` default to the query-wide budgets in
    ``cfg`` but can be overridden per exchange — the shuffle service gives
    every partition lane a full edge budget of its own (the Tez
    per-partition buffer model: a partitioned edge may buffer up to N× the
    configured ``exchange.buffer_*`` before lanes spill)."""

    def __init__(self, tag: str, cfg: ExchangeConfig,
                 buffer_rows: Optional[int] = None,
                 buffer_bytes: Optional[int] = None):
        self.tag = tag
        self.cfg = cfg
        self.buffer_rows = int(buffer_rows if buffer_rows is not None
                               else cfg.buffer_rows)
        self.buffer_bytes = int(buffer_bytes if buffer_bytes is not None
                                else cfg.buffer_bytes)
        self._slots: List[object] = []
        self._cond = make_condition(name="exchange")
        self._closed = False
        self._error: Optional[BaseException] = None
        self._mem_rows = 0
        self._mem_bytes = 0
        self._spill_seq = 0
        # multi-consumer edges replay the full chunk sequence, so chunks are
        # retained until query teardown; the DAG scheduler flips this off
        # for single-consumer FORWARD edges, which then free each chunk
        # (memory and spill file) the moment its one reader consumes it
        self.retain = True
        # metrics surfaced through DAGScheduler -> QueryHandle.poll()
        self.total_rows = 0
        self.spilled_rows = 0
        self.spilled_bytes = 0
        self.spilled_chunks = 0
        self.peak_buffered_rows = 0
        self.freed_chunks = 0
        # declared edge schema (repro.core.schema.Schema) — set by the DAG
        # scheduler from the producer vertex's inferred plan schema.
        # ``_verify`` is non-None only when cfg.check_batches is on AND a
        # schema is known: the put() hot path pays one attribute test.
        self.schema = None
        self._verify = None

    def declare_schema(self, schema) -> None:
        """Declare the edge's column contract; under ``REPRO_CHECK_BATCHES``
        / ``debug.check_batches`` every put() asserts conformance."""
        self.schema = schema
        self._verify = schema if (schema is not None
                                  and self.cfg.check_batches) else None

    # ------------------------------------------------------------ producer
    def put(self, batch: VectorBatch) -> None:
        if self._verify is not None:
            self._verify.check_batch(batch, context=f"exchange {self.tag}")
        n = batch.num_rows
        nbytes = batch_nbytes(batch)
        with self._cond:
            if self._closed:
                return
            overflow = n > 0 and (
                self._mem_rows + n > self.buffer_rows
                or self._mem_bytes + nbytes > self.buffer_bytes
            )
            if overflow and not self.cfg.spill:
                raise MemoryPressureError(
                    f"exchange {self.tag} over budget "
                    f"({self._mem_rows + n} rows / "
                    f"{self._mem_bytes + nbytes} bytes buffered, "
                    f"budget {self.buffer_rows} rows / "
                    f"{self.buffer_bytes} bytes) and exchange.spill is off"
                )
            if overflow:
                # unique per process + exchange instance: vertex tags (v1,
                # v2, ...) repeat across queries that may share a configured
                # exchange.spill_dir
                path = os.path.join(
                    self.cfg.make_scratch(),
                    f"{self.tag}_{os.getpid():x}_{id(self):x}"
                    f"_{self._spill_seq:06d}.npz",
                )
                self._spill_seq += 1
                if self.cfg.trace is not None:
                    t_io = clock.perf_counter()
                    _save_chunk(path, batch)
                    note_spill_io(clock.perf_counter() - t_io)
                else:
                    _save_chunk(path, batch)
                self._slots.append(_DiskSlot(path))
                self.spilled_rows += n
                self.spilled_bytes += nbytes
                self.spilled_chunks += 1
                if self.cfg.metrics is not None:
                    self.cfg.metrics.inc("exchange.spilled_chunks")
                    self.cfg.metrics.inc("exchange.spilled_rows", n)
                    self.cfg.metrics.inc("exchange.spilled_bytes", nbytes)
            else:
                self._slots.append(_MemSlot(batch))
                self._mem_rows += n
                self._mem_bytes += nbytes
                self.peak_buffered_rows = max(self.peak_buffered_rows,
                                              self._mem_rows)
            self.total_rows += n
            self._cond.notify_all()

    def close(self, error: Optional[BaseException] = None) -> None:
        with self._cond:
            if self._closed:
                return
            self._closed = True
            self._error = error
            self._cond.notify_all()

    # ------------------------------------------------------------ consumers
    def available(self, i: int) -> bool:
        """Would ``reader()``'s next step at position ``i`` return without
        blocking?  True when chunk ``i`` exists or the stream is closed
        (end-of-stream / error both resolve immediately).  The adaptive
        layer's swappable sources poll this to drain an exchange without
        ever committing to a blocking wait."""
        with self._cond:
            return i < len(self._slots) or self._closed

    def failed(self) -> bool:
        """True when the producer closed this exchange with an error."""
        with self._cond:
            return self._error is not None

    def reader(self) -> Iterator[VectorBatch]:
        """A pass over the full chunk sequence (blocking iterator).

        With ``retain`` off (single-consumer edges) each slot is released
        as soon as it is handed to the reader: buffered memory is returned
        to the budget and spill files are unlinked after loading.
        """
        i = 0
        while True:
            with self._cond:
                if self.cfg.trace is not None and i >= len(self._slots) \
                        and not self._closed:
                    # blocking wait: charge it to the consuming vertex's
                    # exchange-wait sub-phase (thread-local frame)
                    t_wait = clock.perf_counter()
                    while i >= len(self._slots) and not self._closed:
                        self._cond.wait(0.05)
                    note_exchange_wait(clock.perf_counter() - t_wait)
                else:
                    while i >= len(self._slots) and not self._closed:
                        self._cond.wait(0.05)
                if i < len(self._slots):
                    slot = self._slots[i]
                    if slot is None:
                        raise RuntimeError(
                            f"exchange {self.tag}: chunk {i} already freed "
                            f"(single-consumer edge read twice)"
                        )
                    if not self.retain:
                        self._slots[i] = None
                        self.freed_chunks += 1
                        if isinstance(slot, _MemSlot):
                            self._mem_rows -= slot.batch.num_rows
                            self._mem_bytes -= batch_nbytes(slot.batch)
                elif self._error is not None:
                    raise self._error
                else:
                    return
            i += 1
            if isinstance(slot, _MemSlot):
                yield slot.batch
            else:
                if self.cfg.trace is not None:
                    t_io = clock.perf_counter()
                    batch = _load_chunk(slot.path)
                    note_spill_io(clock.perf_counter() - t_io)
                else:
                    batch = _load_chunk(slot.path)
                if not self.retain:
                    try:
                        os.unlink(slot.path)
                    except OSError:  # pragma: no cover - already gone
                        pass
                yield batch

    def read_all(self) -> VectorBatch:
        chunks = list(self.reader())
        if not chunks:
            # keep the declared schema on zero-row results instead of
            # collapsing to a columnless batch
            return VectorBatch.empty(self.schema) if self.schema is not None \
                else VectorBatch({})
        return VectorBatch.concat(chunks, context=f"exchange {self.tag}")

    # ------------------------------------------------------------ teardown
    def stats(self) -> Dict[str, int]:
        with self._cond:
            return {
                "rows": self.total_rows,
                "spilled_rows": self.spilled_rows,
                "spilled_bytes": self.spilled_bytes,
                "spilled_chunks": self.spilled_chunks,
                "peak_buffered_rows": self.peak_buffered_rows,
                "freed_chunks": self.freed_chunks,
            }

    def discard(self) -> None:
        """Release buffered chunks and delete this exchange's spill files."""
        with self._cond:
            slots, self._slots = self._slots, []
            self._closed = True
            self._mem_rows = self._mem_bytes = 0
        for slot in slots:
            if isinstance(slot, _DiskSlot):
                try:
                    os.unlink(slot.path)
                except OSError:
                    pass
