"""Workload manager (paper §5.2).

Administers access to LLAP resources through *resource plans*: pools with
capacity fractions and admission parallelism, mappings that route queries to
pools by user/application, and triggers that move or kill queries based on
runtime metrics.  Only one plan is active at a time; plans persist in the
metastore.  Idle pool capacity may be borrowed by queries from other pools
until the owning pool claims it.

Admission has two entry points: :meth:`WorkloadManager.admit` (admit or
raise — the synchronous execution path) and
:meth:`WorkloadManager.wait_admit` (queue until a running query releases
pool capacity — the async scheduler's path, woken by
:meth:`WorkloadManager.release` and responsive to the handle's
``CancelToken`` while queued).

Admission state is **sharded per pool** (lock striping): every pool keeps
its own condition variable and FIFO queue, so hundreds of concurrent
``execute_async`` handles queued on different pools don't convoy behind
one global condvar.  The small amount of cross-pool state — slot table,
pool load counters, borrow rotation — lives under a separate short-hold
lock (``_lock``); the ordering discipline is shard lock first, then
``_lock``, and :meth:`release` notifies shards only after dropping
``_lock``, so the two layers never deadlock.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

from ...analysis.lockdep import make_condition, make_lock, make_rlock
from ..metastore import Metastore
from ..obs.metrics import MetricsRegistry


class QueryKilledError(Exception):
    pass


@dataclass
class PoolDef:
    name: str
    alloc_fraction: float
    query_parallelism: int


@dataclass
class RuleDef:
    name: str
    metric: str  # e.g. total_runtime (ms), rows_produced
    threshold: float
    action: str  # 'move' | 'kill'
    target_pool: Optional[str] = None
    pools: List[str] = field(default_factory=list)  # pools the rule is attached to


@dataclass
class ResourcePlan:
    name: str
    pools: Dict[str, PoolDef] = field(default_factory=dict)
    rules: Dict[str, RuleDef] = field(default_factory=dict)
    mappings: List[tuple] = field(default_factory=list)  # (kind, entity, pool)
    default_pool: Optional[str] = None

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "pools": {
                k: {"alloc_fraction": p.alloc_fraction,
                    "query_parallelism": p.query_parallelism}
                for k, p in self.pools.items()
            },
            "rules": {
                k: {"metric": r.metric, "threshold": r.threshold,
                    "action": r.action, "target_pool": r.target_pool,
                    "pools": r.pools}
                for k, r in self.rules.items()
            },
            "mappings": self.mappings,
            "default_pool": self.default_pool,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "ResourcePlan":
        plan = cls(d["name"])
        for k, p in d.get("pools", {}).items():
            plan.pools[k] = PoolDef(k, p["alloc_fraction"], p["query_parallelism"])
        for k, r in d.get("rules", {}).items():
            plan.rules[k] = RuleDef(k, r["metric"], r["threshold"], r["action"],
                                    r.get("target_pool"), list(r.get("pools", [])))
        plan.mappings = [tuple(m) for m in d.get("mappings", [])]
        plan.default_pool = d.get("default_pool")
        return plan


@dataclass
class QuerySlot:
    query_id: str
    pool: str
    admitted_at: float = field(default_factory=time.time)
    borrowed_from: Optional[str] = None
    metrics: Dict[str, float] = field(default_factory=dict)
    killed: bool = False
    moves: List[str] = field(default_factory=list)
    cancel_token: Optional[object] = None  # CancelToken of an async handle


class _PoolShard:
    """Per-pool admission stripe: its own condvar + FIFO ticket queue."""

    __slots__ = ("lock", "cond", "waiting")

    def __init__(self):
        self.lock = make_rlock("wlm.shard")
        self.cond = make_condition(self.lock, name="wlm.shard.cond")
        self.waiting: Deque[object] = deque()


class WorkloadManager:
    def __init__(self, hms: Metastore, total_executors: int = 16,
                 metrics: Optional[MetricsRegistry] = None):
        self.hms = hms
        self.total_executors = total_executors
        # admission counters live in the warehouse MetricsRegistry (PR 10);
        # a private registry keeps directly-constructed managers working
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.metrics.gauge("wlm.queue_depths", self.queue_depths)
        # cross-pool state: slot table, load counters, borrow rotation.
        # Held briefly; never while waiting.  Lock order: shard then _lock.
        self._lock = make_rlock("wlm.global")
        self._active: Optional[ResourcePlan] = None
        self._running: Dict[str, QuerySlot] = {}
        self._pool_load: Dict[str, int] = {}
        # per-pool admission shards (fair FIFO queueing; see wait_admit)
        self._shards: Dict[Optional[str], _PoolShard] = {}
        self._shards_lock = make_lock("wlm.shards")
        # round-robin rotation among pool heads contending for borrowed
        # idle capacity: the pool that borrowed last yields to the next
        # contending pool in cyclic (sorted-name) order
        self._borrow_last: Optional[str] = None
        plan_dict = hms.active_resource_plan()
        if plan_dict:
            self._active = ResourcePlan.from_dict(plan_dict)
            self._pool_load = {p: 0 for p in self._active.pools}

    def _shard(self, pool: Optional[str]) -> _PoolShard:
        with self._shards_lock:
            shard = self._shards.get(pool)
            if shard is None:
                shard = self._shards[pool] = _PoolShard()
            return shard

    # ------------------------------------------------------------- plan DDL
    def create_plan(self, name: str) -> None:
        self.hms.save_resource_plan(name, ResourcePlan(name).to_dict())

    def _load(self, name: str) -> ResourcePlan:
        d = self.hms.get_resource_plan(name)
        if d is None:
            raise KeyError(f"no resource plan {name}")
        return ResourcePlan.from_dict(d)

    def _store(self, plan: ResourcePlan) -> None:
        self.hms.save_resource_plan(plan.name, plan.to_dict())
        if self._active and self._active.name == plan.name:
            self._active = plan
            for p in plan.pools:
                self._pool_load.setdefault(p, 0)

    def create_pool(self, plan_name: str, pool: str, alloc_fraction: float,
                    query_parallelism: int) -> None:
        plan = self._load(plan_name)
        plan.pools[pool] = PoolDef(pool, alloc_fraction, query_parallelism)
        self._store(plan)

    def create_rule(self, plan_name: str, rule: str, metric: str,
                    threshold: float, action: str,
                    target_pool: Optional[str]) -> None:
        plan = self._load(plan_name)
        plan.rules[rule] = RuleDef(rule, metric, threshold, action, target_pool)
        self._store(plan)

    def add_rule_to_pool(self, plan_name: str, rule: str, pool: str) -> None:
        plan = self._load(plan_name)
        plan.rules[rule].pools.append(pool)
        self._store(plan)

    def create_mapping(self, plan_name: str, kind: str, entity: str, pool: str) -> None:
        plan = self._load(plan_name)
        plan.mappings.append((kind, entity, pool))
        self._store(plan)

    def set_default_pool(self, plan_name: str, pool: str) -> None:
        plan = self._load(plan_name)
        plan.default_pool = pool
        self._store(plan)

    def activate(self, plan_name: str) -> None:
        plan = self._load(plan_name)
        self.hms.activate_resource_plan(plan_name)
        with self._lock:
            self._active = plan
            self._pool_load = {p: 0 for p in plan.pools}

    @property
    def active_plan(self) -> Optional[ResourcePlan]:
        return self._active

    # ------------------------------------------------------------- admission
    def route(self, user: Optional[str] = None, application: Optional[str] = None) -> Optional[str]:
        plan = self._active
        if plan is None:
            return None
        for kind, entity, pool in plan.mappings:
            if kind == "application" and application == entity:
                return pool
            if kind == "user" and user == entity:
                return pool
        return plan.default_pool or (next(iter(plan.pools)) if plan.pools else None)

    def admit(self, query_id: str, user=None, application=None,
              cancel_token=None) -> Optional[QuerySlot]:
        """Admit or die: raises :class:`QueryKilledError` when the routed
        pool is saturated and no idle capacity can be borrowed (the
        pre-async behavior, kept for the synchronous execution path)."""
        slot, saturated = self.try_admit(query_id, user, application,
                                         cancel_token)
        if saturated:
            pool = self.route(user, application)
            raise QueryKilledError(
                f"pool {pool} at parallelism limit and no idle capacity"
            )
        return slot

    def try_admit(self, query_id: str, user=None, application=None,
                  cancel_token=None):
        """Non-blocking admission probe.

        Returns ``(slot, saturated)``: ``(QuerySlot, False)`` on admission,
        ``(None, False)`` when no resource plan applies (run unmanaged), and
        ``(None, True)`` when the routed pool is at its parallelism limit
        with no idle capacity to borrow — the caller may queue and retry.
        """
        with self._lock:
            plan = self._active
            if plan is None:
                return None, False
            pool = self.route(user, application)
            if pool is None:
                return None, False
            slot = QuerySlot(query_id, pool, cancel_token=cancel_token)
            if self._pool_load.get(pool, 0) >= plan.pools[pool].query_parallelism:
                # pool saturated: borrow idle capacity from another pool
                # (§5.2).  When several pools' queue heads contend for the
                # same idle capacity, grants rotate round-robin across the
                # contending pools instead of going to whichever head woke
                # first.
                if not self._borrow_turn(pool):
                    return None, True
                for other, pdef in plan.pools.items():
                    if other != pool and self._pool_load.get(other, 0) < pdef.query_parallelism:
                        slot.borrowed_from = other
                        pool_to_charge = other
                        self._borrow_last = pool
                        break
                else:
                    return None, True
            else:
                pool_to_charge = pool
            self._pool_load[pool_to_charge] = self._pool_load.get(pool_to_charge, 0) + 1
            slot.metrics["charged_pool"] = pool_to_charge
            self._running[query_id] = slot
            self.metrics.inc("wlm.admitted")
            if slot.borrowed_from is not None:
                self.metrics.inc("wlm.borrowed")
            return slot, False

    def _borrow_turn(self, pool: str) -> bool:
        """May ``pool``'s queue head borrow idle capacity right now?

        With zero or one pool queueing there is no contention and any
        borrower may proceed.  With several, the grant rotates cyclically
        (sorted pool order) starting after the pool that borrowed last —
        arrival at the shared condition variable no longer decides."""
        # len() of another shard's deque is read without its lock — the
        # rotation is a fairness heuristic, and a stale length only shifts
        # whose turn it is by one grant
        with self._shards_lock:
            shards = list(self._shards.items())
        contenders = sorted(p for p, s in shards
                            if p is not None and s.waiting)
        if len(contenders) <= 1 or pool not in contenders:
            return True
        last = self._borrow_last
        if last is None:
            allowed = contenders[0]
        else:
            after = [p for p in contenders if p > last]
            allowed = after[0] if after else contenders[0]
        return pool == allowed

    def wait_admit(self, query_id: str, user=None, application=None,
                   cancel_token=None, timeout: Optional[float] = None,
                   poll_interval: float = 0.05) -> Optional[QuerySlot]:
        """Blocking admission through per-pool FIFO queues.

        Each routed pool keeps its own queue and only the queue *head* may
        probe for capacity, so admission within a pool is arrival-ordered
        instead of FIFO-by-wakeup (a late waiter can no longer race an
        earlier one to a freed slot); the per-pool heads round-robin over
        borrowable idle capacity via the shared condition variable.
        Re-probes whenever a running query releases capacity (and at
        ``poll_interval`` so a tripped ``cancel_token`` is observed
        promptly).  Raises the token's error when cancelled/killed while
        queued, and :class:`QueryKilledError` on ``timeout``.
        """
        deadline = (time.monotonic() + timeout) if timeout is not None else None
        ticket = object()
        pool = self.route(user, application)
        shard = self._shard(pool)
        with shard.cond:
            if cancel_token is not None:
                cancel_token.check()
            # fast path only when nobody is queued for the routed pool —
            # otherwise a new arrival could race the queue head to a slot
            # that was freed between the release and the head's wakeup
            if not shard.waiting:
                slot, saturated = self.try_admit(query_id, user, application,
                                                 cancel_token)
                if not saturated:
                    return slot
            shard.waiting.append(ticket)
            try:
                while True:
                    if cancel_token is not None:
                        cancel_token.check()
                    if shard.waiting[0] is ticket:
                        slot, saturated = self.try_admit(
                            query_id, user, application, cancel_token)
                        if not saturated:
                            return slot
                    wait = poll_interval
                    if deadline is not None:
                        remaining = deadline - time.monotonic()
                        if remaining <= 0:
                            self.metrics.inc("wlm.admission_timeouts")
                            raise QueryKilledError(
                                f"query {query_id} timed out waiting for "
                                f"admission"
                            )
                        wait = min(wait, remaining)
                    shard.cond.wait(wait)
            finally:
                try:
                    shard.waiting.remove(ticket)
                except ValueError:  # pragma: no cover - defensive
                    pass
                # the next-in-line head (if any) probes immediately
                shard.cond.notify_all()

    def queue_depths(self) -> Dict[str, int]:
        """Admission queue depth per pool (for ``QueryHandle.poll()``
        diagnostics: which pools have unplaceable queries right now)."""
        with self._shards_lock:
            shards = list(self._shards.items())
        out = {p: 0 for p in (self._active.pools if self._active else ())}
        out.update({p: len(s.waiting) for p, s in shards
                    if p is not None and s.waiting})
        return out

    def executors_for(self, slot: Optional[QuerySlot]) -> int:
        if slot is None or self._active is None:
            return self.total_executors
        frac = self._active.pools[slot.pool].alloc_fraction
        return max(1, int(self.total_executors * frac))

    # ------------------------------------------------------------- triggers
    def update_metrics(self, query_id: str, **metrics) -> None:
        """Record metrics and fire any matching triggers (move/kill)."""
        with self._lock:
            slot = self._running.get(query_id)
            plan = self._active
            if slot is None or plan is None:
                return
            slot.metrics.update(metrics)
            slot.metrics["total_runtime"] = (time.time() - slot.admitted_at) * 1000.0
            for rule in plan.rules.values():
                if rule.pools and slot.pool not in rule.pools:
                    continue
                value = slot.metrics.get(rule.metric)
                if value is None or value <= rule.threshold:
                    continue
                if rule.action == "move" and rule.target_pool and slot.pool != rule.target_pool:
                    slot.moves.append(f"{slot.pool}->{rule.target_pool}")
                    slot.pool = rule.target_pool
                    self.metrics.inc("wlm.moved")
                elif rule.action == "kill":
                    slot.killed = True
                    self.metrics.inc("wlm.killed")
        if slot.killed:
            # trip the handle's token first so sibling DAG vertices stop at
            # their next boundary, then surface the kill to the caller
            if slot.cancel_token is not None:
                slot.cancel_token.kill(
                    f"query {query_id} killed by trigger"
                )
            raise QueryKilledError(f"query {query_id} killed by trigger")

    def release(self, query_id: str) -> None:
        with self._lock:
            slot = self._running.pop(query_id, None)
            if slot is not None:
                self.metrics.inc("wlm.released")
                charged = slot.metrics.get("charged_pool", slot.pool)
                if charged in self._pool_load and self._pool_load[charged] > 0:
                    self._pool_load[charged] -= 1
        if slot is None:
            return
        # wake waiters *after* dropping _lock (shard-then-_lock ordering).
        # Freed capacity in one pool can admit another pool's head via
        # borrowing, so every shard with waiters is notified; the shards'
        # 0.05s poll backstop covers any shard created concurrently.
        with self._shards_lock:
            shards = list(self._shards.values())
        for shard in shards:
            with shard.cond:
                if shard.waiting:
                    shard.cond.notify_all()
