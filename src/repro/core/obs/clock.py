"""The sanctioned clocks for runtime/serving/federation timing.

Every duration that feeds telemetry — vertex walls, exchange waits, spill
I/O, adaptive straggler ages — must come from one clock family so spans
from different modules compose into a single consistent
:class:`~.trace.QueryTrace` timeline.  Lint rule REP007 enforces the
chokepoint: raw ``time.monotonic()`` / ``time.perf_counter()`` calls inside
``core/runtime``, ``core/serving`` and ``core/federation`` are findings;
code there imports these aliases (or uses span helpers) instead.  The only
allowlisted exceptions are scheduler *deadline* math (WLM admission
timeouts, result-stream stall guards), where the raw clock is the point.
"""
from __future__ import annotations

import time

#: High-resolution duration clock (span timestamps, vertex walls).
perf_counter = time.perf_counter

#: Monotonic event clock (straggler ages, production telemetry).
monotonic = time.monotonic

#: Wall clock, for human-facing timestamps only (query-log entries).
now = time.time
