"""Warehouse-wide metrics registry: counters, gauges, bucketed histograms.

One :class:`MetricsRegistry` per warehouse is the single source for every
counter the surfaces report — WLM admission, serving-tier hits, exchange
spill volume, query outcomes/latency.  The existing dict shapes
(``server_stats()``, ``poll()["serving"]``, ``stats_snapshot()``) are
*derived* from registry-backed counters so the surfaces can't drift from
the registry, and ``Connection.metrics()`` exposes the whole snapshot.

Counters are registry-locked (increments happen on cold paths: spills,
admissions, query completion — never per morsel).  Gauges are callables
evaluated at snapshot time.  Histograms use fixed millisecond buckets with
rank-interpolated p50/p99 estimates.
"""
from __future__ import annotations

import bisect
from typing import Callable, Dict, List, Optional, Sequence

from ...analysis.lockdep import make_lock

#: Latency buckets (milliseconds), upper bounds; one overflow bucket above.
DEFAULT_BUCKETS_MS = (1.0, 2.0, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0,
                      500.0, 1000.0, 2500.0, 5000.0, 10000.0)


class Counter:
    __slots__ = ("_lock", "_v")

    def __init__(self, lock):
        self._lock = lock
        self._v = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._v += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._v


class Histogram:
    __slots__ = ("_lock", "buckets", "_counts", "_sum", "_n")

    def __init__(self, lock, buckets: Sequence[float] = DEFAULT_BUCKETS_MS):
        self._lock = lock
        self.buckets = tuple(sorted(float(b) for b in buckets))
        self._counts: List[int] = [0] * (len(self.buckets) + 1)
        self._sum = 0.0
        self._n = 0

    def observe(self, value: float) -> None:
        i = bisect.bisect_left(self.buckets, float(value))
        with self._lock:
            self._counts[i] += 1
            self._sum += float(value)
            self._n += 1

    def _quantile(self, q: float) -> Optional[float]:
        """Bucket-resolution quantile: the upper bound of the bucket the
        rank lands in (overflow bucket reports the largest bound)."""
        if self._n == 0:
            return None
        rank = q * self._n
        seen = 0
        for i, c in enumerate(self._counts):
            seen += c
            if seen >= rank:
                return self.buckets[min(i, len(self.buckets) - 1)]
        return self.buckets[-1]

    def snapshot(self) -> dict:
        with self._lock:
            counts = list(self._counts)
            total, n = self._sum, self._n
            p50, p99 = self._quantile(0.50), self._quantile(0.99)
        bucket_counts = {
            f"le_{self.buckets[i]:g}": counts[i]
            for i in range(len(self.buckets))
        }
        bucket_counts["overflow"] = counts[-1]
        return {"count": n, "sum": round(total, 3),
                "mean": round(total / n, 3) if n else None,
                "p50": p50, "p99": p99, "buckets": bucket_counts}


class MetricsRegistry:
    """Named counters / gauges / histograms with a JSON-able snapshot."""

    def __init__(self):
        self._lock = make_lock("obs.metrics")
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Callable[[], object]] = {}
        self._hists: Dict[str, Histogram] = {}

    # -- instruments --------------------------------------------------------
    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter(self._lock)
            return c

    def inc(self, name: str, n: int = 1) -> None:
        self.counter(name).inc(n)

    def gauge(self, name: str, fn: Callable[[], object]) -> None:
        """Register (or replace) a gauge evaluated at snapshot time."""
        with self._lock:
            self._gauges[name] = fn

    def histogram(self, name: str,
                  buckets: Sequence[float] = DEFAULT_BUCKETS_MS) -> Histogram:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = Histogram(self._lock, buckets)
            return h

    def observe(self, name: str, value: float) -> None:
        self.histogram(name).observe(value)

    # -- snapshot -----------------------------------------------------------
    def snapshot(self) -> dict:
        with self._lock:
            counters = {k: c._v for k, c in sorted(self._counters.items())}
            gauges = dict(self._gauges)
            hists = dict(self._hists)
        gauge_vals = {}
        for name, fn in sorted(gauges.items()):
            try:
                gauge_vals[name] = fn()
            except Exception:  # noqa: BLE001 - telemetry must not raise
                gauge_vals[name] = None
        return {"counters": counters, "gauges": gauge_vals,
                "histograms": {k: h.snapshot()
                               for k, h in sorted(hists.items())}}
