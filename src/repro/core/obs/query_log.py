"""Always-on bounded ring buffer of completed queries.

The warehouse records one entry per completed query — status, wall time,
rows, admission wait, cache / shared-scan disposition — regardless of
whether tracing is on, so ``Connection.query_log()`` can answer "what ran
here lately" with zero configuration.  Capacity comes from the declared
``obs.query_log_size`` default (the ring is warehouse-wide; oldest entries
evict first).
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional

from ...analysis.lockdep import make_lock


class QueryLog:
    def __init__(self, capacity: int = 128):
        self.capacity = max(int(capacity), 1)
        self._lock = make_lock("obs.query_log")
        self._entries: deque = deque(maxlen=self.capacity)

    def record(self, entry: Dict) -> None:
        with self._lock:
            self._entries.append(dict(entry))

    def entries(self, limit: Optional[int] = None) -> List[Dict]:
        """Oldest-first list of retained entries (copies); ``limit`` keeps
        only the most recent N."""
        with self._lock:
            out = [dict(e) for e in self._entries]
        return out[-int(limit):] if limit else out

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)
