"""Warehouse observability layer (PR 10).

Three coupled pieces, shared by every telemetry surface:

  * :mod:`.trace` — structured per-query tracing: a :class:`QueryTrace`
    of nested spans and point events (pipeline stages, WLM admission
    wait, DAG vertices split into compute / exchange-wait / spill-I/O,
    shuffle lanes, federated split reads, kernel dispatches, serving and
    adaptive events), exportable as Chrome trace-event JSON for
    Perfetto.  ``make_span`` / ``emit_event`` follow the lockdep factory
    pattern: plain no-op singletons when ``obs.tracing`` is off, one
    attribute test on the hot path.
  * :mod:`.metrics` — the warehouse :class:`MetricsRegistry` (counters /
    gauges / bucketed histograms); ``poll()``, ``server_stats()`` and the
    WLM/serving/shuffle counters keep their dict shapes but derive from
    it, and ``Connection.metrics()`` exposes the full snapshot.
  * :mod:`.query_log` — the always-on bounded ring of completed queries
    behind ``Connection.query_log()``.

:class:`WarehouseObs` bundles the three plus a bounded store of completed
traces (``Connection.export_trace(query_id, path)``); the clock aliases in
:mod:`.clock` are the REP007-sanctioned timing sources for
``core/runtime``, ``core/serving`` and ``core/federation``.
"""
from __future__ import annotations

import json
from collections import OrderedDict
from typing import Optional

from ...analysis.lockdep import make_lock
from . import clock
from .metrics import DEFAULT_BUCKETS_MS, Counter, Histogram, MetricsRegistry
from .query_log import QueryLog
from .trace import (NOOP_SPAN, QueryTrace, close_vertex_frame, emit_event,
                    make_span, note_exchange_wait, note_spill_io,
                    open_vertex_frame, tracing_enabled)

__all__ = [
    "DEFAULT_BUCKETS_MS", "Counter", "Histogram", "MetricsRegistry",
    "NOOP_SPAN", "QueryLog", "QueryTrace", "WarehouseObs", "clock",
    "close_vertex_frame", "emit_event", "make_span", "note_exchange_wait",
    "note_spill_io", "open_vertex_frame", "tracing_enabled",
]


class WarehouseObs:
    """Per-warehouse observability hub: registry + query log + traces."""

    def __init__(self, query_log_size: Optional[int] = None,
                 trace_store_size: Optional[int] = None):
        from ..config_keys import DEFAULT_CONFIG

        self.metrics = MetricsRegistry()
        self.query_log = QueryLog(
            query_log_size or DEFAULT_CONFIG["obs.query_log_size"])
        self._trace_cap = max(
            int(trace_store_size
                or DEFAULT_CONFIG["obs.trace_store_size"]), 1)
        self._traces: "OrderedDict[str, QueryTrace]" = OrderedDict()
        self._lock = make_lock("obs.traces")

    # -- trace store --------------------------------------------------------
    def store_trace(self, qid: str, trace: QueryTrace) -> None:
        with self._lock:
            self._traces[qid] = trace
            self._traces.move_to_end(qid)
            while len(self._traces) > self._trace_cap:
                self._traces.popitem(last=False)

    def get_trace(self, qid: str) -> Optional[QueryTrace]:
        with self._lock:
            return self._traces.get(qid)

    def export_trace(self, qid: str, path: str) -> str:
        """Write one completed query's Chrome trace JSON to ``path``."""
        trace = self.get_trace(qid)
        if trace is None:
            raise KeyError(
                f"no trace retained for query {qid!r} (was obs.tracing on, "
                f"and is the query within the last {self._trace_cap} traced "
                f"completions?)")
        with open(path, "w") as f:
            json.dump(trace.to_chrome(), f, indent=1)
            f.write("\n")
        return path

    # -- query completion ---------------------------------------------------
    def note_query_done(self, entry: dict,
                        trace: Optional[QueryTrace] = None) -> None:
        """Record one completed query: ring-buffer entry, outcome counters,
        latency histograms, and (when traced) the retained trace."""
        self.query_log.record(entry)
        status = str(entry.get("status", "unknown")).lower()
        self.metrics.inc(f"query.{status}")
        if entry.get("wall_ms") is not None:
            self.metrics.observe("query.wall_ms", entry["wall_ms"])
        if entry.get("queue_wait_ms") is not None:
            self.metrics.observe("query.queue_wait_ms",
                                 entry["queue_wait_ms"])
        if entry.get("cache_hit"):
            self.metrics.inc("query.result_cache_served")
        if trace is not None and entry.get("qid"):
            self.store_trace(entry["qid"], trace)
