"""Structured per-query tracing (spans + point events, Chrome-exportable).

One :class:`QueryTrace` collects everything a single query does — pipeline
stages, the WLM admission wait, every DAG vertex (split into compute vs.
exchange-wait vs. spill-I/O time), shuffle lanes, federated split reads,
kernel dispatches, serving-tier attach/hit and adaptive decisions — on one
shared clock (:mod:`.clock`), and exports the lot as Chrome trace-event
JSON (``QueryHandle.trace()`` / ``Connection.export_trace``) so a query
renders directly in Perfetto / ``chrome://tracing``.

Hot-path discipline follows the lockdep factory pattern: tracing resolves
to a per-query ``trace`` object exactly once (``None`` when ``obs.tracing``
is off), every instrumentation site pays a single ``is not None`` attribute
test, and :func:`make_span` returns the module-level :data:`NOOP_SPAN`
singleton when tracing is off — no span objects are ever allocated on the
morsel path.

Vertex sub-phase accounting is thread-local: a vertex thread opens a
frame (:func:`open_vertex_frame`), the exchange layer accumulates blocking
wait and spill-I/O durations into it (:func:`note_exchange_wait` /
:func:`note_spill_io`), and the scheduler folds the frame into the vertex
record at completion.  Accumulation outside an open frame (e.g. the client
thread draining the root exchange) is silently dropped.
"""
from __future__ import annotations

import os
import threading
from typing import Dict, List, Optional

from ...analysis.lockdep import make_lock
from . import clock

ENV_FLAG = "REPRO_OBS_TRACING"


def tracing_enabled(config: Optional[dict] = None) -> bool:
    """Is per-query tracing on — via session config or process-wide env?"""
    if os.environ.get(ENV_FLAG, "") not in ("", "0"):
        return True
    return bool((config or {}).get("obs.tracing", False))


# ---------------------------------------------------------------- factories
class _NoopSpan:
    """The tracing-off span: a stateless context-manager singleton."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


#: The one no-op span instance; ``make_span(None, ...)`` always returns it,
#: so tracing-off runs allocate zero span objects (tests check identity).
NOOP_SPAN = _NoopSpan()


def make_span(trace: Optional["QueryTrace"], name: str, cat: str = "span",
              **args):
    """A live span on ``trace``, or the shared no-op when tracing is off."""
    if trace is None:
        return NOOP_SPAN
    return trace.span(name, cat, **args)


def emit_event(trace: Optional["QueryTrace"], name: str, cat: str = "event",
               **args) -> None:
    """Record a point event; no-op (no allocation) when tracing is off."""
    if trace is not None:
        trace.event(name, cat, **args)


# -------------------------------------------------- thread-local accounting
class _VertexFrame:
    __slots__ = ("wait_s", "spill_s")

    def __init__(self):
        self.wait_s = 0.0
        self.spill_s = 0.0


_tls = threading.local()


def open_vertex_frame() -> _VertexFrame:
    """Start exchange-wait / spill-I/O accounting on this thread."""
    frame = _VertexFrame()
    _tls.frame = frame
    return frame


def close_vertex_frame() -> None:
    _tls.frame = None


def note_exchange_wait(seconds: float) -> None:
    frame = getattr(_tls, "frame", None)
    if frame is not None:
        frame.wait_s += seconds


def note_spill_io(seconds: float) -> None:
    frame = getattr(_tls, "frame", None)
    if frame is not None:
        frame.spill_s += seconds


# ------------------------------------------------------------------- spans
class _Span:
    """A live span: context manager recording a completed interval."""

    __slots__ = ("_trace", "name", "cat", "args", "_t0")

    def __init__(self, trace: "QueryTrace", name: str, cat: str, args: dict):
        self._trace = trace
        self.name = name
        self.cat = cat
        self.args = args
        self._t0 = 0.0

    def __enter__(self) -> "_Span":
        self._t0 = clock.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        self._trace.add_span(self.name, self.cat, self._t0,
                             clock.perf_counter(), **self.args)
        return False


class QueryTrace:
    """All spans and events one query emitted, on one shared clock.

    Live spans record on the thread they ran on; synthetic per-vertex and
    per-lane spans (built from :meth:`add_vertex` records at export time)
    get their own tracks so aggregate sub-phases can never interleave with
    live span nesting.
    """

    def __init__(self, qid: str, sql: str = ""):
        self.qid = qid
        self.sql = sql
        self.t0 = clock.perf_counter()
        self._lock = make_lock("obs.trace")
        # (name, cat, t_begin, t_end, track, args); track None => this thread
        self._spans: List[tuple] = []
        # (name, cat, ts, track, args)
        self._events: List[tuple] = []
        self.vertices: Dict[str, dict] = {}
        self.kernels: Dict[str, int] = {}

    # -- recording ----------------------------------------------------------
    def span(self, name: str, cat: str = "span", **args) -> _Span:
        return _Span(self, name, cat, args)

    def event(self, name: str, cat: str = "event", **args) -> None:
        with self._lock:
            self._events.append(
                (name, cat, clock.perf_counter(), threading.get_ident(),
                 args))

    def add_span(self, name: str, cat: str, t_begin: float, t_end: float,
                 track=None, **args) -> None:
        """Record a completed interval (``track=None`` = calling thread)."""
        if track is None:
            track = threading.get_ident()
        with self._lock:
            self._spans.append((name, cat, t_begin, t_end, track, args))

    def kernel_dispatch(self, name: str, engine: str) -> None:
        """Count a kernel-registry dispatch; first occurrence of each
        (kernel, engine) pair also drops a point event on the timeline."""
        key = f"{name}[{engine}]"
        with self._lock:
            seen = self.kernels.get(key, 0)
            self.kernels[key] = seen + 1
            if seen == 0:
                self._events.append(
                    (f"kernel:{key}", "kernel", clock.perf_counter(),
                     threading.get_ident(), {}))

    def add_vertex(self, vid: str, t_begin: float, seconds: float,
                   wait_s: float = 0.0, spill_s: float = 0.0, rows: int = 0,
                   lanes=None, **extra) -> None:
        """Record one DAG vertex's wall split into compute vs.
        exchange-wait vs. spill-I/O (sub-phase seconds come from this
        thread's vertex frame; compute is the remainder)."""
        seconds = max(float(seconds), 0.0)
        wait_s = min(max(float(wait_s), 0.0), seconds)
        spill_s = min(max(float(spill_s), 0.0), max(seconds - wait_s, 0.0))
        rec = {
            "vid": vid,
            "t0": t_begin,
            "seconds": seconds,
            "compute_s": max(seconds - wait_s - spill_s, 0.0),
            "exchange_wait_s": wait_s,
            "spill_io_s": spill_s,
            "rows": int(rows),
            "lanes": list(lanes) if lanes else None,
        }
        rec.update(extra)
        with self._lock:
            # trace rollup keyed by vertex id, not DAG structure
            self.vertices[vid] = rec  # repro-lint: REP005

    # -- export -------------------------------------------------------------
    def summary(self) -> dict:
        """Structured rollup (EXPLAIN ANALYZE / bench trace_summary feed)."""
        with self._lock:
            spans = list(self._spans)
            events = list(self._events)
            vertices = {k: dict(v) for k, v in self.vertices.items()}
            kernels = dict(self.kernels)
        stages = {
            name.split(":", 1)[1]: round((t1 - t_b) * 1e3, 3)
            for name, cat, t_b, t1, _track, _a in spans if cat == "stage"
        }
        verts = {
            vid: {
                "total_ms": round(r["seconds"] * 1e3, 3),
                "compute_ms": round(r["compute_s"] * 1e3, 3),
                "exchange_wait_ms": round(r["exchange_wait_s"] * 1e3, 3),
                "spill_io_ms": round(r["spill_io_s"] * 1e3, 3),
                "rows": r["rows"],
                "lanes": r["lanes"],
            }
            for vid, r in sorted(vertices.items())
        }
        return {
            "qid": self.qid,
            "stages_ms": stages,
            "vertices": verts,
            "events": [
                {"name": name, "cat": cat,
                 "ts_ms": round((ts - self.t0) * 1e3, 3), **args}
                for name, cat, ts, _track, args in sorted(
                    events, key=lambda e: e[2])
            ],
            "kernel_dispatches": kernels,
        }

    def to_chrome(self) -> dict:
        """Chrome trace-event JSON (ph/ts/pid/tid; balanced B/E pairs)."""
        with self._lock:
            spans = list(self._spans)
            events = list(self._events)
            vertices = {k: dict(v) for k, v in self.vertices.items()}

        def us(t: float) -> float:
            return round((t - self.t0) * 1e6, 3)

        # synthetic per-vertex tracks: vertex span wrapping strictly-nested
        # sequential sub-phase spans, plus one track per shuffle lane
        for vid, r in sorted(vertices.items()):
            track = f"vertex {vid}"
            base = us(r["t0"])
            total = max(r["seconds"] * 1e6, 1.0)
            spans.append((f"vertex:{vid}", "vertex", r["t0"],
                          r["t0"] + total / 1e6, track, {
                              "rows": r["rows"],
                              "compute_ms": round(r["compute_s"] * 1e3, 3),
                              "exchange_wait_ms":
                                  round(r["exchange_wait_s"] * 1e3, 3),
                              "spill_io_ms": round(r["spill_io_s"] * 1e3, 3),
                          }))
            subs = [("compute", r["compute_s"] * 1e6),
                    ("exchange-wait", r["exchange_wait_s"] * 1e6),
                    ("spill-io", r["spill_io_s"] * 1e6)]
            durs = [max(d, 0.01) for _n, d in subs]
            scale = (total - 0.02) / sum(durs) if sum(durs) > total - 0.02 \
                else 1.0
            cursor = base + 0.01
            for (sub, _d), dur in zip(subs, durs):
                end = cursor + dur * scale
                spans.append((f"{vid}:{sub}", "vertex-phase",
                              self.t0 + cursor / 1e6, self.t0 + end / 1e6,
                              track, {}))
                cursor = end
            for lane in r["lanes"] or []:
                p = lane.get("partition")
                spans.append((f"lane:{vid}.p{p}", "lane", r["t0"],
                              r["t0"] + total / 1e6, f"lane {vid}.p{p}",
                              dict(lane)))

        # stable small-int tids per track, in first-seen order
        tids: Dict[object, int] = {}

        def tid_of(track) -> int:
            if track not in tids:
                tids[track] = len(tids) + 1
            return tids[track]

        pid = os.getpid()
        out = []
        for name, cat, t_b, t_e, track, args in spans:
            dur = max(us(t_e) - us(t_b), 0.001)
            tid = tid_of(track)
            # sort keys give valid nesting for any properly-nestable set:
            # at equal ts all E before all B, longer B (parents) first,
            # shorter E (children) first
            out.append(((us(t_b), 1, -dur),
                        {"ph": "B", "ts": us(t_b), "pid": pid, "tid": tid,
                         "name": name, "cat": cat, "args": args}))
            out.append(((us(t_b) + dur, 0, dur),
                        {"ph": "E", "ts": us(t_b) + dur, "pid": pid,
                         "tid": tid, "name": name, "cat": cat}))
        for name, cat, ts, track, args in events:
            out.append(((us(ts), 2, 0.0),
                        {"ph": "i", "ts": us(ts), "pid": pid,
                         "tid": tid_of(track), "name": name, "cat": cat,
                         "s": "t", "args": args}))
        out.sort(key=lambda pair: pair[0])
        trace_events = [
            {"ph": "M", "ts": 0, "pid": pid, "tid": 0,
             "name": "process_name", "args": {"name": f"query {self.qid}"}}
        ]
        for track, tid in tids.items():
            label = track if isinstance(track, str) else f"thread-{tid}"
            trace_events.append(
                {"ph": "M", "ts": 0, "pid": pid, "tid": tid,
                 "name": "thread_name", "args": {"name": label}})
        trace_events.extend(ev for _k, ev in out)
        return {"traceEvents": trace_events, "displayTimeUnit": "ms",
                "otherData": {"qid": self.qid, "sql": self.sql}}
