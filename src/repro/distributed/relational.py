"""Distributed relational operators via shard_map + jax.lax collectives.

Hive's Tez edges map onto TPU-native collectives (DESIGN.md §2):

  SHUFFLE (hash repartition)  -> jax.lax.all_to_all
  BROADCAST (map join)        -> jax.lax.all_gather
  partial aggregation         -> psum / segment-local partials + all_to_all

These run the warehouse's vectorized operators data-parallel across the
'data' mesh axis: each shard holds a horizontal slice of the table (the
partition-directory layout maps 1:1 onto shards).  Keys are int64 codes
(factorized composite keys) and payloads are float columns — matching the
columnar batch layout after dictionary encoding.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map


# ---------------------------------------------------------------------------
# distributed hash aggregation: local partial agg -> all_to_all by key range
# ---------------------------------------------------------------------------
def make_distributed_group_sum(mesh: Mesh, num_groups: int, axis: str = "data"):
    """Grouped SUM/COUNT over sharded (codes, values).

    Phase 1 (map side): each shard aggregates its rows into a dense (G,)
    partial — Hive's map-side partial aggregation.
    Phase 2 (shuffle): G is range-partitioned across shards; partials move
    with one all_to_all; each shard sums its range — the reduce side.
    Output: fully-replicated (G,) sums/counts (all_gather at the end).
    """
    n_shards = mesh.shape[axis]
    g_pad = ((num_groups + n_shards - 1) // n_shards) * n_shards

    def kernel(codes, values):
        # map-side partial aggregation (dense accumulate)
        sums = jnp.zeros((g_pad,), jnp.float32).at[codes].add(
            values.astype(jnp.float32))
        counts = jnp.zeros((g_pad,), jnp.float32).at[codes].add(
            (codes >= 0).astype(jnp.float32))
        # shuffle: range-partition the group domain
        sums = sums.reshape(n_shards, g_pad // n_shards)
        counts = counts.reshape(n_shards, g_pad // n_shards)
        sums = jax.lax.all_to_all(sums, axis, 0, 0, tiled=False)
        counts = jax.lax.all_to_all(counts, axis, 0, 0, tiled=False)
        # reduce side: sum partials for my key range
        my_sums = jnp.sum(sums, axis=0)
        my_counts = jnp.sum(counts, axis=0)
        # final: replicate (BI-style small result)
        all_sums = jax.lax.all_gather(my_sums, axis, axis=0, tiled=True)
        all_counts = jax.lax.all_gather(my_counts, axis, axis=0, tiled=True)
        return all_sums[:num_groups], all_counts[:num_groups]

    spec_in = P(axis)
    spec_out = P()
    return jax.jit(shard_map(
        kernel, mesh=mesh, in_specs=(spec_in, spec_in),
        out_specs=(spec_out, spec_out), check_rep=False,
    ))


# ---------------------------------------------------------------------------
# distributed hash join: all_to_all hash repartition, then local join
# ---------------------------------------------------------------------------
def make_shuffle_join(mesh: Mesh, rows_per_shard_out: int, axis: str = "data"):
    """Inner equi-join of two sharded key/value relations.

    Both sides hash-repartition on the join key with all_to_all so matching
    keys land on the same shard (Tez SHUFFLE edge), then each shard runs the
    vectorized local hash join.  Fixed output capacity per shard (static
    shapes); overflow is reported so the planner can re-run with more
    capacity (mirrors Hive's reoptimization on memory errors, §4.2).

    Inputs: (l_keys, l_vals) and (r_keys, r_vals), each sharded over `axis`;
    key = int64 >= 0; -1 marks padding.
    Returns (out_keys, out_lv, out_rv, overflow_count) per shard.
    """
    n_shards = mesh.shape[axis]

    def repartition(keys, vals):
        n = keys.shape[0]
        dest = jnp.where(keys >= 0, jnp.mod(keys, n_shards), -1).astype(jnp.int32)
        cap = n  # per-destination capacity (uniform-hash assumption x1)
        order = jnp.argsort(dest, stable=True)
        keys_s, vals_s, dest_s = keys[order], vals[order], dest[order]
        # position within destination bucket
        pos = jnp.arange(n) - jnp.searchsorted(dest_s, dest_s, side="left")
        buf_k = jnp.full((n_shards, cap), -1, keys.dtype)
        buf_v = jnp.zeros((n_shards, cap), vals.dtype)
        ok = (dest_s >= 0) & (pos < cap)
        buf_k = buf_k.at[jnp.where(ok, dest_s, 0), jnp.where(ok, pos, 0)].set(
            jnp.where(ok, keys_s, -1))
        buf_v = buf_v.at[jnp.where(ok, dest_s, 0), jnp.where(ok, pos, 0)].set(
            jnp.where(ok, vals_s, 0))
        buf_k = jax.lax.all_to_all(buf_k, axis, 0, 0, tiled=False)
        buf_v = jax.lax.all_to_all(buf_v, axis, 0, 0, tiled=False)
        return buf_k.reshape(-1), buf_v.reshape(-1)

    def local_join(lk, lv, rk, rv):
        order = jnp.argsort(rk)
        rk_s, rv_s = rk[order], rv[order]
        lo = jnp.searchsorted(rk_s, lk, side="left")
        hi = jnp.searchsorted(rk_s, lk, side="right")
        counts = jnp.where(lk >= 0, hi - lo, 0)
        total = jnp.sum(counts)
        cap = rows_per_shard_out
        starts = jnp.cumsum(counts) - counts
        # expand matches into fixed-capacity output
        out_k = jnp.full((cap,), -1, lk.dtype)
        out_l = jnp.zeros((cap,), lv.dtype)
        out_r = jnp.zeros((cap,), rv.dtype)
        idx = jnp.arange(cap)
        src_row = jnp.searchsorted(starts + counts, idx, side="right")
        src_row = jnp.minimum(src_row, lk.shape[0] - 1)
        within = idx - starts[src_row]
        valid = (idx < total) & (within < counts[src_row])
        r_idx = order[jnp.minimum(lo[src_row] + within, rk.shape[0] - 1)]
        out_k = jnp.where(valid, lk[src_row], -1)
        out_l = jnp.where(valid, lv[src_row], 0)
        out_r = jnp.where(valid, rv_s[jnp.minimum(lo[src_row] + within,
                                                  rk.shape[0] - 1)], 0)
        overflow = jnp.maximum(total - cap, 0)
        return out_k, out_l, out_r, overflow

    def kernel(lk, lv, rk, rv):
        lk2, lv2 = repartition(lk, lv)
        rk2, rv2 = repartition(rk, rv)
        out_k, out_l, out_r, ovf = local_join(lk2, lv2, rk2, rv2)
        return out_k, out_l, out_r, jax.lax.psum(ovf, axis)

    spec = P(axis)
    return jax.jit(shard_map(
        kernel, mesh=mesh, in_specs=(spec, spec, spec, spec),
        out_specs=(spec, spec, spec, P()), check_rep=False,
    ))


# ---------------------------------------------------------------------------
# broadcast (map) join: all_gather the small side
# ---------------------------------------------------------------------------
def make_broadcast_join(mesh: Mesh, axis: str = "data"):
    """Inner equi-join where the (small) right side is replicated via
    all_gather — Hive's map join / Tez BROADCAST edge."""

    def kernel(lk, lv, rk, rv):
        rk_all = jax.lax.all_gather(rk, axis, axis=0, tiled=True)
        rv_all = jax.lax.all_gather(rv, axis, axis=0, tiled=True)
        order = jnp.argsort(rk_all)
        rk_s, rv_s = rk_all[order], rv_all[order]
        lo = jnp.searchsorted(rk_s, lk, side="left")
        found = (lo < rk_s.shape[0]) & (rk_s[jnp.minimum(lo, rk_s.shape[0] - 1)] == lk) & (lk >= 0)
        rv_match = jnp.where(found, rv_s[jnp.minimum(lo, rk_s.shape[0] - 1)], 0)
        return jnp.where(found, lk, -1), jnp.where(found, lv, 0), rv_match

    spec = P(axis)
    return jax.jit(shard_map(
        kernel, mesh=mesh, in_specs=(spec, spec, spec, spec),
        out_specs=(spec, spec, spec), check_rep=False,
    ))
