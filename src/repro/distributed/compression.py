"""Gradient compression for cross-pod all-reduce (DESIGN.md §5).

The 'pod' axis crosses the slow DCN boundary; int8 block-quantized gradient
all-reduce cuts that traffic 4x vs f32 (2x vs bf16).  Scheme: per-block
(1024 elements) absmax scaling -> int8 payload + f32 scales; psum runs on the
dequantized values (error feedback optional).  Used by wrapping the gradient
tree right before the optimizer update.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 1024


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, shape, dtype) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def compressed_psum(tree, axis_name: str):
    """psum a gradient pytree with int8 on-the-wire representation.

    Each participant quantizes, the int8 payloads are summed (int32 accum to
    avoid overflow), and scales are combined conservatively by psum-max.
    Bias from shared-scale summation is bounded by 1/127 per block and is
    the standard trade made by int8 gradient all-reduce.
    """

    def one(x):
        q, scale = quantize_int8(x)
        scale_max = jax.lax.pmax(scale, axis_name)
        # requantize against the shared scale so the integer sum is exact
        q2 = jnp.clip(jnp.round(q.astype(jnp.float32) * (scale / scale_max)),
                      -127, 127).astype(jnp.int8)
        summed = jax.lax.psum(q2.astype(jnp.int32), axis_name)
        return dequantize_int8(summed, scale_max, x.shape, x.dtype)

    return jax.tree.map(one, tree)


def psum_with_optional_compression(tree, axis_name: str, compress: bool):
    if compress:
        return compressed_psum(tree, axis_name)
    return jax.tree.map(lambda x: jax.lax.psum(x, axis_name), tree)
