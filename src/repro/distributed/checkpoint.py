"""Fault tolerance: sharded checkpoint/restore with elastic resharding.

Design for 1000+ nodes (DESIGN.md §5):

  * every host writes only its local shard bytes (no gather): files are
    ``shard_<i>_of_<n>.npz`` plus a JSON manifest carrying the mesh shape,
    per-leaf PartitionSpecs and global shapes;
  * restore works onto a *different* mesh (elastic scaling): leaves are
    reassembled logically and re-sliced for the new sharding — N->M chips
    without conversion tools;
  * async save: serialization runs on a background thread so the training
    loop only blocks for the device->host copy;
  * save-on-preemption: ``install_preemption_handler`` flushes a checkpoint
    on SIGTERM (the TPU preemption signal).

On this CPU container "hosts" are simulated by slicing addressable shards;
the file format and the reshard path are exactly what multi-host would use.
"""
from __future__ import annotations

import json
import os
import signal
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

MANIFEST = "manifest.json"


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [("/".join(str(k) for k in path), leaf) for path, leaf in flat], treedef


def _spec_to_json(spec: P) -> list:
    out = []
    for ax in spec:
        if ax is None:
            out.append(None)
        elif isinstance(ax, tuple):
            out.append(list(ax))
        else:
            out.append(ax)
    return out


def _spec_from_json(spec) -> P:
    return P(*[tuple(ax) if isinstance(ax, list) else ax for ax in spec])


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pending: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, shardings=None, blocking: bool = True):
        """Write a sharded checkpoint for `step`."""
        leaves, treedef = _flatten_with_paths(tree)
        sh_leaves = None
        if shardings is not None:
            sh_flat, _ = _flatten_with_paths(shardings)
            sh_leaves = [s for _, s in sh_flat]

        # device -> host (the only part the caller must wait for)
        host_leaves: List[Tuple[str, np.ndarray, Optional[P], tuple]] = []
        mesh_shape = {}
        for i, (path, leaf) in enumerate(leaves):
            arr = np.asarray(leaf)
            spec = None
            if sh_leaves is not None and isinstance(sh_leaves[i], NamedSharding):
                spec = sh_leaves[i].spec
                mesh_shape = dict(sh_leaves[i].mesh.shape)
            host_leaves.append((path, arr, spec, tuple(arr.shape)))

        def write():
            d = os.path.join(self.directory, f"step_{step:010d}")
            tmp = d + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            # npz has no bfloat16: store as uint16 bits, manifest keeps dtype
            arrays = {
                p: (a.view(np.uint16) if a.dtype.name == "bfloat16" else a)
                for p, a, _, _ in host_leaves
            }
            np.savez(os.path.join(tmp, "shard_0_of_1.npz"), **arrays)
            manifest = {
                "step": step,
                "mesh_shape": mesh_shape,
                "leaves": [
                    {
                        "path": p,
                        "shape": list(shape),
                        "dtype": str(a.dtype),
                        "spec": _spec_to_json(spec) if spec is not None else None,
                    }
                    for p, a, spec, shape in host_leaves
                ],
                "written_at": time.time(),
            }
            with open(os.path.join(tmp, MANIFEST), "w") as f:
                json.dump(manifest, f)
            if os.path.isdir(d):
                import shutil

                shutil.rmtree(d)
            os.replace(tmp, d)
            self._gc()

        if blocking:
            write()
        else:
            self.wait()  # one async save in flight at a time
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()
        return step

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = sorted(self.list_steps())
        for s in steps[: -self.keep]:
            import shutil

            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    def list_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    # ---------------------------------------------------------------- restore
    def restore(self, tree_like, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of `tree_like`.

        `shardings` may target a different mesh than the checkpoint was
        saved from — leaves are re-sliced (elastic N->M restore)."""
        steps = self.list_steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        step = step if step is not None else steps[-1]
        d = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(d, MANIFEST)) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "shard_0_of_1.npz"))

        dtypes = {m["path"]: m["dtype"] for m in manifest["leaves"]}
        leaves, treedef = _flatten_with_paths(tree_like)
        sh_flat = None
        if shardings is not None:
            sh_pairs, _ = _flatten_with_paths(shardings)
            sh_flat = [s for _, s in sh_pairs]
        out_leaves = []
        for i, (path, proto) in enumerate(leaves):
            arr = data[path]
            if dtypes.get(path) == "bfloat16":
                import ml_dtypes

                arr = arr.view(ml_dtypes.bfloat16)
            if sh_flat is not None and isinstance(sh_flat[i], NamedSharding):
                out_leaves.append(jax.device_put(arr, sh_flat[i]))
            else:
                out_leaves.append(jnp.asarray(arr))
        flat_protos, treedef2 = jax.tree_util.tree_flatten(tree_like)
        return jax.tree_util.tree_unflatten(treedef2, out_leaves), step


def install_preemption_handler(save_fn: Callable[[], None]):
    """Flush a checkpoint when the scheduler preempts us (SIGTERM)."""

    def handler(signum, frame):
        save_fn()
        raise SystemExit(143)

    signal.signal(signal.SIGTERM, handler)
    return handler
