"""mamba2-130m — SSD (state-space duality), attention-free [arXiv:2405.21060]."""
from .base import ModelConfig, SSMConfig, register

register(ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    layer_pattern=("ssm",),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    tie_embeddings=True,
    source="arXiv:2405.21060",
))
