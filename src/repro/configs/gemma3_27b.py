"""gemma3-27b — 5:1 local:global sliding-window pattern, 128k context
[hf:google/gemma-3 family].  62 layers = 10 x (5 local + 1 global) + 2 local
tail (handled as unscanned remainder layers)."""
from .base import ModelConfig, register

register(ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21_504,
    vocab_size=262_144,
    act="gelu",
    qk_norm=True,
    tie_embeddings=True,
    scale_embeddings=True,
    sliding_window=1024,
    rope_theta=1_000_000.0,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    tail_pattern=("local", "local"),
    source="hf:google/gemma-3-1b-pt (scaled)",
))
