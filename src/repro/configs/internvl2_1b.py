"""internvl2-1b — InternViT frontend (STUB) + InternLM2 backbone
[arXiv:2404.16821].  input_specs() supplies precomputed patch/text embeddings."""
from .base import ModelConfig, register

register(ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151_655,
    embedding_stub=True,
    layer_pattern=("attn",),
    source="arXiv:2404.16821",
))
