"""qwen3-14b — qk_norm, GQA kv=8 [hf:Qwen/Qwen3-8B family]."""
from .base import ModelConfig, register

register(ModelConfig(
    name="qwen3-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=17_408,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    layer_pattern=("attn",),
    source="hf:Qwen/Qwen3-8B",
))
