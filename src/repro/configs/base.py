"""Model/shape configuration system for the ML substrate.

Every assigned architecture is a `ModelConfig`; every input-shape set is a
`ShapeConfig`.  `ARCH_REGISTRY` is populated by the per-arch modules in this
package; `get_config(name)` is the single entry point used by the launcher
(``--arch <id>``).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256

    def num_heads(self, d_model: int) -> int:
        return self.expand * d_model // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # defaults to d_model // num_heads
    act: str = "silu"  # silu (SwiGLU) | gelu (GeGLU)
    gated_mlp: bool = True  # False: plain 2-matrix FFN (granite, musicgen)
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    scale_embeddings: bool = False  # gemma-style sqrt(d) embedding scaling
    sliding_window: Optional[int] = None
    # layer pattern repeated through depth, e.g. 5 local + 1 global (gemma3);
    # entries: 'attn' | 'local' | 'global' | 'ssm' | 'moe' | 'shared_attn'
    layer_pattern: Tuple[str, ...] = ("attn",)
    # unscanned remainder layers appended after the scanned periods (for
    # depths not divisible by the pattern period, e.g. gemma3's 62 = 10*6+2)
    tail_pattern: Tuple[str, ...] = ()
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    embedding_stub: bool = False  # vlm/audio: frontend supplies embeddings
    shared_attention: bool = False  # zamba2: one shared attn block reused
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def pattern_period(self) -> int:
        return len(self.layer_pattern)

    @property
    def num_periods(self) -> int:
        scanned = self.num_layers - len(self.tail_pattern)
        assert scanned % self.pattern_period == 0, (
            f"{self.name}: {scanned} scanned layers not divisible by pattern "
            f"period {self.pattern_period}"
        )
        return scanned // self.pattern_period

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        d, hd = self.d_model, self.resolved_head_dim
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.embedding_stub:
            total = self.vocab_size * d  # lm head only; frontend is external
        def layer_params(kind: str) -> int:
            if kind in ("attn", "local", "global"):
                attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) \
                    + self.num_heads * hd * d
                nmat = 3 if self.gated_mlp else 2
                return attn + nmat * d * self.d_ff + 2 * d
            if kind == "moe":
                attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) \
                    + self.num_heads * hd * d
                m = self.moe
                return attn + m.num_experts * 3 * d * m.d_ff_expert \
                    + d * m.num_experts + 2 * d
            if kind == "ssm":
                s = self.ssm
                d_inner = s.expand * d
                nheads = s.num_heads(d)
                in_proj = d * (2 * d_inner + 2 * s.d_state + nheads)
                return in_proj + d_inner * s.d_conv + d_inner * d \
                    + 2 * nheads + d
            if kind == "shared_attn":
                return 0  # shared weights counted once below
            raise ValueError(kind)

        total += sum(layer_params(k) for k in self.layer_pattern) * self.num_periods
        total += sum(layer_params(k) for k in self.tail_pattern)
        if self.shared_attention:
            attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) \
                + self.num_heads * hd * d
            nmat = 3 if self.gated_mlp else 2
            total += attn + nmat * d * self.d_ff + 2 * d
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        full = self.param_count()
        moe_layers = sum(1 for k in self.layer_pattern if k == "moe") * self.num_periods \
            + sum(1 for k in self.tail_pattern if k == "moe")
        inactive = moe_layers * (m.num_experts - m.top_k) * 3 * self.d_model * m.d_ff_expert
        return full - inactive


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode
    microbatches: int = 1  # gradient-accumulation steps for train shapes


# the four LM shape cells from the assignment
LM_SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "mamba2-130m", "granite-34b", "qwen3-14b", "gemma-7b", "gemma3-27b",
    "internvl2-1b", "olmoe-1b-7b", "grok-1-314b", "zamba2-1.2b",
    "musicgen-medium",
]

ARCH_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    ARCH_REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not ARCH_REGISTRY:
        load_all()
    if name not in ARCH_REGISTRY:
        raise KeyError(f"unknown arch {name}; have {sorted(ARCH_REGISTRY)}")
    return ARCH_REGISTRY[name]


def load_all() -> Dict[str, ModelConfig]:
    for arch in ARCH_IDS:
        importlib.import_module("repro.configs." + arch.replace("-", "_").replace(".", "_"))
    return ARCH_REGISTRY


def supported_shapes(cfg: ModelConfig) -> List[str]:
    """Which of the four shape cells apply to this arch (see DESIGN.md)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    # long_500k needs sub-quadratic attention: run for SSM/hybrid and for
    # gemma3 (5:1 sliding-window locals); skip for pure full-attention archs.
    if cfg.family in ("ssm", "hybrid") or (
        cfg.sliding_window is not None and "local" in cfg.layer_pattern
    ):
        out.append("long_500k")
    return out


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    kw = dataclasses.asdict(cfg)
    period = cfg.pattern_period
    kw["tail_pattern"] = tuple(kw["tail_pattern"])
    kw.update(
        num_layers=max(period, 2 if period == 1 else period) + len(cfg.tail_pattern),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) or 1,
        d_ff=128,
        vocab_size=256,
        head_dim=16,
        sliding_window=16 if cfg.sliding_window else None,
    )
    kw["layer_pattern"] = tuple(kw["layer_pattern"])
    if cfg.moe:
        kw["moe"] = MoEConfig(num_experts=4, top_k=2, d_ff_expert=32)
    if cfg.ssm:
        kw["ssm"] = SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=8)
    return ModelConfig(**kw)
