"""zamba2-1.2b — Mamba2 backbone + shared attention block [arXiv:2411.15242].
38 layers = 6 x (5 ssm + 1 shared_attn) + 2 ssm tail; the shared_attn block
reuses one global set of attention+MLP weights at every application."""
from .base import ModelConfig, SSMConfig, register

register(ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32_000,
    layer_pattern=("ssm", "ssm", "ssm", "ssm", "ssm", "shared_attn"),
    tail_pattern=("ssm", "ssm"),
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=256),
    shared_attention=True,
    source="arXiv:2411.15242",
))
