"""gemma-7b — GeGLU, head_dim=256, 16H MHA-ish (kv=16) [arXiv:2403.08295]."""
from .base import ModelConfig, register

register(ModelConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24_576,
    vocab_size=256_000,
    act="gelu",
    tie_embeddings=True,
    scale_embeddings=True,
    layer_pattern=("attn",),
    source="arXiv:2403.08295",
))
