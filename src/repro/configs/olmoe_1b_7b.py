"""olmoe-1b-7b — 64-expert top-8 MoE [arXiv:2409.02060]."""
from .base import ModelConfig, MoEConfig, register

register(ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,
    vocab_size=50_304,
    qk_norm=True,
    layer_pattern=("moe",),
    moe=MoEConfig(num_experts=64, top_k=8, d_ff_expert=1024),
    source="arXiv:2409.02060",
))
