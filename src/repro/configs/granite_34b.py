"""granite-34b — llama-arch code model, MQA (GQA kv=1) [arXiv:2405.04324]."""
from .base import ModelConfig, register

register(ModelConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24_576,
    vocab_size=49_152,
    act="gelu",
    gated_mlp=False,
    layer_pattern=("attn",),
    source="arXiv:2405.04324",
))
