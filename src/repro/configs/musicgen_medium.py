"""musicgen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284].
EnCodec frontend is a STUB: input_specs() supplies precomputed frame
embeddings; the backbone predicts codebook tokens (vocab=2048)."""
from .base import ModelConfig, register

register(ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    embedding_stub=True,
    act="gelu",
    gated_mlp=False,
    layer_pattern=("attn",),
    source="arXiv:2306.05284",
))
