"""grok-1-314b — 8-expert top-2 MoE, 314B total params [hf:xai-org/grok-1]."""
from .base import ModelConfig, MoEConfig, register

register(ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32_768,
    vocab_size=131_072,
    layer_pattern=("moe",),
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32_768),
    source="hf:xai-org/grok-1",
))
