"""Static schema-flow checker for plans and compiled task DAGs (SCH001-006).

``repro.core.schema`` defines the typed contract — ``ColumnType``/``Schema``
plus the per-node inference rules mirroring the executor's dtype semantics.
This module is the verification pass over that contract: it re-infers every
vertex's output schema in topological order (placeholders seeded from their
producer's inferred schema) and reports *definite* contradictions as rule-
coded findings.  Unknowable types degrade to ``any`` and are never flagged.

Rule codes:

=======  ==================================================================
SCH001   a column reference does not resolve against its input schema
SCH002   UNION / ShuffleRead branch schemas disagree (arity or dtypes with
         no common promotion)
SCH003   aggregate partial state and its merging fold disagree on the state
         dtype (a split/collapse or federated merge rewrite would silently
         change the result type — e.g. a float32 MIN partial re-folded
         through SUM)
SCH004   join or shuffle-partition key dtypes disagree across sides/lanes
         (the bitcast FNV ``hash_partition`` kernel routes string and
         numeric keys through different bit patterns, so mixed-family keys
         co-partition wrongly)
SCH005   a federated residual operator references a column the pushed
         projection/aggregate dropped from the connector's output
SCH006   a DAG edge placeholder disagrees with its producer vertex's output
         schema (names or declared dtypes)
=======  ==================================================================

Like the structural validator, this runs on every compiled (and adaptively
mutated) DAG when ``REPRO_VALIDATE_PLANS`` / ``debug.validate_plans`` is on:
``plan_validator.check_dag`` calls :func:`validate_dag_schemas` after its
structural pass, so the pipeline hook and the adaptive ``_adopt`` chokepoint
both get schema checking for free.
"""
from __future__ import annotations

from typing import Dict, List, Optional

RULES = {
    "SCH001": "unresolved column reference",
    "SCH002": "union/shuffle branch schema mismatch",
    "SCH003": "aggregate partial/merge fold state dtype mismatch",
    "SCH004": "join or shuffle-partition key dtype mismatch",
    "SCH005": "federated residual references a non-surviving column",
    "SCH006": "edge placeholder/producer schema disagreement",
}


def _classify(node, exc) -> str:
    """Map an inference failure on ``node`` to its rule code."""
    from ..core.optimizer import plan as P
    from ..core.schema import UnresolvedColumnError

    if isinstance(exc, UnresolvedColumnError):
        if _over_federated(node):
            return "SCH005"
        return "SCH001"
    if isinstance(node, (P.Union, P.ShuffleRead)):
        return "SCH002"
    if isinstance(node, P.Join):
        return "SCH004"
    return "SCH001"


def _over_federated(node) -> bool:
    """True when ``node`` is a residual operator directly over a pushed
    FederatedScan (walking through unary residual ops only)."""
    from ..core.optimizer import plan as P

    cur = node
    while cur.inputs:
        child = cur.inputs[0]
        if isinstance(child, P.FederatedScan):
            return child.spec is not None
        if not isinstance(child, (P.Filter, P.Project, P.Sort, P.Limit,
                                  P.Aggregate)):
            return False
        cur = child
    return False


def _check_merge_folds(node, src_schema, violations: List[str]) -> None:
    """SCH003: a merging-fold Aggregate (the shape split/collapse rewrites
    and federated partial-agg merges emit — each spec re-aggregates a
    partial-state column into itself) must preserve the state dtype."""
    from ..core.optimizer import plan as P
    from ..core.runtime.dag import MaterializedNode
    from ..core.schema import agg_result_type
    from ..core.sql import ast as A

    if not isinstance(node.input, (P.Union, P.Aggregate, MaterializedNode)):
        return
    for spec in node.aggs:
        if not (isinstance(spec.arg, A.Col) and spec.arg.table is None
                and spec.arg.name == spec.out_name
                and spec.arg.name in src_schema):
            continue  # not a self-fold over a partial-state column
        state = src_schema.get(spec.arg.name)
        folded = agg_result_type(spec.fn, state)
        if "any" in (state.token, folded.token):
            continue
        if folded.token != state.token:
            violations.append(
                f"SCH003: {node.describe()}: merging fold "
                f"{spec.fn}({spec.out_name}) changes the partial state "
                f"dtype {state.render()} -> {folded.render()}")


def _infer_collect(node, violations: List[str], memo: Dict[int, object],
                   where: str = ""):
    """Infer ``node``'s schema, recording rule-coded findings instead of
    raising; a subtree that already failed returns None (no cascades)."""
    from ..core.schema import SchemaMismatchError, infer_node

    if id(node) in memo:
        return memo[id(node)]
    ins = [_infer_collect(c, violations, memo, where) for c in node.inputs]
    out = None
    if not any(s is None for s in ins):
        try:
            out = infer_node(node, ins)
            from ..core.optimizer import plan as P

            if isinstance(node, P.Aggregate):
                _check_merge_folds(node, ins[0], violations)
        except SchemaMismatchError as exc:
            code = _classify(node, exc)
            violations.append(f"{code}: {where}{node.describe()}: {exc}")
    memo[id(node)] = out
    return out


def validate_plan_schema(plan) -> List[str]:
    """Schema-flow findings for one (pre-compile) plan tree."""
    violations: List[str] = []
    _infer_collect(plan, violations, {})
    return violations


def validate_dag_schemas(dag) -> List[str]:
    """Schema-flow findings for a compiled task DAG.

    Vertices are re-inferred in topo order; each ``MaterializedNode``
    placeholder is seeded with its producer vertex's inferred output schema
    (so drift across edges is caught), then checked against the
    placeholder's own declared names/schema (SCH006) and its lane keys
    (SCH004)."""
    from ..core.runtime.dag import _walk_materialized
    from ..core.schema import Schema

    violations: List[str] = []
    vertex_schema: Dict[str, Optional[Schema]] = {}
    try:
        order = dag.topo_order()
    except (KeyError, RecursionError):
        return []  # structurally broken; the structural pass reports it
    for vid in set(dag.vertices) - set(order):
        order.append(vid)  # staged/orphan vertices still get checked
    for vid in order:
        vert = dag.vertices[vid]
        memo: Dict[int, object] = {}
        for mn in _walk_materialized(vert.plan):
            produced = vertex_schema.get(mn.tag)
            if produced is None:
                declared = getattr(mn, "schema", None)
                memo[id(mn)] = declared if declared is not None \
                    else Schema.any_of(mn.names)
                continue
            _check_placeholder(vid, mn, produced, violations)
            memo[id(mn)] = produced.project(mn.names) \
                if set(mn.names) <= set(produced.names()) \
                else Schema.any_of(mn.names)
        vertex_schema[vid] = _infer_collect(vert.plan, violations, memo,
                                            where=f"{vid}: ")
    return violations


def _check_placeholder(vid, mn, produced, violations: List[str]) -> None:
    from ..core.schema import Schema

    if list(mn.names) != produced.names():
        violations.append(
            f"SCH006: {vid}: edge {mn.tag!r} placeholder declares columns "
            f"{list(mn.names)[:8]} but the producer emits "
            f"{produced.names()[:8]}")
        return
    declared: Optional[Schema] = getattr(mn, "schema", None)
    if declared is not None:
        for name, ty in declared:
            got = produced.get(name)
            if got is None or "any" in (ty.token, got.token):
                continue
            if got.token != ty.token and not ty.accepts(got.np_dtype()):
                violations.append(
                    f"SCH006: {vid}: edge {mn.tag!r} column {name!r} "
                    f"declared {ty.render()} but the producer emits "
                    f"{got.render()}")
    for key in mn.partition_keys:
        try:
            produced.resolve(key)
        except Exception:
            violations.append(
                f"SCH004: {vid}: edge {mn.tag!r} partition key {key!r} "
                f"does not resolve in the producer schema "
                f"{produced.names()[:8]} — lanes would hash a missing "
                f"column")
