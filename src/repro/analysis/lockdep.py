"""Lockdep sanitizer: runtime lock-order tracking behind a factory.

The warehouse holds ~20 locks across sharded WLM admission, the query
scheduler, per-edge exchanges, the serving tier, the metastore, and LLAP.
A lock-order inversion between any two of them surfaces in production as a
rare CI hang; this module makes it a deterministic, immediate failure
instead — the Linux-kernel lockdep idea scaled down to this runtime:

  * locks are created through :func:`make_lock` / :func:`make_rlock` /
    :func:`make_condition` with a *class name* (``"wlm.shard"``,
    ``"exchange"``, ...).  With ``REPRO_LOCKDEP`` unset in the environment
    the factories return plain :mod:`threading` primitives — zero overhead,
    byte-identical behavior;
  * with ``REPRO_LOCKDEP=1`` they return tracked wrappers that maintain a
    per-thread held-lock set and a global *acquisition-order graph* over
    lock class names.  Acquiring ``B`` while holding ``A`` records the edge
    ``A -> B``; an acquisition whose new edge would close a cycle raises
    :class:`LockOrderError` **at acquire time**, before any thread blocks —
    one AB + one BA acquisition anywhere in the process's history is
    enough, no actual interleaving race required.

Conditions built over tracked locks stay tracked (``threading.Condition``
delegates ``acquire``/``release``/``_release_save``/``_acquire_restore`` to
the lock object), and a ``wait()`` correctly drops the lock from the held
set for its duration.

Same-class edges (one exchange's condition acquired while holding another
exchange's) are recorded but never treated as cycles: lane arrays create
thousands of same-class siblings that are only ever held one at a time, and
instance-level ordering among them is meaningless.  A genuine same-class
nesting discipline would need explicit nesting annotations (kernel
``mutex_lock_nested``); nothing in this runtime holds two same-class locks.
"""
from __future__ import annotations

import os
import threading
from typing import Dict, List, Optional, Set

ENV_FLAG = "REPRO_LOCKDEP"


def enabled() -> bool:
    return bool(os.environ.get(ENV_FLAG))


class LockOrderError(RuntimeError):
    """An acquisition would close a cycle in the lock-order graph."""

    def __init__(self, holding: str, acquiring: str, path: List[str],
                 held_now: List[str]):
        self.holding = holding
        self.acquiring = acquiring
        self.path = path
        # path runs acquiring -> ... -> holding; the new holding->acquiring
        # edge closes the cycle
        chain = " -> ".join(path + [acquiring])
        super().__init__(
            f"lock-order inversion: acquiring {acquiring!r} while holding "
            f"{holding!r}, but the acquisition-order graph already has "
            f"{chain} (held now: {held_now})"
        )


class _Graph:
    """The global acquisition-order graph over lock class names."""

    def __init__(self):
        self._lock = threading.Lock()
        # name -> set of names acquired while name was held
        self._edges: Dict[str, Set[str]] = {}
        # (a, b) -> "where" string of the first time the edge was seen
        self._sites: Dict[tuple, str] = {}

    def reset(self) -> None:
        with self._lock:
            self._edges.clear()
            self._sites.clear()

    def snapshot(self) -> Dict[str, Set[str]]:
        with self._lock:
            return {k: set(v) for k, v in self._edges.items()}

    def _path(self, src: str, dst: str) -> Optional[List[str]]:
        """A path src -> ... -> dst in the edge set, or None.  Caller holds
        the graph lock."""
        stack, parent = [src], {src: None}
        while stack:
            n = stack.pop()
            if n == dst:
                out, cur = [], dst
                while cur is not None:
                    out.append(cur)
                    cur = parent[cur]
                return list(reversed(out))
            for m in self._edges.get(n, ()):
                if m not in parent:
                    parent[m] = n
                    stack.append(m)
        return None

    def note_acquire(self, held: List["TrackedLock"],
                     acquiring: "TrackedLock") -> None:
        """Record held->acquiring edges; raise on a would-be cycle."""
        new = acquiring.lock_name
        with self._lock:
            for h in held:
                a = h.lock_name
                if a == new:
                    continue  # same-class siblings: see module docstring
                if new not in self._edges.get(a, ()):
                    # would a -> new close a cycle?  (new ->* a exists)
                    path = self._path(new, a)
                    if path is not None:
                        raise LockOrderError(a, new, path,
                                             [x.lock_name for x in held])
                    self._edges.setdefault(a, set()).add(new)
                    self._sites.setdefault((a, new), _caller_site())


def _caller_site() -> str:
    import traceback

    for frame in reversed(traceback.extract_stack(limit=12)):
        fn = frame.filename
        if "analysis/lockdep" not in fn.replace(os.sep, "/"):
            return f"{fn}:{frame.lineno}"
    return "?"


_GRAPH = _Graph()
_STATE = threading.local()


def _held() -> Dict[int, list]:
    """Per-thread held map: id(lock) -> [lock, depth]."""
    try:
        return _STATE.held
    except AttributeError:
        _STATE.held = {}
        return _STATE.held


def reset() -> None:
    """Clear the global order graph (test isolation)."""
    _GRAPH.reset()


def graph_snapshot() -> Dict[str, Set[str]]:
    return _GRAPH.snapshot()


class TrackedLock:
    """A named, order-tracked wrapper over ``threading.Lock``/``RLock``.

    Exposes the full lock protocol (including the ``_release_save`` /
    ``_acquire_restore`` / ``_is_owned`` trio ``threading.Condition`` uses),
    so it can stand anywhere the plain primitive did.
    """

    _reentrant = False

    def __init__(self, name: str, inner=None):
        self.lock_name = name
        self._inner = inner if inner is not None else threading.Lock()

    # ------------------------------------------------------------- tracking
    def _before_acquire(self) -> None:
        held = _held()
        ent = held.get(id(self))
        if ent is not None and self._reentrant:
            return  # reentrant re-acquire: no new edges
        _GRAPH.note_acquire([e[0] for e in held.values() if e[1] > 0], self)

    def _note_acquired(self) -> None:
        held = _held()
        ent = held.setdefault(id(self), [self, 0])
        ent[1] += 1

    def _note_released(self, full: bool = False) -> None:
        held = _held()
        ent = held.get(id(self))
        if ent is None:
            return
        ent[1] = 0 if full else ent[1] - 1
        if ent[1] <= 0:
            del held[id(self)]

    # ------------------------------------------------------------- protocol
    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        self._before_acquire()
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._note_acquired()
        return got

    def release(self) -> None:
        self._inner.release()
        self._note_released()

    def __enter__(self):
        self.acquire()  # repro-lint: REP004 — the wrapper IS the protocol
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def locked(self) -> bool:
        return self._inner.locked()

    # Condition protocol: wait() fully releases the lock — drop it from the
    # held set for the wait's duration so cross-thread edges stay truthful
    def _release_save(self):
        if hasattr(self._inner, "_release_save"):
            state = self._inner._release_save()
        else:
            self._inner.release()
            state = None
        self._note_released(full=True)
        return state

    def _acquire_restore(self, state) -> None:
        self._before_acquire()
        if hasattr(self._inner, "_acquire_restore"):
            self._inner._acquire_restore(state)
        else:
            self._inner.acquire()  # repro-lint: REP004 — protocol internals
        self._note_acquired()

    def _is_owned(self) -> bool:
        if hasattr(self._inner, "_is_owned"):
            return self._inner._is_owned()
        ent = _held().get(id(self))
        return ent is not None and ent[1] > 0

    def __repr__(self):
        return f"<{type(self).__name__} {self.lock_name!r}>"


class TrackedRLock(TrackedLock):
    _reentrant = True

    def __init__(self, name: str):
        super().__init__(name, threading.RLock())


class TrackedCondition(threading.Condition):
    """``threading.Condition`` over a tracked lock.

    ``Condition`` binds ``acquire``/``release`` straight to the lock object
    and uses its ``_release_save``/``_acquire_restore`` during ``wait``, so
    every entry/exit and every wait-side release/reacquire flows through
    the tracking in :class:`TrackedLock` with no further overrides here.
    """

    def __init__(self, lock=None, name: str = "condition"):
        if lock is None:
            lock = TrackedRLock(f"{name}.lock")
        elif not isinstance(lock, TrackedLock):
            raise TypeError(
                "TrackedCondition requires a tracked lock (make_lock / "
                "make_rlock), got %r" % (lock,)
            )
        super().__init__(lock)


# ===========================================================================
# the factory: zero-overhead plain primitives unless REPRO_LOCKDEP is set
# ===========================================================================
def make_lock(name: str):
    """A mutex of lock-class ``name`` (plain ``threading.Lock`` when
    lockdep is off)."""
    return TrackedLock(name) if enabled() else threading.Lock()


def make_rlock(name: str):
    return TrackedRLock(name) if enabled() else threading.RLock()


def make_condition(lock=None, name: str = "condition"):
    """A condition variable over ``lock`` (created if None).

    When lockdep is enabled and ``lock`` is an untracked primitive (or
    None), a tracked lock of class ``name`` is created instead, so the
    condition's waits/notifies participate in order checking.
    """
    if not enabled():
        return threading.Condition(lock)
    if lock is None or not isinstance(lock, TrackedLock):
        lock = TrackedRLock(f"{name}.lock")
    return TrackedCondition(lock, name=name)
