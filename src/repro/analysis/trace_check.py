"""Chrome trace-event JSON validator (the CI obs-smoke checker).

Checks the structural contract Perfetto / ``chrome://tracing`` rely on:

  * every event carries ``ph``, ``ts``, ``pid``, ``tid`` and ``name``;
  * ``ph`` is one of B/E/X/i/I/M;
  * per ``(pid, tid)`` track, timestamps are non-decreasing and B/E pairs
    are balanced with matching names (proper nesting — an ``E`` must close
    the innermost open ``B``);
  * no ``B`` left open at end of trace.

Usable as a library (``validate_chrome_trace``) from tests and the obs
smoke, or as a CLI::

    PYTHONPATH=src python -m repro.analysis.trace_check trace.json

exits 0 when the trace validates, 1 with one problem per line otherwise.
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List, Tuple

_VALID_PH = {"B", "E", "X", "i", "I", "M"}
_REQUIRED = ("ph", "ts", "pid", "tid", "name")


def validate_chrome_trace(data) -> List[str]:
    """Return a list of problems (empty = valid).  ``data`` is the loaded
    JSON object ({"traceEvents": [...]}) or the raw event list."""
    events = data.get("traceEvents") if isinstance(data, dict) else data
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    problems: List[str] = []
    stacks: Dict[Tuple, List[str]] = {}
    last_ts: Dict[Tuple, float] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        missing = [f for f in _REQUIRED if f not in ev]
        if missing:
            problems.append(f"event {i} ({ev.get('name')!r}): missing "
                            f"required fields {missing}")
            continue
        ph = ev["ph"]
        if ph not in _VALID_PH:
            problems.append(f"event {i} ({ev['name']!r}): unknown ph {ph!r}")
            continue
        if ph == "M":
            continue  # metadata: no timeline position
        if not isinstance(ev["ts"], (int, float)):
            problems.append(f"event {i} ({ev['name']!r}): non-numeric ts")
            continue
        key = (ev["pid"], ev["tid"])
        if ev["ts"] < last_ts.get(key, float("-inf")):
            problems.append(
                f"event {i} ({ev['name']!r}): ts {ev['ts']} goes backwards "
                f"on track {key} (last {last_ts[key]})")
        last_ts[key] = ev["ts"]
        if ph == "B":
            stacks.setdefault(key, []).append(ev["name"])
        elif ph == "E":
            stack = stacks.get(key) or []
            if not stack:
                problems.append(f"event {i} ({ev['name']!r}): E with no "
                                f"open B on track {key}")
            elif stack[-1] != ev["name"]:
                problems.append(
                    f"event {i}: E {ev['name']!r} does not close innermost "
                    f"open span {stack[-1]!r} on track {key}")
                stack.pop()
            else:
                stack.pop()
    for key, stack in stacks.items():
        if stack:
            problems.append(f"track {key}: unclosed spans at end of "
                            f"trace: {stack}")
    return problems


def main(argv: List[str]) -> int:
    if len(argv) != 1:
        print("usage: python -m repro.analysis.trace_check TRACE.json",
              file=sys.stderr)
        return 2
    with open(argv[0]) as f:
        data = json.load(f)
    problems = validate_chrome_trace(data)
    for p in problems:
        print(p)
    if not problems:
        events = data.get("traceEvents", data)
        spans = sum(1 for e in events if e.get("ph") == "B")
        print(f"OK: {len(events)} events, {spans} spans, trace validates")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
