"""Structural validator for compiled task DAGs.

``compile_dag`` destructively rewrites the optimized plan (operator inputs
are replaced with :class:`MaterializedNode` placeholders, ``ShuffleRead``
lanes are bound to producer vids), and the pipelined scheduler derives all
of its wiring — exchange fan-out, shuffle lane arrays, retention refcounts,
scratch-dir lifetime — from the compiled structure.  A malformed DAG does
not fail at compile time; it deadlocks a reader on an exchange nobody
writes, leaks spill files, or silently corrupts the plan cache.  This
module makes those failure modes loud at compile time:

  * every placeholder tag and dependency resolves to a vertex, and each
    vertex's ``deps`` list agrees with the placeholders actually reachable
    in its subtree (the scheduler trusts ``deps`` for topo order and the
    placeholders for wiring — disagreement means a vertex can start before
    its input exchange exists);
  * every vertex is reachable from the root, and every non-root vertex has
    at least one consumer (an orphan vertex's exchange retains every chunk
    until query end — an unbounded leak on large scans);
  * partitioned (shuffle) edges: lane indices are in range, agreeing specs
    cover every lane exactly, and the root never carries a lane array (the
    scheduler reads the root with ``read_all`` — nothing consumes lanes);
  * no leftover ``P.ShuffleRead`` nodes (compile must lower them all);
  * the DAG shares no plan-node objects with any plan-cache entry —
    compiling a cached plan in place (instead of the deep copy the cache
    probe hands out) would corrupt the cached "pristine" plan for every
    later session.

Validation runs on every compiled DAG when the session sets
``debug.validate_plans`` or the ``REPRO_VALIDATE_PLANS`` env var is set
(the test suite turns it on for the whole tier-1 run via an autouse
fixture); it is a no-op otherwise.
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional

ENV_FLAG = "REPRO_VALIDATE_PLANS"


class PlanValidationError(AssertionError):
    """A compiled DAG violates a structural invariant."""

    def __init__(self, violations: List[str]):
        self.violations = list(violations)
        super().__init__(
            "compiled DAG failed structural validation:\n  - "
            + "\n  - ".join(self.violations)
        )


def _plan_node_ids(plan) -> set:
    """ids of every node in a plan tree (placeholders are leaves)."""
    from ..core.runtime.dag import MaterializedNode

    seen, stack = set(), [plan]
    while stack:
        node = stack.pop()
        if id(node) in seen or node is None:
            continue
        seen.add(id(node))
        if isinstance(node, MaterializedNode):
            continue
        stack.extend(getattr(node, "inputs", ()))
        for rf in getattr(node, "runtime_filters", ()):
            stack.append(rf.producer)
    return seen


def validate_dag(dag, plan_cache=None, staged=None) -> List[str]:
    """All structural violations in ``dag`` (empty list = valid).

    ``staged`` names speculation clones the adaptive layer has added but
    not yet wired to a consumer: they are exempt from the orphan checks
    (their adoption — the consumer swap — is itself validated later), but
    their placeholders, deps, and lane indices are checked like any other
    vertex."""
    from ..core.optimizer import plan as P
    from ..core.runtime.dag import _walk_materialized, partitioned_edges

    v: List[str] = []
    vertices = dag.vertices

    if dag.root not in vertices:
        return [f"root vertex {dag.root!r} is not in the DAG"]

    # --- per-vertex: placeholders, deps, leftover ShuffleReads ------------
    readers: Dict[str, int] = {vid: 0 for vid in vertices}
    lane_readers: Dict[str, Dict[int, int]] = {}
    fed_by: Dict[str, set] = {vid: set() for vid in vertices}
    for vid, vert in vertices.items():
        tags = set()
        for mn in _walk_materialized(vert.plan):
            tags.add(mn.tag)
            if mn.tag not in vertices:
                v.append(f"{vid}: placeholder reads unknown vertex "
                         f"{mn.tag!r}")
                continue
            readers[mn.tag] += 1
            if mn.partition is not None:
                n = mn.num_partitions or 0
                if not (0 <= mn.partition < n):
                    v.append(f"{vid}: lane {mn.partition} of edge "
                             f"{mn.tag!r} out of range [0, {n})")
                lane_readers.setdefault(mn.tag, {})
                lane_readers[mn.tag][mn.partition] = \
                    lane_readers[mn.tag].get(mn.partition, 0) + 1
        expected = tags | set(vert.feeds)
        declared = set(vert.deps)
        for dep in declared - set(vertices):
            v.append(f"{vid}: declared dep {dep!r} is not in the DAG")
        if declared != expected:
            missing = expected - declared
            extra = declared - expected - (declared - set(vertices))
            if missing:
                v.append(f"{vid}: deps missing placeholder edges "
                         f"{sorted(missing)} — the scheduler may start "
                         f"this vertex before its inputs exist")
            if extra:
                v.append(f"{vid}: deps declare edges {sorted(extra)} with "
                         f"no placeholder or feed reading them")
        for dep in declared & set(vertices):
            fed_by[dep].add(vid)
        for node in _plan_node_ids_nodes(vert.plan):
            if isinstance(node, P.ShuffleRead):
                v.append(f"{vid}: leftover ShuffleRead (compile_dag must "
                         f"lower every lane read to a placeholder)")

    # --- reachability / orphan consumers ----------------------------------
    seen, stack = set(), [dag.root]
    while stack:
        cur = stack.pop()
        if cur in seen or cur not in vertices:
            continue
        seen.add(cur)
        stack.extend(vertices[cur].deps)
    staged = staged or ()
    for vid in sorted(set(vertices) - seen):
        if vid in staged:
            continue
        v.append(f"{vid}: unreachable from root {dag.root!r} (orphan "
                 f"vertex — its exchange would retain forever)")
    for vid in sorted(vertices):
        if vid == dag.root or vid in staged:
            continue
        if readers[vid] == 0 and not fed_by[vid]:
            v.append(f"{vid}: no consumer reads this vertex's exchange")

    # --- partitioned-edge lane coverage -----------------------------------
    specs = partitioned_edges(dag)
    if dag.root in specs:
        v.append(f"root {dag.root!r} carries a partitioned lane spec but "
                 f"is read via read_all — lanes would never drain")
    for tag, (n, _keys) in specs.items():
        if tag == dag.root:
            continue
        lanes = lane_readers.get(tag, {})
        uncovered = [i for i in range(n) if lanes.get(i, 0) == 0]
        if uncovered:
            v.append(f"edge {tag!r}: lanes {uncovered} of {n} have no "
                     f"reader — the ShuffleWriter would retain them "
                     f"until query end")

    # --- plan-cache aliasing ----------------------------------------------
    if plan_cache is not None:
        cached = _cached_plans(plan_cache)
        if cached:
            dag_ids = set()
            for vert in vertices.values():
                dag_ids |= _plan_node_ids(vert.plan)
            for key, ids in cached:
                shared = dag_ids & ids
                if shared:
                    v.append(
                        f"DAG shares {len(shared)} plan node(s) with "
                        f"cached plan {key[:60]!r}... — compile mutates "
                        f"node inputs in place, so the cached pristine "
                        f"plan is being corrupted (deepcopy on probe?)")
    return v


def _plan_node_ids_nodes(plan):
    """Every node object in a plan tree (excluding placeholder subtrees)."""
    from ..core.runtime.dag import MaterializedNode

    seen, stack, out = set(), [plan], []
    while stack:
        node = stack.pop()
        if node is None or id(node) in seen:
            continue
        seen.add(id(node))
        out.append(node)
        if isinstance(node, MaterializedNode):
            continue
        stack.extend(getattr(node, "inputs", ()))
        for rf in getattr(node, "runtime_filters", ()):
            stack.append(rf.producer)
    return out


def _cached_plans(plan_cache):
    """(key, node-id set) per live plan-cache entry."""
    lock = getattr(plan_cache, "_lock", None)
    entries = getattr(plan_cache, "_entries", None)
    if entries is None:
        return []
    if lock is not None:
        with lock:
            items = list(entries.items())
    else:
        items = list(entries.items())
    return [(key, _plan_node_ids(e.plan)) for key, e in items]


def check_dag(dag, plan_cache=None, staged=None) -> None:
    """Raise :class:`PlanValidationError` if ``dag`` is malformed.

    Runs the structural pass first, then (only on structurally sound DAGs,
    so findings never cascade) the schema-flow pass from
    :mod:`repro.analysis.schema_check` — every caller of this chokepoint
    (the pipeline's compile/re-optimize hook, the adaptive ``_adopt``
    helper) therefore gets the typed schema contract checked as well."""
    violations = validate_dag(dag, plan_cache, staged=staged)
    if not violations:
        from .schema_check import validate_dag_schemas

        violations = validate_dag_schemas(dag)
    if violations:
        raise PlanValidationError(violations)


def validation_enabled(config: Optional[dict] = None) -> bool:
    if os.environ.get(ENV_FLAG):
        return True
    return bool(config and config.get("debug.validate_plans"))


def maybe_validate_dag(dag, config: Optional[dict] = None,
                       plan_cache=None) -> None:
    """The pipeline's hook: validate iff the debug config or env asks."""
    if validation_enabled(config):
        check_dag(dag, plan_cache)
