"""Repo-specific invariant lint (AST-based).

Five checkers encode invariants the warehouse runtime depends on but the
language cannot express.  Each has bitten (or nearly bitten) this codebase:

REP001  every ``config.get("key")`` call site must name a key declared in
        ``repro.core.config_keys`` — an undeclared key silently reads its
        hard-coded fallback forever (``keep_acid_cols`` shipped that way).
REP002  operator *generator* loops that drain an exchange/shuffle/split
        reader must observe the cancel token at batch boundaries
        (``.check()`` / ``._checkpoint()``) — a missed check turns query
        cancellation into "runs to completion anyway" on that edge.
REP003  no new ``_collect`` (full materialization) call sites: spilling
        exchanges exist so operators stream; the three legacy sites
        (sort/window/global-aggregate) are allowlisted until their
        streaming rewrites land.
REP004  lock hygiene: a bare ``lock.acquire()`` statement must be
        immediately followed by ``try/finally: release`` (else an
        exception leaks a held lock), and ``cond.wait()`` must sit inside
        a predicate loop (``while``) — a bare wait misses wakeups and
        deadlocks on spurious ones.
REP005  a running query's DAG (``vertices`` / ``deps`` / ``edge_types``)
        may only be mutated by ``compile_dag``'s construction (dag.py) or
        inside the ``apply``/``undo`` closures the adaptive layer hands to
        its validating adopt-helper (``AdaptiveManager._adopt`` re-checks
        the whole DAG with ``check_dag`` and rolls back on violation) —
        any other mid-query structural edit bypasses validation and can
        wedge the pipelined scheduler.
REP006  streaming operators (generator functions) must derive output
        columns from the input batch or the node's declared schema, never
        from a hard-coded ``VectorBatch({...})`` dict literal — literal
        column names drift silently when the schema contract
        (``repro.core.schema``) evolves, and the static checker
        (SCH001-006) cannot see them.  Hidden ``__``-prefixed columns
        (ACID bookkeeping, dummy evaluation rows) are exempt.
REP007  traced subsystems (``core/runtime``, ``core/serving``,
        ``core/federation``) must not read ``time.monotonic()`` /
        ``time.perf_counter()`` directly — timing goes through
        ``repro.core.obs.clock`` (or a trace span), so every duration a
        trace, metric, or EXPLAIN ANALYZE reports comes off one clock.
        Raw reads drift out of trace timelines silently (the tracer
        timestamps spans on the obs clock).  The obs package itself and
        two wait-timing sites (WLM admission deadlines, the exchange
        stall detector) are allowlisted.

Findings can be suppressed per line with ``# repro-lint: REPnnn`` (comma
separated, or ``all``).  The CLI (``python -m repro.analysis``) exits
nonzero iff any unsuppressed finding remains.
"""
from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

CODES = {
    "REP001": "undeclared session-config key",
    "REP002": "reader loop misses cancel check",
    "REP003": "full materialization outside allowlist",
    "REP004": "lock/condition misuse",
    "REP005": "live-DAG mutation outside validated adoption",
    "REP006": "operator builds VectorBatch from a dict literal",
    "REP007": "raw clock read in a traced subsystem (use obs clock)",
}

# REP001 only polices the warehouse runtime; the modeling/training side of
# the repo has its own config conventions.
EXCLUDE_DIRS = {"models", "train", "configs", "distributed", "launch",
                "kernels", "__pycache__"}

# receivers whose .get() is a session-config read
_CONFIG_RECEIVERS = {"config", "cfg", "session_config"}

# reader-producing calls whose drain loops must be cancellable (REP002)
_READER_CALLS = {"reader", "lane_reader", "read_split"}

# cancel-observation calls that satisfy REP002
_CANCEL_CALLS = {"check", "_checkpoint"}

# DAG structural state (REP005): attributes whose mutation rewires a
# running query's DAG
_DAG_STRUCT_ATTRS = {"vertices", "deps", "edge_types"}

# container methods that mutate in place (REP005)
_MUTATING_METHODS = {"pop", "update", "clear", "append", "extend",
                     "insert", "remove", "setdefault", "popitem"}

# where DAG structure may legitimately change: dag.py builds the DAG
# before the scheduler adopts it; in adaptive.py only the apply/undo
# closures executed by the validating adopt-helper may rewrite it
_DAG_MUTATION_FILES = {"dag.py"}
_DAG_MUTATION_FUNCS = {"apply", "undo"}

# (file basename, enclosing function) pairs allowed to _collect (REP003):
# the sort / global-aggregate / window operators still materialize their
# input; each carries a TODO for the streaming rewrite.
COLLECT_ALLOWLIST: Set[Tuple[str, str]] = {
    ("exec.py", "_stream_sort"),
    ("exec.py", "_aggregate_materialized"),
    ("exec.py", "_stream_windowop"),
}

# raw time.* attributes REP007 polices in traced subsystems
_RAW_CLOCK_ATTRS = {"monotonic", "perf_counter"}

# REP007 subtree gate: which path segments put a file in a traced subsystem
_REP007_SUBSYSTEMS = {"runtime", "serving", "federation"}

# (file basename, enclosing function) pairs allowed to read raw clocks
# (REP007): these sites time *waiting*, not traced work — WLM admission
# deadline math and the exchange stall detector — and must not perturb
# the obs clock's span timeline semantics.
REP007_ALLOWLIST: Set[Tuple[str, str]] = {
    ("wlm.py", "wait_admit"),
    ("scheduler.py", "_put"),
}


def _rep007_applies(path: str) -> bool:
    """REP007 scope: inside the repro package only ``core/runtime``,
    ``core/serving`` and ``core/federation`` (never the obs layer itself,
    which aliases the raw clocks); outside the package (the lint fixture)
    the check always applies."""
    parts = path.replace(os.sep, "/").split("/")
    if "obs" in parts:
        return False
    if "repro" in parts:
        return "core" in parts and bool(_REP007_SUBSYSTEMS & set(parts))
    return True

_SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*([A-Za-z0-9_,\s]+)")


@dataclass(frozen=True)
class Finding:
    path: str
    line: int
    code: str
    message: str

    def __str__(self):
        return f"{self.path}:{self.line}: {self.code} {self.message}"


def _suppressions(source: str) -> Dict[int, Set[str]]:
    """line number -> set of suppressed codes (or {'all'})."""
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(line)
        if m:
            codes = {c.strip().upper() for c in m.group(1).split(",")
                     if c.strip()}
            out[i] = {"ALL"} if "ALL" in codes else codes
    return out


def _terminal_name(node: ast.AST) -> Optional[str]:
    """The last name segment of a Name / Attribute chain."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def _is_call_to(node: ast.AST, names: Set[str]) -> bool:
    return (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr in names)


class _Checker(ast.NodeVisitor):
    def __init__(self, path: str, declared_keys: Optional[Set[str]]):
        self.path = path
        self.base = os.path.basename(path)
        self.declared = declared_keys
        self.findings: List[Finding] = []
        self._func_stack: List[ast.AST] = []   # enclosing function nodes
        self._gen_stack: List[bool] = []       # is that function a generator?
        self._while_depth = 0
        self.check_config = True               # REP001 scope gate
        self.check_clock = _rep007_applies(path)  # REP007 scope gate

    # ------------------------------------------------------------- helpers
    def _emit(self, code: str, line: int, message: str) -> None:
        self.findings.append(Finding(self.path, line, code, message))

    def _current_func_name(self) -> Optional[str]:
        return self._func_stack[-1].name if self._func_stack else None

    def _in_generator(self) -> bool:
        return bool(self._gen_stack) and self._gen_stack[-1]

    @staticmethod
    def _is_generator(fn: ast.AST) -> bool:
        # manual walk that skips nested function bodies
        stack = list(ast.iter_child_nodes(fn))
        while stack:
            node = stack.pop()
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                continue
            if isinstance(node, (ast.Yield, ast.YieldFrom)):
                return True
            stack.extend(ast.iter_child_nodes(node))
        return False

    # ----------------------------------------------------------- traversal
    def visit_FunctionDef(self, node):
        self._func_stack.append(node)
        self._gen_stack.append(self._is_generator(node))
        self._check_body_statements(node.body)
        self.generic_visit(node)
        self._func_stack.pop()
        self._gen_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_While(self, node):
        self._while_depth += 1
        self.generic_visit(node)
        self._while_depth -= 1

    def visit_Module(self, node):
        self._check_body_statements(node.body)
        self.generic_visit(node)

    def visit_If(self, node):
        self._check_body_statements(node.body)
        self._check_body_statements(node.orelse)
        self.generic_visit(node)

    def visit_With(self, node):
        self._check_body_statements(node.body)
        self.generic_visit(node)

    def visit_Try(self, node):
        self._check_body_statements(node.body)
        self._check_body_statements(node.finalbody)
        self._check_body_statements(node.orelse)
        for handler in node.handlers:
            self._check_body_statements(handler.body)
        self.generic_visit(node)

    # --------------------------------------------------------------- REP001
    def visit_Call(self, node):
        if (self.check_config and self.declared is not None
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "get" and node.args
                and isinstance(node.args[0], ast.Constant)
                and isinstance(node.args[0].value, str)):
            recv = _terminal_name(node.func.value)
            if recv in _CONFIG_RECEIVERS:
                key = node.args[0].value
                if key not in self.declared:
                    self._emit(
                        "REP001", node.lineno,
                        f"config key {key!r} is not declared in "
                        f"repro.core.config_keys",
                    )
        # REP003: _collect call sites
        callee = None
        if isinstance(node.func, ast.Attribute):
            callee = node.func.attr
        elif isinstance(node.func, ast.Name):
            callee = node.func.id
        if callee == "_collect":
            fn = self._current_func_name() or "<module>"
            if ((self.base, fn) not in COLLECT_ALLOWLIST
                    and fn != "_collect"):
                self._emit(
                    "REP003", node.lineno,
                    f"_collect (full materialization) in {fn}() is not "
                    f"allowlisted — stream through the exchange instead",
                )
        # REP005: in-place mutation via container methods
        if (isinstance(node.func, ast.Attribute)
                and node.func.attr in _MUTATING_METHODS):
            attr = self._dag_struct_attr(node.func.value)
            if attr is not None:
                self._check_dag_mutation(node, attr,
                                         f".{node.func.attr}()")
        # REP007: raw time.monotonic()/time.perf_counter() in a traced
        # subsystem — timing there must come off the obs clock
        if (self.check_clock
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in _RAW_CLOCK_ATTRS
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "time"):
            fn = self._current_func_name() or "<module>"
            if (self.base, fn) not in REP007_ALLOWLIST:
                self._emit(
                    "REP007", node.lineno,
                    f"raw time.{node.func.attr}() in {fn}() — traced "
                    f"subsystems time through repro.core.obs.clock (or a "
                    f"span) so traces, metrics, and EXPLAIN ANALYZE share "
                    f"one clock",
                )
        # REP006: VectorBatch({...}) dict literal inside an operator
        if (self._in_generator()
                and _terminal_name(node.func) == "VectorBatch"
                and node.args and isinstance(node.args[0], ast.Dict)):
            literal_names = [
                k.value for k in node.args[0].keys
                if isinstance(k, ast.Constant) and isinstance(k.value, str)
                and not k.value.startswith("__")
            ]
            if literal_names:
                self._emit(
                    "REP006", node.lineno,
                    f"operator hard-codes output column(s) "
                    f"{literal_names[:4]} in a VectorBatch dict literal — "
                    f"derive names from the input batch or the node's "
                    f"declared schema so the schema contract can check "
                    f"them",
                )
        self.generic_visit(node)

    # --------------------------------------------------------------- REP002
    def visit_For(self, node):
        if self._in_generator() and _is_call_to(node.iter, _READER_CALLS):
            observed = any(
                _is_call_to(inner, _CANCEL_CALLS)
                for stmt in node.body
                for inner in ast.walk(stmt)
            )
            if not observed:
                src = node.iter.func.attr  # type: ignore[union-attr]
                self._emit(
                    "REP002", node.lineno,
                    f"generator loop over .{src}() never observes the "
                    f"cancel token (call .check() or self._checkpoint() "
                    f"once per batch)",
                )
        self.generic_visit(node)

    # --------------------------------------------------------------- REP005
    def _dag_struct_attr(self, node: ast.AST) -> Optional[str]:
        """``vertices``/``deps``/``edge_types`` if ``node`` is an attribute
        access on one of them (``dag.vertices``, ``merge.deps``, ...)."""
        if (isinstance(node, ast.Attribute)
                and node.attr in _DAG_STRUCT_ATTRS):
            return node.attr
        return None

    def _dag_mutation_allowed(self) -> bool:
        if self.base in _DAG_MUTATION_FILES:
            return True
        if self.base == "adaptive.py":
            return self._current_func_name() in _DAG_MUTATION_FUNCS
        return False

    def _check_dag_mutation(self, node: ast.AST, attr: str,
                            what: str) -> None:
        if self._dag_mutation_allowed():
            return
        self._emit(
            "REP005", node.lineno,
            f"{what} of .{attr} mutates a live DAG outside the validating "
            f"adopt-helper — route the rewrite through an apply/undo pair "
            f"given to AdaptiveManager._adopt (it re-runs check_dag and "
            f"rolls back on violation)",
        )

    def _check_mutation_targets(self, targets: Iterable[ast.AST],
                                stmt: ast.AST, what: str) -> None:
        for tgt in targets:
            attr = None
            if isinstance(tgt, ast.Subscript):
                attr = self._dag_struct_attr(tgt.value)
            else:
                attr = self._dag_struct_attr(tgt)
            if attr is not None:
                self._check_dag_mutation(stmt, attr, what)

    def visit_Assign(self, node):
        self._check_mutation_targets(node.targets, node, "assignment")
        self.generic_visit(node)

    def visit_AugAssign(self, node):
        self._check_mutation_targets([node.target], node, "assignment")
        self.generic_visit(node)

    def visit_Delete(self, node):
        self._check_mutation_targets(node.targets, node, "deletion")
        self.generic_visit(node)

    # --------------------------------------------------------------- REP004
    def _check_body_statements(self, body: Sequence[ast.stmt]) -> None:
        """Bare ``x.acquire()`` must be immediately followed by a
        try/finally that releases."""
        for i, stmt in enumerate(body):
            if not (isinstance(stmt, ast.Expr)
                    and _is_call_to(stmt.value, {"acquire"})):
                continue
            nxt = body[i + 1] if i + 1 < len(body) else None
            ok = (isinstance(nxt, ast.Try) and any(
                _is_call_to(inner, {"release"})
                for fstmt in nxt.finalbody
                for inner in ast.walk(fstmt)
            ))
            if not ok:
                recv = _terminal_name(stmt.value.func.value) or "lock"
                self._emit(
                    "REP004", stmt.lineno,
                    f"bare {recv}.acquire() without an immediate "
                    f"try/finally release — an exception here leaks a "
                    f"held lock (prefer `with {recv}:`)",
                )

    def visit_Expr(self, node):
        # cond.wait() outside a predicate loop
        call = node.value
        if (isinstance(call, ast.Call)
                and isinstance(call.func, ast.Attribute)
                and call.func.attr == "wait"):
            recv = _terminal_name(call.func.value) or ""
            if "cond" in recv.lower() and self._while_depth == 0:
                self._emit(
                    "REP004", node.lineno,
                    f"{recv}.wait() outside a `while <predicate>` loop — "
                    f"spurious/missed wakeups require re-checking the "
                    f"predicate (or use wait_for)",
                )
        self.generic_visit(node)


def _declared_keys() -> Optional[Set[str]]:
    try:
        from repro.core.config_keys import CONFIG_KEYS
        return set(CONFIG_KEYS)
    except Exception:  # registry import failure: skip REP001, lint the rest
        return None


def lint_source(source: str, path: str = "<string>",
                declared_keys: Optional[Set[str]] = None) -> List[Finding]:
    """Lint one source blob; returns unsuppressed findings."""
    tree = ast.parse(source, filename=path)
    checker = _Checker(path, declared_keys if declared_keys is not None
                       else _declared_keys())
    # REP001 scope: warehouse code only
    parts = set(path.replace(os.sep, "/").split("/"))
    if parts & EXCLUDE_DIRS:
        checker.check_config = False
    checker.visit(tree)
    suppress = _suppressions(source)
    out = []
    for f in sorted(checker.findings, key=lambda f: (f.line, f.code)):
        codes = suppress.get(f.line, ())
        if "ALL" in codes or f.code in codes:
            continue
        out.append(f)
    return out


def lint_file(path: str,
              declared_keys: Optional[Set[str]] = None) -> List[Finding]:
    with open(path, "r", encoding="utf-8") as fh:
        return lint_source(fh.read(), path, declared_keys)


def iter_python_files(root: str) -> Iterable[str]:
    if os.path.isfile(root):
        yield root
        return
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for name in sorted(filenames):
            if name.endswith(".py"):
                yield os.path.join(dirpath, name)


def lint_paths(paths: Sequence[str]) -> List[Finding]:
    declared = _declared_keys()
    findings: List[Finding] = []
    for root in paths:
        for path in iter_python_files(root):
            findings.extend(lint_file(path, declared))
    return findings
